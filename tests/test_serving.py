"""Adaptive serving runtime: controller hysteresis, plan ladder/refresh
invariants, store watcher, and the end-to-end hot-swap serve (CPU,
reduced model) with a single decode trace."""

import numpy as np
import pytest

from repro.core.arith import benchmark
from repro.core.circuits import Circuit, Op
from repro.core.synth import area
from repro.library import (
    OperatorSignature,
    OperatorStore,
    plan_ladder,
    refresh_plan,
    select_plan,
    validate_lut_stack,
)
from repro.library.compile import load_mul_frontier
from repro.serving import (
    ControllerConfig,
    LibraryWatcher,
    PlanLadder,
    QoSController,
    Telemetry,
    steady,
)
from repro.serving.loadgen import make_profile, synth_requests


# ---------------------------------------------------------------------------
# handcrafted 2-bit multipliers: deterministic frontier rungs for the tests
# ---------------------------------------------------------------------------
def trunc_mul2() -> Circuit:
    """Exact low 2 product bits, upper bits dropped (wce 8, small area)."""
    c = Circuit.empty(4, "trunc_mul2")
    a0, a1, b0, b1 = 0, 1, 2, 3
    p0 = c.add(Op.AND, a0, b0)
    p1 = c.add(Op.XOR, c.add(Op.AND, a1, b0), c.add(Op.AND, a0, b1))
    z = c.const(False)
    for out in (p0, p1, z, z):
        c.mark_output(out)
    return c


def zero_mul2() -> Circuit:
    """Constant-zero multiplier (wce 9, ~zero area) — the frontier floor."""
    c = Circuit.empty(4, "zero_mul2")
    z = c.const(False)
    for _ in range(4):
        c.mark_output(z)
    return c


def fill_library(root, circuits) -> OperatorStore:
    store = OperatorStore(root)
    exact_vals = benchmark("mul_i4").eval_words().astype(np.int64)
    for circ in circuits:
        wce = int(np.abs(circ.eval_words().astype(np.int64) - exact_vals).max())
        store.put_circuit(
            circ, OperatorSignature("mul", 2, "wce", max(1, wce)),
            area=area(circ), source="test",
        )
    return store


@pytest.fixture()
def two_op_library(tmp_path):
    """Exact + truncated multiplier: a 2-rung frontier."""
    root = tmp_path / "lib"
    fill_library(root, [benchmark("mul_i4"), trunc_mul2()])
    return root


# ---------------------------------------------------------------------------
# plan ladder / refresh / validation (library.qos extensions)
# ---------------------------------------------------------------------------
def test_plan_ladder_monotone(two_op_library):
    compiled, exact_area, _ = load_mul_frontier(two_op_library)
    sens = np.ones(3)
    ladder = plan_ladder(compiled, sens, exact_area=exact_area, levels=5)
    assert len(ladder) >= 2
    assert all(c.key is None for c in ladder[0].choices)  # level 0 = exact
    areas = [p.total_area for p in ladder]
    drifts = [p.predicted_total for p in ladder]
    assert all(a > b for a, b in zip(areas, areas[1:])), areas
    assert all(a <= b for a, b in zip(drifts, drifts[1:])), drifts
    # last level is the full descent: every layer on its cheapest rung
    cheapest = min(rec.area for rec, _ in compiled)
    assert all(c.area == cheapest for c in ladder[-1].choices)


def test_plan_ladder_minimum_levels_reach_full_descent(two_op_library):
    """Even the coarsest ladder must span exact -> full greedy descent,
    otherwise a post-refresh controller can never reach the cheap plans."""
    compiled, exact_area, _ = load_mul_frontier(two_op_library)
    cheapest = min(rec.area for rec, _ in compiled)
    for levels in (2, 3):
        ladder = plan_ladder(compiled, np.ones(2), exact_area=exact_area,
                             levels=levels)
        assert all(c.key is None for c in ladder[0].choices)
        assert all(c.area == cheapest for c in ladder[-1].choices), levels


def test_refresh_plan_keeps_budget_and_monotonicity(tmp_path):
    root = tmp_path / "lib"
    store = fill_library(root, [benchmark("mul_i4"), trunc_mul2()])
    compiled, exact_area, _ = load_mul_frontier(root)
    sens = np.ones(4)
    lo = select_plan(compiled, sens, 1.0, exact_area=exact_area)
    hi = select_plan(compiled, sens, 1e9, exact_area=exact_area)

    # densify the store, refresh both plans against the new frontier
    circ = zero_mul2()
    store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 9),
                      area=area(circ), source="test")
    compiled2, exact_area2, _ = load_mul_frontier(root)
    assert len(compiled2) == len(compiled) + 1
    lo2 = refresh_plan(lo, compiled2, sens, exact_area=exact_area2)
    hi2 = refresh_plan(hi, compiled2, sens, exact_area=exact_area2)
    assert lo2.budget == lo.budget and hi2.budget == hi.budget
    # monotonicity survives the refresh: tighter budget never buys more area
    assert lo2.total_area >= hi2.total_area
    # the unbounded plan adopts the newly added cheaper operator everywhere
    assert hi2.total_area < hi.total_area


def test_validate_lut_stack_rejects_mismatch():
    ok = np.zeros((4, 16, 16), np.int32)
    validate_lut_stack(ok, np.ones((4, 16, 16), np.int32))  # no raise
    with pytest.raises(ValueError, match="refusing"):
        validate_lut_stack(ok, np.zeros((5, 16, 16), np.int32))
    with pytest.raises(ValueError, match="refusing"):
        validate_lut_stack(ok, np.zeros((4, 16, 16), np.int64))


def test_plan_id_tracks_assignment_not_budget(two_op_library):
    compiled, exact_area, _ = load_mul_frontier(two_op_library)
    sens = np.ones(2)
    a = select_plan(compiled, sens, 0.0, exact_area=exact_area)
    b = select_plan(compiled, sens, 1e-9, exact_area=exact_area)
    c = select_plan(compiled, sens, 1e9, exact_area=exact_area)
    assert a.plan_id == b.plan_id        # same assignment, different budget
    assert a.plan_id != c.plan_id


# ---------------------------------------------------------------------------
# controller hysteresis
# ---------------------------------------------------------------------------
def _ladder(library, n_layers=2, levels=4):
    compiled, exact_area, _ = load_mul_frontier(library)
    return PlanLadder.build(compiled, n_layers, exact_area=exact_area,
                            levels=levels)


def test_controller_no_flap_on_oscillating_latency(two_op_library):
    ladder = _ladder(two_op_library)
    ctrl = QoSController(ladder, ControllerConfig(
        target_ms_per_step=50.0, drift_budget=1.0, patience=2, cooldown=1,
        ewma_alpha=0.3))
    # oscillation straddling the band: streaks keep resetting -> no move
    for i in range(40):
        assert ctrl.observe(80.0 if i % 2 else 20.0) is None
    assert ctrl.moves == 0 and ctrl.level == 0
    # oscillation *inside* the deadband: no move either
    for i in range(40):
        assert ctrl.observe(53.0 if i % 2 else 47.0) is None
    assert ctrl.moves == 0


def test_controller_walks_up_under_load_then_down_on_drift(two_op_library):
    ladder = _ladder(two_op_library)
    top = len(ladder) - 1
    ctrl = QoSController(ladder, ControllerConfig(
        target_ms_per_step=10.0, drift_budget=0.1, patience=1, cooldown=0,
        ewma_alpha=1.0))
    # sustained overload with drift headroom: walk up to the cheapest level
    levels = [ctrl.observe(100.0, drift=0.0) for _ in range(top + 2)]
    assert ctrl.level == top
    assert [l for l in levels if l is not None] == list(range(1, top + 1))
    # drift headroom gone: walks back down even though still overloaded
    ctrl.observe(100.0, drift=10.0)
    assert ctrl.level == top - 1
    assert ctrl.last_reason == "drift"


def test_controller_idle_steps_back_toward_exact(two_op_library):
    ladder = _ladder(two_op_library)
    ctrl = QoSController(ladder, ControllerConfig(
        target_ms_per_step=50.0, drift_budget=1.0, patience=2, cooldown=0,
        ewma_alpha=1.0), level=len(ladder) - 1)
    for _ in range(2):
        ctrl.observe(10.0)
    assert ctrl.level == len(ladder) - 2
    assert ctrl.last_reason == "idle"


def test_controller_cooldown_spaces_moves(two_op_library):
    ladder = _ladder(two_op_library, levels=6)
    if len(ladder) < 3:
        pytest.skip("frontier too coarse for a 3-level ladder")
    ctrl = QoSController(ladder, ControllerConfig(
        target_ms_per_step=10.0, drift_budget=1.0, patience=1, cooldown=3,
        ewma_alpha=1.0))
    moves = [ctrl.observe(100.0) for _ in range(8)]
    moved_at = [i for i, m in enumerate(moves) if m is not None]
    assert all(b - a >= 4 for a, b in zip(moved_at, moved_at[1:])), moved_at


def test_controller_refresh_clamps_level(two_op_library, tmp_path):
    ladder = _ladder(two_op_library)
    ctrl = QoSController(ladder, ControllerConfig(), level=len(ladder) - 1)
    compiled, exact_area, _ = load_mul_frontier(two_op_library)
    ctrl.refresh(compiled[:1], exact_area)   # frontier collapsed to 1 op
    assert ctrl.level <= len(ctrl.ladder) - 1


def test_ladder_refresh_keeps_requested_resolution(tmp_path):
    """A sparse frontier dedups the ladder; refreshing against a denser
    one must regain the *requested* level count, not ratchet down."""
    root = tmp_path / "lib"
    store = fill_library(root, [benchmark("mul_i4"), trunc_mul2()])
    compiled, exact_area, _ = load_mul_frontier(root)
    sparse = PlanLadder.build(compiled[:1], 4, exact_area=exact_area,
                              levels=6)
    assert sparse.requested_levels == 6
    circ = zero_mul2()
    store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 9),
                      area=area(circ), source="test")
    compiled2, exact_area2, _ = load_mul_frontier(root)
    dense = sparse.refresh(compiled2, exact_area2)
    assert len(dense) > len(sparse)
    assert dense.requested_levels == 6


# ---------------------------------------------------------------------------
# watcher / store version token
# ---------------------------------------------------------------------------
def test_version_token_changes_on_put(tmp_path):
    store = fill_library(tmp_path / "lib", [benchmark("mul_i4")])
    t0 = store.version_token()
    assert t0 == store.version_token()       # stable across reads
    circ = trunc_mul2()
    store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 8),
                      area=area(circ), source="test")
    assert store.version_token() != t0


def test_watcher_detects_midrun_put(two_op_library):
    watcher = LibraryWatcher(two_op_library, min_poll_s=0.0)
    assert not watcher.poll()                # nothing changed yet
    store = OperatorStore(two_op_library)
    circ = zero_mul2()
    store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 9),
                      area=area(circ), source="fleet")
    assert watcher.poll()                    # change seen exactly once
    assert not watcher.poll()
    compiled, _, bits = watcher.load_frontier()
    assert bits == 2
    assert any(r.wce == 9 for r, _ in compiled)


def test_watcher_rate_limit(two_op_library):
    now = [0.0]
    watcher = LibraryWatcher(two_op_library, min_poll_s=5.0,
                             clock=lambda: now[0])
    store = OperatorStore(two_op_library)
    circ = zero_mul2()
    store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 9),
                      area=area(circ), source="fleet")
    now[0] = 1.0
    assert not watcher.poll()                # inside the rate limit
    now[0] = 6.0
    assert watcher.poll()


# ---------------------------------------------------------------------------
# loadgen / telemetry
# ---------------------------------------------------------------------------
def test_loadgen_profiles_deterministic():
    p = make_profile("ramp", ticks=5, per_tick=4, prompt_len=8, gen_len=2)
    assert p.arrivals[-1] == 4 and p.n_ticks == 5
    r1 = synth_requests(p, vocab_size=128, seed=3)
    r2 = synth_requests(p, vocab_size=128, seed=3)
    flat1 = [t for tick in r1 for r in tick for t in r.tokens.tolist()]
    flat2 = [t for tick in r2 for r in tick for t in r.tokens.tolist()]
    assert flat1 == flat2
    assert sum(len(t) for t in r1) == p.total_requests
    spike_p = make_profile("spike", ticks=8, per_tick=6)
    assert max(spike_p.arrivals) == 6 and min(spike_p.arrivals) == 1


def test_telemetry_ring_bounds_and_summary(two_op_library):
    compiled, exact_area, _ = load_mul_frontier(two_op_library)
    plan = select_plan(compiled, np.ones(2), 1e9, exact_area=exact_area)
    tel = Telemetry(capacity=4)
    tel.register_plan(plan)
    for b in range(10):
        tel.record_batch(batch=b, tick=b, n_requests=2, prefill_s=0.1,
                         decode_s=0.2, prefill_tokens=8, decode_tokens=16,
                         decode_steps=8, plan_id=plan.plan_id)
    tel.record_swap(batch=9, reason="qos-load", old=None, new=plan.plan_id)
    assert len(tel.events) == 4              # ring stays bounded
    s = tel.summary()
    assert s["batches"] == 10 and s["requests"] == 20
    assert s["swaps"] == 1 and s["swaps_by_reason"] == {"qos-load": 1}
    assert s["decode_tok_s"] == pytest.approx(16 / 0.2, rel=1e-3)
    assert s["prefill_tok_s"] == pytest.approx(8 / 0.1, rel=1e-3)


# ---------------------------------------------------------------------------
# end-to-end: adaptive serve with controller + watcher hot-swaps, one trace
# ---------------------------------------------------------------------------
def test_e2e_adaptive_serve_hot_swaps_without_retrace(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_model
    from repro.serving import ServingEngine

    lib = tmp_path / "lib"
    store = fill_library(lib, [benchmark("mul_i4"), trunc_mul2()])
    compiled, exact_area, _ = load_mul_frontier(lib)

    cfg = get_config("gemma3-1b", reduced=True).with_approx_mlp()
    params = init_model(cfg, jax.random.PRNGKey(0))

    ladder = PlanLadder.build(compiled, cfg.n_layers, exact_area=exact_area,
                              levels=4)
    assert len(ladder) >= 2
    # unreachable latency target -> sustained "overload" on any machine, so
    # the controller must walk the frontier up; huge drift budget keeps the
    # walk unobstructed
    ctrl = QoSController(ladder, ControllerConfig(
        target_ms_per_step=1e-6, drift_budget=1e9, patience=1, cooldown=0,
        shadow_every=1, ewma_alpha=1.0))
    watcher = LibraryWatcher(lib, min_poll_s=0.0)

    def densify_midrun(engine, batch_idx):
        if batch_idx == 2:   # a "background fleet sweep" lands a cheaper op
            circ = zero_mul2()
            store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 9),
                              area=area(circ), source="fleet")

    engine = ServingEngine(cfg, params, batch=2, prompt_len=4, gen_len=4,
                           plan=ladder.plan(0), compiled=compiled,
                           exact_area=exact_area)
    profile = steady(6, 2, prompt_len=4, gen_len=4)
    tel = engine.serve(profile, controller=ctrl, watcher=watcher,
                       telemetry=Telemetry(), on_batch_end=densify_midrun)

    reasons = {s["reason"] for s in tel.swaps}
    assert any(r.startswith("qos-") for r in reasons), tel.swaps
    assert "library" in reasons, tel.swaps
    assert tel.swap_count >= 2
    # the decode step was traced exactly once across every swap
    assert engine.trace_count == 1
    # the serve ended on a cheaper-than-exact plan that includes the
    # mid-run operator (zero_mul2 has area ~0)
    assert engine.plan.total_area < ladder.plan(0).total_area
    keys_used = {c.key for c in engine.plan.choices}
    new_keys = {r.key for r, _ in engine._compiled if r.wce == 9}
    assert keys_used & new_keys, (keys_used, new_keys)
    # drift was sampled against the exact shadow step
    assert any(e["drift"] is not None for e in tel.events)
    s = tel.summary()
    assert s["batches"] == 6 and s["requests"] == 12
    assert s["plans_used"] >= 2


def test_e2e_plain_engine_single_trace(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_model
    from repro.serving import ServingEngine

    cfg = get_config("gemma3-1b", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch=2, prompt_len=4, gen_len=4)
    tel = engine.serve(steady(2, 3, prompt_len=4, gen_len=4))
    # 3 arrivals/tick on batch=2 -> two batches per tick (one short, padded)
    assert tel.n_batches == 4 and tel.n_requests == 6
    assert engine.trace_count == 1
    assert tel.summary()["decode_tok_s"] > 0
    # the short final batch keeps only the real request's completion
    assert engine.last_tokens.shape == (1, 4)
