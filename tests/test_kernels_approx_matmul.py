"""Pallas approx_matmul (bitplane/one-hot MXU formulation) vs gather oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.quant import approx_linear, build_lut, exact_mul_lut, quantize_int4
from repro.core.arith import benchmark


@pytest.mark.parametrize("M,K,N", [
    (8, 16, 8),
    (37, 53, 29),       # awkward shapes -> padding paths
    (128, 128, 128),    # exact block fit
    (130, 257, 64),
])
def test_matches_gather_oracle(M, K, N, rng):
    lut = rng.integers(0, 226, size=(16, 16)).astype(np.int32)
    a = rng.integers(0, 16, size=(M, K)).astype(np.int32)
    b = rng.integers(0, 16, size=(K, N)).astype(np.int32)
    gt = lut[a[:, :, None], b[None, :, :]].sum(axis=1)
    o_ref = np.asarray(ref.approx_matmul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut)))
    o_pal = np.asarray(ops.approx_matmul(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut),
        backend="pallas_interpret"))
    assert np.array_equal(o_ref, gt)
    assert np.array_equal(o_pal, gt)


def test_exact_lut_reproduces_int_matmul(rng):
    """With the exact product table, the LUT matmul IS an int matmul."""
    lut = exact_mul_lut()
    a = rng.integers(0, 16, size=(24, 48)).astype(np.int32)
    b = rng.integers(0, 16, size=(48, 16)).astype(np.int32)
    out = np.asarray(ops.approx_matmul(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut), backend="ref"))
    assert np.array_equal(out, a @ b)


def test_lut_built_from_exact_circuit_is_exact():
    lut = build_lut(benchmark("mul_i8"))
    assert np.array_equal(lut, exact_mul_lut())


def test_approx_linear_signed_decomposition(rng):
    """Signed int4 x int4 through the unsigned multiplier + exact correction
    equals the plain quantized matmul when the LUT is exact."""
    x = rng.standard_normal((5, 32)).astype(np.float32)
    w = rng.standard_normal((32, 7)).astype(np.float32)
    lut = jnp.asarray(exact_mul_lut())
    got = np.asarray(approx_linear(jnp.asarray(x), jnp.asarray(w), lut, backend="ref"))
    xq, sx = quantize_int4(jnp.asarray(x), axis=-1)
    wq, sw = quantize_int4(jnp.asarray(w), axis=0)
    want = np.asarray(
        ((np.asarray(xq) - 8) @ (np.asarray(wq) - 8)).astype(np.float32)
        * np.asarray(sx) * np.asarray(sw)
    )
    assert np.allclose(got, want, rtol=1e-5, atol=1e-5)
