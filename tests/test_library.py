"""Operator library: store round-trip, frontier dominance, LUT lowering,
QoS selection invariants."""

import numpy as np
import pytest

from repro.core.arith import benchmark
from repro.core.baselines import muscat_like
from repro.core.circuits import Circuit, Op
from repro.core.synth import area
from repro.library import (
    OperatorRecord,
    OperatorSignature,
    OperatorStore,
    ParetoFrontier,
    compile_record,
    pareto_front,
    select_plan,
    stack_luts,
)
from repro.library.compile import (
    base_table,
    clear_compile_cache,
    compile_cache_stats,
    compile_circuit,
    exact_lut16,
)
from repro.library.qos import measure_sensitivities
from repro.library.store import circuit_from_dict, circuit_to_dict
from repro.quant import build_lut


@pytest.fixture(scope="module")
def mul2_ops():
    """A few sound 2-bit multipliers at different ETs (plus the exact one)."""
    exact = benchmark("mul_i4")
    ops = {0: (exact, area(exact))}
    for et in (1, 2, 4):
        res = muscat_like(exact, et=et, restarts=2, wall_budget_s=10)
        ops[et] = (res.circuit, res.area)
    return ops


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------
def test_store_roundtrip_identical_lut(tmp_path, mul2_ops):
    store = OperatorStore(tmp_path / "lib")
    circ, a = mul2_ops[2]
    sig = OperatorSignature("mul", 2, "wce", 2)
    rec = store.put_circuit(circ, sig, area=a, source="muscat")
    assert rec.key

    back = store.get(sig, rec.key)
    assert back.area == rec.area
    assert back.wce == rec.wce
    assert back.source == "muscat"
    # the reloaded netlist must compile to the *identical* LUT
    np.testing.assert_array_equal(
        compile_record(back).lut, compile_record(rec).lut
    )
    np.testing.assert_array_equal(build_lut(back.circuit), build_lut(circ))


def test_store_put_is_idempotent(tmp_path, mul2_ops):
    store = OperatorStore(tmp_path / "lib")
    circ, a = mul2_ops[1]
    sig = OperatorSignature("mul", 2, "wce", 1)
    r1 = store.put_circuit(circ, sig, area=a)
    r2 = store.put_circuit(circ, sig, area=a)
    assert r1.key == r2.key
    assert len(store) == 1


def test_store_rejects_unsound_operator(tmp_path, mul2_ops):
    store = OperatorStore(tmp_path / "lib")
    circ, a = mul2_ops[4]  # wce possibly up to 4
    exact = benchmark("mul_i4")
    wce = int(np.abs(circ.eval_words().astype(np.int64)
                     - exact.eval_words().astype(np.int64)).max())
    if wce == 0:
        pytest.skip("pruner found an exact circuit; nothing unsound to store")
    with pytest.raises(ValueError, match="unsound"):
        store.put_circuit(circ, OperatorSignature("mul", 2, "wce", wce - 1),
                          area=a)


def test_store_query_filters_and_version(tmp_path, mul2_ops):
    store = OperatorStore(tmp_path / "lib")
    for et in (1, 2, 4):
        circ, a = mul2_ops[et]
        store.put_circuit(circ, OperatorSignature("mul", 2, "wce", et), area=a)
    assert len(store.query("mul", 2)) == len(store)
    assert store.query("adder") == []
    assert {s.threshold for s in store.signatures()} == {1, 2, 4}
    le2 = store.query("mul", 2, max_threshold=2)
    assert all(r.signature.threshold <= 2 for r in le2)

    # future format versions are rejected, not misparsed
    import json
    path = next((tmp_path / "lib").glob("*/*.json"))
    doc = json.loads(path.read_text())
    doc["format_version"] = 999
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="format_version"):
        store.query("mul", 2)


def test_store_skips_foreign_signature_dirs(tmp_path, mul2_ops):
    """A merged-in future store (e.g. 8-bit operators) must not break
    queries over the signatures this reader understands."""
    store = OperatorStore(tmp_path / "lib")
    circ, a = mul2_ops[1]
    store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 1), area=a)
    (tmp_path / "lib" / "mul8b_wce1").mkdir()
    (tmp_path / "lib" / "not-a-signature").mkdir()
    assert len(store.signatures()) == 1
    assert len(store.query("mul")) == 1


def test_circuit_serialization_roundtrip():
    c = benchmark("adder_i6")
    back = circuit_from_dict(circuit_to_dict(c))
    assert np.array_equal(back.eval_words(), c.eval_words())
    assert back.name == c.name


# ---------------------------------------------------------------------------
# pareto
# ---------------------------------------------------------------------------
def _fake_record(a: float, wce: int) -> OperatorRecord:
    sig = OperatorSignature("mul", 2, "wce", max(wce, 1))
    return OperatorRecord(signature=sig, circuit=benchmark("mul_i4"),
                          area=a, wce=wce, mae=float(wce) / 4,
                          key=f"a{a}w{wce}")


def test_pareto_dominated_never_returned():
    recs = [
        _fake_record(10.0, 0),
        _fake_record(8.0, 1),
        _fake_record(9.0, 2),   # dominated by (8.0, 1)
        _fake_record(8.0, 3),   # dominated by (8.0, 1)
        _fake_record(5.0, 3),
        _fake_record(5.0, 5),   # dominated by (5.0, 3)
    ]
    fr = ParetoFrontier(recs)
    areas = {(r.area, r.wce) for r in fr.front}
    assert areas == {(10.0, 0), (8.0, 1), (5.0, 3)}
    for q in (fr.query(), fr.query(max_error=3), fr.query(max_area=8.0)):
        for r in q:
            assert (r.area, r.wce) in areas
    assert fr.best_under_error(2).area == 8.0
    assert fr.best_under_error(0).area == 10.0
    assert fr.cheapest().area == 5.0
    assert fr.most_accurate().wce == 0


def test_pareto_front_generic_objectives():
    pts = [(1, 9), (2, 2), (3, 1), (3, 3), (4, 0), (2, 2)]
    front = pareto_front(pts, (lambda p: p[0], lambda p: p[1]))
    assert front == [(1, 9), (2, 2), (3, 1), (4, 0)]


# ---------------------------------------------------------------------------
# compile
# ---------------------------------------------------------------------------
def test_exact_2bit_mul_tiles_to_exact_16x16():
    comp = compile_circuit(benchmark("mul_i4"), "mul", 2)
    np.testing.assert_array_equal(comp.lut, exact_lut16("mul"))
    assert comp.wce16 == 0 and comp.mae16 == 0.0


def test_exact_2bit_adder_chains_to_exact_16x16():
    comp = compile_circuit(benchmark("adder_i4"), "adder", 2)
    np.testing.assert_array_equal(comp.lut, exact_lut16("adder"))


def test_exact_3bit_blocks_compose_exactly():
    """bits=3 is the odd case: the top chunk is 1 bit wide and the final
    adder carry sits at bit 6, not bit 4."""
    np.testing.assert_array_equal(
        compile_circuit(benchmark("mul_i6"), "mul", 3).lut, exact_lut16("mul")
    )
    np.testing.assert_array_equal(
        compile_circuit(benchmark("adder_i6"), "adder", 3).lut,
        exact_lut16("adder"),
    )


def test_exact_4bit_paths_match_build_lut():
    mul4 = benchmark("mul_i8")
    comp = compile_circuit(mul4, "mul", 4)
    np.testing.assert_array_equal(comp.lut, build_lut(mul4))
    add4 = benchmark("adder_i8")
    np.testing.assert_array_equal(
        compile_circuit(add4, "adder", 4).lut, exact_lut16("adder")
    )


def test_approx_block_tiling_bounds_error(mul2_ops):
    """Tiling an approximate block keeps the compiled table's wce finite and
    >= the block-level wce signal (errors compose, never vanish)."""
    circ, _ = mul2_ops[2]
    base = base_table(circ, 2)
    block_err = np.abs(base - exact_lut16("mul")[:4, :4]).max()
    comp = compile_circuit(circ, "mul", 2)
    # each of the 4 chunk products contributes <= block_err * 2**(2*(i+j))
    assert comp.wce16 <= block_err * (1 + 4 + 4 + 16)
    if block_err > 0:
        assert comp.wce16 > 0


def test_compile_cache_hits(tmp_path, mul2_ops):
    clear_compile_cache()
    store = OperatorStore(tmp_path / "lib")
    circ, a = mul2_ops[1]
    rec = store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 1), area=a)
    c1 = compile_record(rec)
    c2 = compile_record(rec)
    assert c1 is c2
    stats = compile_cache_stats()
    assert stats["hits"] >= 1 and stats["misses"] == 1


# ---------------------------------------------------------------------------
# qos
# ---------------------------------------------------------------------------
def _operator_set():
    """Three synthetic frontier operators (area descending, error ascending)."""
    ops = []
    for key, a, mae in (("fine", 8.0, 0.1), ("mid", 5.0, 0.5), ("coarse", 2.0, 2.0)):
        rec = _fake_record(a, int(mae * 4))
        rec.key = key
        lut = exact_lut16("mul") + np.full((16, 16), 0, dtype=np.int64)
        from repro.library.compile import CompiledLut
        ops.append((rec, CompiledLut(lut.astype(np.int32), "mul", 2, int(mae * 4), mae)))
    return ops


def test_qos_budget_monotonicity():
    ops = _operator_set()
    sens = np.array([0.3, 1.0, 0.1, 2.0, 0.5])
    budgets = [0.0, 0.05, 0.2, 0.5, 1.0, 2.0, 5.0, 50.0]
    areas = [
        select_plan(ops, sens, b, exact_area=10.0).total_area for b in budgets
    ]
    # tighter budget => total area no smaller
    assert all(a1 >= a2 - 1e-12 for a1, a2 in zip(areas, areas[1:])), areas
    # zero budget with positive sensitivities => everything exact
    assert areas[0] == 10.0 * len(sens)
    # huge budget => everything on the cheapest operator
    assert areas[-1] == 2.0 * len(sens)


def test_qos_respects_budget_and_insensitive_layers():
    ops = _operator_set()
    sens = np.array([0.0, 1.0])        # layer 0 is free to downgrade
    plan = select_plan(ops, sens, 0.0, exact_area=10.0)
    assert plan.choices[0].key == "coarse"   # free downgrades always taken
    assert plan.choices[1].key is None       # budget 0 pins sensitive layers
    assert plan.predicted_total <= 0.0 + 1e-12

    plan2 = select_plan(ops, sens, 0.55, exact_area=10.0)
    assert plan2.predicted_total <= 0.55
    assert plan2.choices[1].key == "mid"     # one affordable downgrade


def test_qos_stack_and_sensitivity_probe():
    ops = _operator_set()
    plan = select_plan(ops, np.zeros(3), 0.0, exact_area=10.0)
    stack = stack_luts(plan, ops)
    assert stack.shape == (3, 16, 16) and stack.dtype == np.int32

    probe = ops[-1][1]
    drifts = {0: 0.6, 1: 0.0, 2: 1.2}
    sens = measure_sensitivities(
        lambda luts: drifts[next(i for i, l in enumerate(luts) if l is not None)],
        3, probe,
    )
    np.testing.assert_allclose(sens, [0.6 / probe.mae16, 0.0, 1.2 / probe.mae16])


# ---------------------------------------------------------------------------
# end-to-end: search sink -> store -> frontier -> per-layer matmul routing
# ---------------------------------------------------------------------------
def test_library_end_to_end_routes_matmul(tmp_path, mul2_ops):
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    store = OperatorStore(tmp_path / "lib")
    for et in (1, 2, 4):
        circ, a = mul2_ops[et]
        store.put_circuit(circ, OperatorSignature("mul", 2, "wce", et), area=a)
    fr = ParetoFrontier.from_store(store, "mul", 2)
    assert len(fr) >= 1
    rec = fr.best_under_error(4)
    comp = compile_record(rec)

    rng = np.random.default_rng(0)
    a_ = rng.integers(0, 16, (8, 16), dtype=np.int64)
    b_ = rng.integers(0, 16, (16, 8), dtype=np.int64)
    got = np.asarray(kops.approx_matmul(
        jnp.asarray(a_, jnp.int32), jnp.asarray(b_, jnp.int32),
        jnp.asarray(comp.lut), backend="ref",
    ))
    # reference: out[m, n] = sum_k LUT[a[m,k], b[k,n]]
    want = np.einsum("mkn->mn", comp.lut[a_[:, :, None],
                                         np.broadcast_to(b_[None], (8, 16, 8))])
    np.testing.assert_array_equal(got, want)
