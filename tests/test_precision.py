"""Multi-bit-width pipeline: composition exactness identities, the
two-level 8-bit kernel vs its oracle, width-generic quantization, the
width-compiled frontier, and the metric-aware error pipeline."""

import numpy as np
import pytest

from repro.core.arith import benchmark
from repro.core.circuits import Circuit, Op
from repro.core.miter import ERROR_METRICS, measure_error
from repro.core.synth import area
from repro.library import OperatorSignature, OperatorStore
from repro.library.compile import compile_circuit, compile_record, \
    load_mul_frontier
from repro.library.qos import select_plan, stack_luts, validate_lut_stack
from repro.precision import compose
from repro.precision.widths import (
    NATIVE_BLOCK_BITS,
    exact_table,
    get_width,
    width_from_lut,
    width_from_side,
    width_from_stack,
)


# ---------------------------------------------------------------------------
# handcrafted blocks (deterministic, no search needed)
# ---------------------------------------------------------------------------
def trunc_mul2() -> Circuit:
    """Exact low 2 product bits, upper bits dropped (wce 8)."""
    c = Circuit.empty(4, "trunc_mul2")
    a0, a1, b0, b1 = 0, 1, 2, 3
    p0 = c.add(Op.AND, a0, b0)
    p1 = c.add(Op.XOR, c.add(Op.AND, a1, b0), c.add(Op.AND, a0, b1))
    z = c.const(False)
    for out in (p0, p1, z, z):
        c.mark_output(out)
    return c


def _fill(root, circuits, bits=2) -> OperatorStore:
    store = OperatorStore(root)
    exact_vals = benchmark(f"mul_i{2 * bits}").eval_words().astype(np.int64)
    for circ in circuits:
        wce = int(np.abs(circ.eval_words().astype(np.int64)
                         - exact_vals).max())
        store.put_circuit(circ, OperatorSignature("mul", bits, "wce",
                                                  max(wce, 1)),
                          area=area(circ))
    return store


# ---------------------------------------------------------------------------
# widths registry
# ---------------------------------------------------------------------------
def test_width_registry_facts():
    w4, w8 = get_width(4), get_width(8)
    assert (w4.side, w4.bias, w4.qmax) == (16, 8, 7)
    assert (w8.side, w8.bias, w8.qmax) == (256, 128, 127)
    assert w4.lut_shape == (16, 16) and w8.lut_shape == (256, 256)
    assert w8.stack_shape(3) == (3, 256, 256)
    assert w4.tile_chunks == 1 and w8.tile_chunks == 4
    assert w4.max_k > w8.max_k > 0
    with pytest.raises(KeyError, match="unsupported"):
        get_width(6)


def test_width_inference_from_shapes():
    assert width_from_side(256).bits == 8
    assert width_from_lut(np.zeros((16, 16))).bits == 4
    assert width_from_stack(np.zeros((5, 256, 256))).bits == 8
    with pytest.raises(ValueError, match="power of two"):
        width_from_side(17)
    with pytest.raises(ValueError, match="square"):
        width_from_lut(np.zeros((16, 8)))
    with pytest.raises(ValueError, match="stack"):
        width_from_stack(np.zeros((16, 16)))


# ---------------------------------------------------------------------------
# composition exactness identities (the satellite's b ∈ {1, 2, 4}, plus 3)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block_bits", [1, 2, 3, 4])
@pytest.mark.parametrize("op_kind", ["mul", "adder"])
def test_exact_blocks_compose_to_exact_8bit_tables(op_kind, block_bits):
    got = compose.compose_table(exact_table(op_kind, block_bits), op_kind,
                                block_bits, 8)
    np.testing.assert_array_equal(got, exact_table(op_kind, 8))


def test_tile_roundtrip_and_is_composed(rng):
    tile = rng.integers(0, 256, (16, 16)).astype(np.int64)
    lut8 = compose.tile_to_width(tile)
    np.testing.assert_array_equal(compose.extract_tile(lut8), tile)
    assert compose.is_composed(lut8)
    assert not compose.is_composed(lut8 + np.eye(256, dtype=np.int64))


def test_composed_8bit_error_amplification_is_bounded():
    """A block's wce amplifies through the shift-add by at most the sum of
    the chunk weights (25x to the tile, 289x tile to table)."""
    base = compose.extract_tile(np.zeros((256, 256), dtype=np.int64))
    del base  # (just exercising the zero path above)
    comp = compile_circuit(trunc_mul2(), "mul", 2, target_bits=8)
    block_wce = 8   # trunc_mul2
    assert 0 < comp.wce16 <= block_wce * 25 * 289
    assert comp.target_bits == 8 and comp.lut.shape == (256, 256)
    assert comp.tile is not None and comp.tile.shape == (16, 16)
    # the stored tile really generates the stored table
    np.testing.assert_array_equal(
        compose.tile_to_width(comp.tile.astype(np.int64)), comp.lut)


def test_compose_blocks_counts():
    assert compose.compose_blocks(4, 8) == 4
    assert compose.compose_blocks(2, 8) == 16
    assert compose.compose_blocks(1, 8) == 64
    assert compose.compose_blocks(2, 4) == 4
    assert compose.compose_blocks(4, 4) == 1


def test_composition_guards():
    """A block table whose shape contradicts its claimed width fails
    loudly, unknown op kinds are rejected, and the identity guard runs
    (and caches) for every composition path used."""
    with pytest.raises(AssertionError, match="does not match"):
        compose.compose_table(np.zeros((4, 4)), "mul", 3, 8)
    with pytest.raises(ValueError, match="op_kind"):
        compose.compose_table(np.zeros((4, 4)), "div", 2, 4)
    compose.verify_exactness("mul", 2, 8)    # idempotent, must not raise
    assert issubclass(compose.CompositionError, AssertionError)


# ---------------------------------------------------------------------------
# the 8-bit kernel vs the oracle (bit-exact, incl. K-padding edges)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N", [
    (8, 16, 8),
    (5, 3, 7),          # K far below the block: heavy padding
    (37, 257, 29),      # K one over a block boundary
    (130, 128, 64),     # exact K-block fit
])
def test_w8_pallas_matches_oracle(M, K, N, rng):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels import ref
    from repro.kernels.approx_matmul import approx_matmul_pallas

    tile = rng.integers(0, 256, (16, 16)).astype(np.int64)
    assert tile[0, 0] != 0 or True  # padding correction must survive any T00
    lut8 = compose.tile_to_width(tile).astype(np.int32)
    a = rng.integers(0, 256, (M, K)).astype(np.int32)
    b = rng.integers(0, 256, (K, N)).astype(np.int32)
    want = lut8[a[:, :, None], b[None, :, :]].sum(axis=1)
    got_ref = np.asarray(ref.approx_matmul(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut8)))
    got_tl = np.asarray(ref.approx_matmul_two_level(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(tile.astype(np.int32))))
    got_pal = np.asarray(approx_matmul_pallas(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut8), interpret=True))
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_tl, want)
    np.testing.assert_array_equal(got_pal, want)


def test_pallas_rejects_unknown_lut_side(rng):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.approx_matmul import approx_matmul_pallas

    a = jnp.zeros((4, 4), jnp.int32)
    with pytest.raises(ValueError, match="LUT side"):
        approx_matmul_pallas(a, a, jnp.zeros((32, 32), jnp.int32),
                             interpret=True)


def test_w8_pallas_rejects_inexact_block_k(rng):
    """block_k beyond the f32-exact shift-add bound (255*bk*289 < 2^24)
    must raise instead of silently rounding."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.approx_matmul import approx_matmul_pallas

    lut8 = jnp.asarray(exact_table("mul", 8).astype(np.int32))
    a = jnp.zeros((8, 256), jnp.int32)
    b = jnp.zeros((256, 8), jnp.int32)
    with pytest.raises(ValueError, match="f32-exact"):
        approx_matmul_pallas(a, b, lut8, block_k=256, interpret=True)
    # the largest exact block size still bit-matches
    max_bk = (1 << 24) // (255 * 289)
    aa = rng.integers(0, 256, (8, 300)).astype(np.int32)
    bb = rng.integers(0, 256, (300, 8)).astype(np.int32)
    lut = exact_table("mul", 8).astype(np.int32)
    want = lut[aa[:, :, None], bb[None, :, :]].sum(axis=1)
    got = np.asarray(approx_matmul_pallas(
        jnp.asarray(aa), jnp.asarray(bb), jnp.asarray(lut),
        block_k=max_bk, interpret=True))
    np.testing.assert_array_equal(got, want)


def test_w8_exact_table_reproduces_int_matmul(rng):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels import ops

    lut8 = exact_table("mul", 8).astype(np.int32)
    assert compose.is_composed(lut8)
    a = rng.integers(0, 256, (9, 33)).astype(np.int32)
    b = rng.integers(0, 256, (33, 6)).astype(np.int32)
    out = np.asarray(ops.approx_matmul(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(lut8), backend="ref"))
    np.testing.assert_array_equal(out, a.astype(np.int64) @ b)


# ---------------------------------------------------------------------------
# width-generic quantization + signed decomposition
# ---------------------------------------------------------------------------
def test_quantize_intb_codes_and_scale(rng):
    jnp = pytest.importorskip("jax.numpy")
    from repro.quant import quantize_int4, quantize_intb

    x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    for bits in (4, 8):
        w = get_width(bits)
        q, s = quantize_intb(x, bits, axis=-1)
        qn = np.asarray(q)
        assert qn.min() >= 1 and qn.max() <= w.side - 1  # code 0 unused
        back = (qn - w.bias) * np.asarray(s)
        assert np.abs(back - np.asarray(x)).max() <= np.asarray(s).max()
    q4, s4 = quantize_int4(x)
    q4b, s4b = quantize_intb(x, 4)
    np.testing.assert_array_equal(np.asarray(q4), np.asarray(q4b))
    np.testing.assert_array_equal(np.asarray(s4), np.asarray(s4b))


def test_approx_linear_w8_signed_decomposition(rng):
    """Signed int8 x int8 through the unsigned composed multiplier + exact
    correction equals the plain quantized matmul when the table is exact."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.quant import approx_linear, quantize_intb

    x = rng.standard_normal((5, 32)).astype(np.float32)
    w = rng.standard_normal((32, 7)).astype(np.float32)
    lut8 = jnp.asarray(exact_table("mul", 8).astype(np.int32))
    got = np.asarray(approx_linear(jnp.asarray(x), jnp.asarray(w), lut8,
                                   backend="ref"))
    xq, sx = quantize_intb(jnp.asarray(x), 8, axis=-1)
    wq, sw = quantize_intb(jnp.asarray(w), 8, axis=0)
    want = (((np.asarray(xq) - 128.0) @ (np.asarray(wq) - 128.0))
            * np.asarray(sx) * np.asarray(sw))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# width-compiled frontier -> plan -> stack
# ---------------------------------------------------------------------------
def test_compile_cache_keys_per_target_width(tmp_path):
    store = _fill(tmp_path / "lib", [trunc_mul2()])
    rec = store.query("mul", 2)[0]
    c4 = compile_record(rec)
    c8 = compile_record(rec, target_bits=8)
    assert c4 is not c8
    assert c4.lut.shape == (16, 16) and c8.lut.shape == (256, 256)
    assert compile_record(rec, target_bits=8) is c8   # cache hit per width


def test_load_mul_frontier_target8_scales_areas(tmp_path):
    lib = tmp_path / "lib"
    _fill(lib, [benchmark("mul_i4"), trunc_mul2()], bits=2)
    _fill(lib, [benchmark("mul_i8")], bits=4)

    legacy, legacy_exact, legacy_bits = load_mul_frontier(lib)
    assert legacy_bits == 4            # widest stored block wins
    assert all(c.target_bits == 4 for _, c in legacy)

    compiled, exact_area, bits = load_mul_frontier(lib, target_bits=8)
    assert bits == 8
    assert exact_area == area(benchmark("mul_i16"))
    assert all(c.lut.shape == (256, 256) for _, c in compiled)
    # every frontier record's area is the block area times its block count
    store = OperatorStore(lib)
    orig = {r.key: r for r in store.query("mul")}
    for rec, comp in compiled:
        blocks = compose.compose_blocks(rec.signature.bits, 8)
        assert rec.area == pytest.approx(orig[rec.key].area * blocks)
    # some exact block survives on the frontier, composing to the exact
    # 8-bit table (which block wins is an area contest: 16 exact 2-bit
    # blocks may legitimately undercut 4 exact 4-bit ones)
    exacts = [c for _, c in compiled if c.wce16 == 0]
    assert exacts and np.array_equal(exacts[0].lut, exact_table("mul", 8))


def test_w8_plan_stack_and_validation(tmp_path):
    lib = tmp_path / "lib"
    _fill(lib, [benchmark("mul_i4"), trunc_mul2()], bits=2)
    compiled, exact_area, _ = load_mul_frontier(lib, target_bits=8)
    plan = select_plan(compiled, np.ones(3), budget=1e12,
                       exact_area=exact_area)
    stack = stack_luts(plan, compiled)
    assert stack.shape == (3, 256, 256) and stack.dtype == np.int32
    # a width move is refused with a width-labelled error
    with pytest.raises(ValueError, match="8-bit"):
        validate_lut_stack(stack, np.zeros((3, 16, 16), np.int32))


def test_stack_luts_rejects_mixed_width_frontier(tmp_path):
    store = _fill(tmp_path / "lib", [trunc_mul2()])
    rec = store.query("mul", 2)[0]
    mixed = [(rec, compile_record(rec)),
             (rec, compile_record(rec, target_bits=8))]
    plan = select_plan([(rec, compile_record(rec))], np.ones(2), 1e12,
                       exact_area=10.0)
    with pytest.raises(ValueError, match="single-width"):
        stack_luts(plan, mixed)


def test_select_width_from_model_config():
    from repro.configs import get_config
    from repro.precision.plans import select_width

    cfg = get_config("gemma3-1b", reduced=True)
    assert select_width(cfg).bits == NATIVE_BLOCK_BITS     # no opt-in yet
    assert select_width(cfg, requested=8).bits == 8
    cfg8 = cfg.with_approx_mlp(bits=8)
    assert cfg8.approx_mlp and cfg8.approx_bits == 8
    assert select_width(cfg8).bits == 8
    with pytest.raises(ValueError, match="contradicts"):
        select_width(cfg8, requested=4)


# ---------------------------------------------------------------------------
# richer error metrics: one measurement, three bounds
# ---------------------------------------------------------------------------
def test_measure_error_stats_consistency():
    stats = measure_error(trunc_mul2(), benchmark("mul_i4").eval_words())
    assert set(ERROR_METRICS) == {"wce", "mae", "mse"}
    assert stats.wce == 8
    assert 0 < stats.mae <= stats.wce
    assert stats.mae**2 <= stats.mse <= stats.wce**2
    assert stats.value("mse") == stats.mse
    with pytest.raises(KeyError):
        stats.value("nope")


def test_store_validates_signature_metric(tmp_path):
    store = OperatorStore(tmp_path / "lib")
    circ = trunc_mul2()             # wce 8, mae ~1.3, mse ~10.4
    stats = measure_error(circ, benchmark("mul_i4").eval_words())
    rec = store.put_circuit(circ, OperatorSignature("mul", 2, "mae", 2),
                            area=3.0)
    assert rec.mse == pytest.approx(stats.mse)
    back = store.records(OperatorSignature("mul", 2, "mae", 2))[0]
    assert back.mse == pytest.approx(stats.mse)
    # wce 8 > mae-threshold 2 is fine (mae is bounded), but a tight mae
    # signature must reject it
    with pytest.raises(ValueError, match="mae"):
        store.put_circuit(circ, OperatorSignature("mul", 2, "mae", 1),
                          area=3.0)
    with pytest.raises(ValueError, match="mse"):
        store.put_circuit(circ, OperatorSignature("mul", 2, "mse", 5),
                          area=3.0)


def test_signature_rejects_fractional_threshold():
    """Fractional mae/mse thresholds would not round-trip through the
    signature dirname ('mae0.5' parses as metric 'mae0.') — refuse at
    construction instead of corrupting the store."""
    with pytest.raises(ValueError, match="positive integer"):
        OperatorSignature("mul", 2, "mae", 0.5)
    with pytest.raises(ValueError, match="digits"):
        OperatorSignature("mul", 2, "mae0.", 5)
    sig = OperatorSignature("mul", 2, "mae", 2.0)   # whole floats normalize
    assert sig.threshold == 2 and isinstance(sig.threshold, int)
    assert OperatorSignature.from_dirname(sig.dirname) == sig


def test_smoke_sweep_plans_the_mae_job():
    from repro.fleet.plan import SWEEPS, plan_jobs

    jobs = plan_jobs(SWEEPS["smoke"])
    mae_jobs = [j for j in jobs if j.error_metric == "mae"]
    assert len(mae_jobs) == 1
    j = mae_jobs[0]
    assert (j.benchmark, j.bits, j.engine) == ("mul", 2, "anneal")
    # metric participates in the job identity and the seed derivation
    twin = [x for x in jobs if (x.benchmark, x.bits, x.et, x.engine)
            == (j.benchmark, j.bits, j.et, j.engine)
            and x.error_metric == "wce"]
    if twin:
        assert twin[0].key() != j.key() and twin[0].seed != j.seed


def test_engines_reject_unboundable_metric():
    from repro.core.engine import SearchJob, get_engine

    job = SearchJob("mul", 2, 2, "tensor", error_metric="mse")
    with pytest.raises(ValueError, match="anneal"):
        get_engine("tensor").run(job)


def test_8bit_sweep_preset_plans():
    from repro.fleet.plan import SWEEPS, plan_jobs

    jobs = plan_jobs(SWEEPS["8bit"])
    assert {j.bits for j in jobs} == {2, 4}
    assert {j.benchmark for j in jobs} == {"mul"}
    assert {j.engine for j in jobs} == {"anneal", "tensor", "muscat",
                                        "mecals"}
