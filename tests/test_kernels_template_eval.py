"""Pallas template_eval vs pure-jnp oracle vs numpy ground truth:
shape/dtype sweep in interpret mode (CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.arith import benchmark
from repro.core.circuits import input_truth_tables
from repro.core.miter import values_from_tables
from repro.core.templates import SharedTemplate, TemplateParams
from repro.kernels import ops


@pytest.mark.parametrize("bench,T,P", [
    ("adder_i4", 4, 16),
    ("adder_i6", 8, 64),
    ("mul_i4", 6, 33),     # non-multiple of block to exercise padding
    ("mul_i6", 10, 128),
    ("mul_i8", 12, 16),    # W=8 packed words
])
def test_kernel_matches_oracle_and_numpy(bench, T, P, rng):
    exact = benchmark(bench)
    n, m = exact.n_inputs, exact.n_outputs
    tpl = SharedTemplate(n, m, pit=T)
    lits = rng.integers(0, 3, size=(P, T, n)).astype(np.int32)
    sel = (rng.random((P, m, T)) < 0.4).astype(np.int32)
    in_tt = jnp.asarray(input_truth_tables(n))
    ev = jnp.asarray(exact.eval_words().astype(np.int32))

    w_ref, s_ref = ops.template_eval(
        jnp.asarray(lits), jnp.asarray(sel), in_tt, ev, backend="ref")
    w_pal, s_pal = ops.template_eval(
        jnp.asarray(lits), jnp.asarray(sel), in_tt, ev,
        backend="pallas_interpret")
    assert np.array_equal(np.asarray(w_ref), np.asarray(w_pal))
    assert np.array_equal(np.asarray(s_ref), np.asarray(s_pal))

    ev_np = exact.eval_words().astype(np.int64)
    for p in range(0, P, max(1, P // 7)):
        tp = TemplateParams(lits[p].astype(np.int8), sel[p].astype(bool))
        vals = values_from_tables(tpl.eval_outputs(tp), n).astype(np.int64)
        err = np.abs(vals - ev_np)
        assert int(err.max()) == int(w_ref[p])
        assert int(err.sum()) == int(s_ref[p])


def test_kernel_block_boundary(rng):
    """Population exactly at / above the block size."""
    exact = benchmark("adder_i4")
    in_tt = jnp.asarray(input_truth_tables(4))
    ev = jnp.asarray(exact.eval_words().astype(np.int32))
    for P in (256, 257):
        lits = rng.integers(0, 3, size=(P, 4, 4)).astype(np.int32)
        sel = (rng.random((P, 3, 4)) < 0.5).astype(np.int32)
        w_ref, _ = ops.template_eval(
            jnp.asarray(lits), jnp.asarray(sel), in_tt, ev, backend="ref")
        w_pal, _ = ops.template_eval(
            jnp.asarray(lits), jnp.asarray(sel), in_tt, ev,
            backend="pallas_interpret")
        assert np.array_equal(np.asarray(w_ref), np.asarray(w_pal))
