"""Circuit IR + exact arithmetic generators."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.arith import BENCHMARKS, benchmark, reference_values
from repro.core.circuits import (
    Circuit, Op, check_topological, input_truth_tables, pack_bits, unpack_bits,
)


@pytest.mark.parametrize("name", BENCHMARKS)
def test_exact_circuits_match_arithmetic(name):
    c = benchmark(name)
    assert check_topological(c)
    assert np.array_equal(c.eval_words(), reference_values(name))


@pytest.mark.parametrize("n", [1, 2, 3, 5, 6, 8])
def test_pack_unpack_roundtrip(n, rng):
    bits = rng.random((3, 1 << n)) < 0.5
    assert np.array_equal(unpack_bits(pack_bits(bits), 1 << n), bits)


def test_input_truth_tables_bit_convention():
    tts = input_truth_tables(3)
    bits = unpack_bits(tts, 8)  # (3, 8)
    for i in range(8):
        for j in range(3):
            assert bits[j, i] == bool((i >> j) & 1)


@given(st.integers(min_value=1, max_value=4), st.randoms())
@settings(max_examples=25, deadline=None)
def test_random_circuit_eval_matches_python(bits_n, pyrandom):
    """Property: bit-packed eval == naive per-assignment interpretation."""
    n = 2 * bits_n
    c = Circuit.empty(n, "rand")
    ops = [Op.AND, Op.OR, Op.XOR, Op.NOT, Op.NAND, Op.NOR]
    for _ in range(12):
        op = pyrandom.choice(ops)
        k = 1 if op is Op.NOT else 2
        args = [pyrandom.randrange(len(c.nodes)) for _ in range(k)]
        c.add(op, *args)
    for _ in range(3):
        c.mark_output(pyrandom.randrange(len(c.nodes)))

    words = c.eval_words()

    def naive(assignment):
        vals = {}
        for i, g in enumerate(c.nodes):
            a = [vals[x] for x in g.args]
            if g.op is Op.INPUT:
                vals[i] = bool((assignment >> i) & 1)
            elif g.op is Op.AND:
                vals[i] = all(a)
            elif g.op is Op.OR:
                vals[i] = any(a)
            elif g.op is Op.XOR:
                vals[i] = a[0] ^ a[1]
            elif g.op is Op.NOT:
                vals[i] = not a[0]
            elif g.op is Op.NAND:
                vals[i] = not all(a)
            elif g.op is Op.NOR:
                vals[i] = not any(a)
        return sum(int(vals[o]) << k for k, o in enumerate(c.outputs))

    for assignment in range(1 << n):
        assert naive(assignment) == int(words[assignment])
