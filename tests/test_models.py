"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes and finiteness, plus decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_fn, forward_fn, init_caches, init_model, loss_fn
from repro.models.config import SHAPES
from repro.train import OptimizerConfig, init_opt_state, make_train_step

B, S = 2, 16


def _batch(cfg, key, seq=S):
    batch = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vision.n_patches, cfg.vision.d_vision))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = forward_fn(cfg)(cfg, params, batch)
    prefix = cfg.vision.n_patches if cfg.vision is not None else 0
    assert logits.shape == (B, S + prefix, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mixtral-8x7b", "rwkv6-3b",
                                  "hymba-1.5b", "whisper-tiny"])
def test_one_train_step_reduces_loss_direction(arch):
    """One AdamW step runs, produces finite metrics, and changes params."""
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(1)
    params = init_model(cfg, key)
    opt = init_opt_state(params)
    step = make_train_step(cfg, OptimizerConfig(lr=1e-3), remat="none")
    batch = _batch(cfg, key)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    assert int(new_opt["step"]) == 1
    diff = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree.leaves(diff)) > 0


def test_microbatch_accumulation_matches_full_batch():
    """Grad accumulation over 2 microbatches == single big batch (loss)."""
    cfg = get_config("stablelm-1.6b", reduced=True)
    key = jax.random.PRNGKey(2)
    params = init_model(cfg, key)
    opt = init_opt_state(params)
    batch = _batch(cfg, key)
    s1 = make_train_step(cfg, OptimizerConfig(), microbatches=1, remat="none")
    s2 = make_train_step(cfg, OptimizerConfig(), microbatches=2, remat="none")
    _, _, m1 = jax.jit(s1)(params, opt, batch)
    _, _, m2 = jax.jit(s2)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2


@pytest.mark.parametrize("arch", [
    "qwen3-4b",
    "gemma3-1b",
    pytest.param("deepseek-v2-lite-16b", marks=pytest.mark.xfail(
        strict=False,
        reason="pre-existing bf16 drift in absorbed-MLA decode on jax "
               "0.4.37 (see ROADMAP); revisit with newer jax or looser "
               "decode tolerance")),
    "rwkv6-3b",
    "hymba-1.5b",
    "mixtral-8x7b",
])
def test_decode_matches_forward(arch):
    """Decoding token-by-token reproduces the teacher-forced logits.

    MoE: the equivalence only holds dropless — decode is dropless by
    design; raise the forward capacity factor so no token drops there
    either (capacity dropping is batch-dependent by construction)."""
    import dataclasses

    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    key = jax.random.PRNGKey(3)
    params = init_model(cfg, key)
    seq = 8
    batch = _batch(cfg, key, seq=seq)
    full_logits, _ = forward_fn(cfg)(cfg, params, batch)
    prefix = cfg.vision.n_patches if cfg.vision is not None else 0

    caches = init_caches(cfg, B, seq)
    step = decode_fn(cfg)
    got = []
    for t in range(seq):
        logits, caches = step(cfg, params, caches, batch["tokens"][:, t:t+1],
                              jnp.int32(t))
        got.append(logits)
    got = jnp.stack(got, axis=1)  # (B, seq, V)
    want = full_logits[:, prefix:, :]
    err = float(jnp.abs(got - want).max())
    assert err < 8e-2, err  # bf16 roundoff across different contraction orders
    # random-init logits are near-flat, so argmax ties flip easily; require
    # agreement well above chance (1/vocab) to catch systematic divergence
    agree = float((jnp.argmax(got, -1) == jnp.argmax(want, -1)).mean())
    assert agree >= 0.6, agree


def test_scan_unroll_is_equivalent():
    cfg = get_config("qwen3-4b", reduced=True)
    key = jax.random.PRNGKey(4)
    params = init_model(cfg, key)
    batch = _batch(cfg, key)
    l1 = loss_fn(cfg)(cfg, params, batch)
    l2 = loss_fn(cfg)(cfg, params, batch, scan_unroll=True)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_sliding_window_limits_attention():
    """A token further than the receptive field back cannot influence the
    output.  Uses dropless MoE capacity: capacity-dropping couples tokens
    through router competition (real GShard semantics), which would leak
    influence through a non-attention channel."""
    import dataclasses

    cfg = get_config("mixtral-8x7b", reduced=True)  # window 32, 2 layers
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    key = jax.random.PRNGKey(5)
    params = init_model(cfg, key)
    seq = 80  # receptive field = n_layers * (window-1) = 62 < 79
    tok = jax.random.randint(key, (1, seq), 0, cfg.vocab_size)
    tok2 = tok.at[0, 0].set((tok[0, 0] + 1) % cfg.vocab_size)
    l1, _ = forward_fn(cfg)(cfg, params, {"tokens": tok})
    l2, _ = forward_fn(cfg)(cfg, params, {"tokens": tok2})
    assert float(jnp.abs(l1[0, -1] - l2[0, -1]).max()) < 1e-5


def test_shape_table_is_complete():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["long_500k"].seq_len == 524_288
    assert SHAPES["train_4k"].global_batch == 256
