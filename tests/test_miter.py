"""Miter soundness: Z3 models and exhaustive checks must agree."""

import numpy as np
import pytest
pytest.importorskip("z3")
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.arith import benchmark
from repro.core.miter import MiterZ3, params_sound, worst_case_error
from repro.core.synth import synthesize
from repro.core.templates import NonsharedTemplate, SharedTemplate


@pytest.mark.parametrize("method,kw", [
    ("shared", {"its": 3}),
    ("xpat", {"lpp": 3}),
])
def test_z3_model_is_sound(method, kw):
    exact = benchmark("adder_i4")
    tpl = (
        SharedTemplate(4, 3, pit=4)
        if method == "shared"
        else NonsharedTemplate(4, 3, ppo=3)
    )
    m = MiterZ3(exact, tpl)
    params = m.solve(et=1, **kw)
    assert params is not None
    assert params_sound(tpl, params, exact.eval_words(), et=1)
    circ = tpl.instantiate(params)
    assert worst_case_error(exact, circ) <= 1
    # synthesis must not change behaviour
    assert worst_case_error(exact, synthesize(circ)) <= 1


def test_et_zero_requires_exactness():
    """ET=0 means the approximation IS the exact function.

    A 2-bit adder's minimal multi-output SoP needs ~11 shared products
    (2 for s0, ~6 for the XOR3 middle bit, 3 for carry) — pool 13."""
    exact = benchmark("adder_i4")
    tpl = SharedTemplate(4, 3, pit=13)
    params = MiterZ3(exact, tpl).solve(et=0, its=13, timeout_ms=180_000)
    assert params is not None
    circ = tpl.instantiate(params)
    assert np.array_equal(circ.eval_words(), exact.eval_words())


def test_infeasible_grid_point_is_unsat():
    """One product cannot realize a 2-bit adder within ET=0."""
    exact = benchmark("adder_i4")
    tpl = SharedTemplate(4, 3, pit=1)
    assert MiterZ3(exact, tpl).solve(et=0, its=1) is None


@given(st.integers(0, 2**32 - 1), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_wce_is_symmetric_bound(seed, et):
    """Property: any random sound params (checked exhaustively) instantiate
    to a circuit whose measured WCE is also <= ET (eval/instantiate agree
    through the miter)."""
    rng = np.random.default_rng(seed)
    exact = benchmark("mul_i4")
    tpl = SharedTemplate(4, 4, pit=6)
    ev = exact.eval_words()
    p = tpl.random_params(rng)
    if params_sound(tpl, p, ev, et):
        assert worst_case_error(exact, tpl.instantiate(p)) <= et
    else:
        assert worst_case_error(exact, tpl.instantiate(p)) > et
