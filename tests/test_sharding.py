"""Sharding rules: logical resolution, divisibility fallbacks, smoke-mesh
end-to-end jit under a real (1-device) mesh context."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import parallel
from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_model
from repro.parallel.api import ShardingContext
from repro.parallel.specs import param_specs
from repro.train import OptimizerConfig, init_opt_state, make_train_step


class _FakeMesh:
    """Minimal mesh stand-in for spec resolution tests."""

    def __init__(self, shape: dict):
        self._shape = dict(shape)

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


def test_resolve_divisible():
    ctx = ShardingContext(_FakeMesh({"data": 16, "model": 16}))
    assert ctx.resolve((256, 4096), ("batch", None)) == P("data", None)
    assert ctx.resolve((4096, 8192), ("fsdp", "model")) == P("data", "model")


def test_resolve_fallback_replicates_uneven():
    ctx = ShardingContext(_FakeMesh({"data": 16, "model": 16}))
    # 51865 (whisper vocab) % 16 != 0 -> replicated, not uneven;
    # resolve() returns MESH axis names ('data'), not logical names
    assert ctx.resolve((51865, 384), ("model", "fsdp")) == P(None, "data")
    # batch of 1 (long_500k) cannot shard
    assert ctx.resolve((1, 1), ("batch", None)) == P(None, None)


def test_resolve_multi_axis_batch():
    ctx = ShardingContext(_FakeMesh({"pod": 2, "data": 16, "model": 16}))
    assert ctx.resolve((256, 10), ("batch", None)) == P(("pod", "data"), None)


@pytest.mark.parametrize("arch", ["qwen3-4b", "mixtral-8x7b", "rwkv6-3b"])
def test_param_specs_cover_all_leaves(arch):
    cfg = get_config(arch)  # FULL config shapes, abstract only
    shapes = jax.eval_shape(lambda k: init_model(cfg, k), jax.random.PRNGKey(0))
    ctx = ShardingContext(_FakeMesh({"data": 16, "model": 16}))
    specs = param_specs(ctx, shapes)
    n_leaves = len(jax.tree.leaves(shapes))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_leaves == n_specs
    # big 2D+ weights must actually shard somewhere
    flat_shapes = jax.tree_util.tree_leaves_with_path(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_shapes, flat_specs):
        if leaf.ndim >= 2 and np.prod(leaf.shape) > 1_000_000:
            assert any(s is not None for s in spec), (path, leaf.shape, spec)


def test_train_step_under_mesh_context():
    """End-to-end: logical constraints + jit under a real mesh (1 device)."""
    cfg = get_config("qwen3-4b", reduced=True)
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    opt = init_opt_state(params)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    step = make_train_step(cfg, OptimizerConfig(), remat="none")
    with parallel.activate(mesh), mesh:
        _, _, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
