"""Checkpoint manager: atomicity, digest verification, exact resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.train import (
    DataState, OptimizerConfig, init_opt_state, make_train_step, next_batch,
    checkpoint as ckpt,
)


@pytest.fixture
def setup(tmp_path):
    cfg = get_config("stablelm-1.6b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    opt = init_opt_state(params)
    return cfg, params, opt, str(tmp_path / "ckpt")


def test_save_restore_roundtrip(setup):
    cfg, params, opt, d = setup
    ckpt.save(d, 3, params, opt, data_state={"seed": 7, "step": 3})
    p2, o2, meta, step = ckpt.restore(d, params, opt)
    assert step == 3 and meta["data_state"]["seed"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_overwrite(setup):
    cfg, params, opt, d = setup
    ckpt.save(d, 1, params, opt)
    ckpt.save(d, 5, params, opt)
    assert ckpt.latest_step(d) == 5


def test_digest_detects_corruption(setup):
    cfg, params, opt, d = setup
    path = ckpt.save(d, 1, params, opt)
    data = open(os.path.join(path, "arrays.npz"), "rb").read()
    with open(os.path.join(path, "arrays.npz"), "wb") as f:
        f.write(data[:100] + bytes([data[100] ^ 0xFF]) + data[101:])
    with pytest.raises(Exception):
        ckpt.restore(d, params, opt)


def test_training_resume_is_bit_identical(setup):
    """Kill-and-restart at step 2 reproduces the uninterrupted run exactly
    (fault-tolerance contract: checkpoint + deterministic data pipeline)."""
    cfg, params, opt, d = setup
    step_fn = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3), remat="none"))

    # uninterrupted: 4 steps
    p, o, ds = params, opt, DataState(seed=0, step=0)
    for _ in range(4):
        batch, ds = next_batch(cfg, 2, 16, ds)
        p, o, _ = step_fn(p, o, batch)
    straight = jax.tree.leaves(p)

    # interrupted: 2 steps -> save -> "crash" -> restore -> 2 more
    p, o, ds = params, opt, DataState(seed=0, step=0)
    for _ in range(2):
        batch, ds = next_batch(cfg, 2, 16, ds)
        p, o, _ = step_fn(p, o, batch)
    ckpt.save(d, 2, p, o, data_state=ds.as_dict())

    p2, o2, meta, _ = ckpt.restore(d, p, o)
    ds2 = DataState.from_dict(meta["data_state"])
    for _ in range(2):
        batch, ds2 = next_batch(cfg, 2, 16, ds2)
        p2, o2, _ = step_fn(p2, o2, batch)

    for a, b in zip(straight, jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "resume diverged"
