"""Cost-accounting plane, live scrape endpoint, and Chrome-trace export.

Unit coverage for MAC derivation, plan pricing, the ledger-joining cost
report and its hard reconciliation invariant, the composed-area bracket,
the ``costs``/``export`` CLI subcommands (plus the uniform no-trace
exit-2 contract), the ``MetricsServer`` endpoints, and the Perfetto
exporter — then the multi-replica traced-serve e2e: a two-replica router
whose merged ledger audits clean and whose per-replica attributions sum
to the fleet total.
"""

import json
import types
import urllib.error
import urllib.request

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs import get_config  # noqa: E402
from repro.core.arith import benchmark  # noqa: E402
from repro.library.compile import load_mul_frontier  # noqa: E402
from repro.models import init_model  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs import provenance as obs_prov  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.obs.__main__ import main as obs_main  # noqa: E402
from repro.obs.costs import (cost_report, mlp_macs_per_layer,  # noqa: E402
                             plan_cost_row, render_report)
from repro.obs.httpd import MetricsServer  # noqa: E402
from repro.obs.metrics import MetricRegistry  # noqa: E402
from repro.obs.perfetto import chrome_trace  # noqa: E402
from repro.obs.provenance import (ProvenanceLedger, audit,  # noqa: E402
                                  read_ledger)
from repro.precision.compose import (compose_blocks,  # noqa: E402
                                     compose_glue_bits)
from repro.serving import (ContinuousServingEngine, PlanLadder,  # noqa: E402
                           Replica, ReplicaRouter, Telemetry, make_profile)

from test_serving import fill_library, trunc_mul2, zero_mul2  # noqa: E402


@pytest.fixture(autouse=True)
def _isolate_obs_globals():
    obs_trace.reset()
    prev = obs_metrics.set_registry(MetricRegistry())
    obs_prov._ledgers.clear()
    yield
    obs_trace.reset()
    obs_metrics.set_registry(prev)
    obs_prov._ledgers.clear()


# ---------------------------------------------------------------------------
# MAC derivation per model family
# ---------------------------------------------------------------------------
def test_mlp_macs_per_layer_families():
    dense = get_config("gemma3-1b", reduced=True)
    m = mlp_macs_per_layer(dense)
    assert len(m) == dense.n_layers
    assert m[0] == 3 * dense.d_model * dense.d_ff        # gated: w1,w3,w2

    enc = get_config("whisper-tiny", reduced=True)
    assert mlp_macs_per_layer(enc)[0] == 2 * enc.d_model * enc.d_ff

    # MoE: only the always-on shared experts route through the LUT path;
    # the top-k dispatch is exact, so n_shared=0 earns an honest zero
    ds = get_config("deepseek-v2-lite-16b", reduced=True)
    assert mlp_macs_per_layer(ds)[0] \
        == ds.moe.n_shared * 3 * ds.d_model * ds.moe.d_ff_expert
    mx = get_config("mixtral-8x7b", reduced=True)
    assert mx.moe.n_shared == 0 and mlp_macs_per_layer(mx)[0] == 0

    with pytest.raises(ValueError, match="RWKV"):
        mlp_macs_per_layer(get_config("rwkv6-3b", reduced=True))


def test_plan_cost_row_prices_the_bracket():
    choices = [types.SimpleNamespace(key=None, area=10.0),
               types.SimpleNamespace(key="k1", area=2.0)]
    plan = types.SimpleNamespace(plan_id="p", choices=choices,
                                 exact_area=10.0)
    macs = [100, 100]
    row = plan_cost_row(plan, macs, layer_areas=[(10.0, 10.0), (2.0, 4.0)])
    assert row["macs"] == 200 and row["approx_macs"] == 100
    # guaranteed end prices against the glue-inclusive upper-bound area
    assert row["saved_lo"] == pytest.approx(100 * (10.0 - 4.0))
    assert row["saved_hi"] == pytest.approx(100 * (10.0 - 2.0))
    assert row["layers"] == {"1": pytest.approx(600.0)}

    # exact serve: full MAC denominator, zero dividend
    exact = plan_cost_row(None, macs)
    assert exact["macs"] == 200 and exact["approx_macs"] == 0
    assert exact["saved_lo"] == exact["saved_hi"] == 0.0


# ---------------------------------------------------------------------------
# composed-area honesty: the glue-adder bracket
# ---------------------------------------------------------------------------
def test_compose_glue_bits_counts_partial_product_adders():
    assert compose_glue_bits(4, 4) == 0          # native: nothing composed
    # 2-bit blocks -> 4-bit: 4 partial products, 3 adds at full width
    assert compose_glue_bits(2, 4) == 3 * 2 * 4
    # beyond the native block: per-tile glue plus the tile-combine stage
    n_tiles = (8 // 4) ** 2
    assert compose_glue_bits(4, 8) == (n_tiles - 1) * 2 * 8
    assert compose_glue_bits(2, 8) \
        == n_tiles * compose_glue_bits(2, 4) + (n_tiles - 1) * 2 * 8
    assert compose_blocks(4, 8) == n_tiles       # sanity: area scaling


def test_compiled_frontier_carries_area_bracket(tmp_path):
    store = fill_library(tmp_path / "lib",
                         [benchmark("mul_i4"), trunc_mul2(), zero_mul2()])
    assert store is not None
    native, _, _ = load_mul_frontier(tmp_path / "lib")
    for rec, comp in native:
        # native tables: nothing composed, the bracket collapses
        assert comp.area_lo == comp.area_hi == pytest.approx(rec.area)

    composed, _, _ = load_mul_frontier(tmp_path / "lib", target_bits=8)
    assert composed, "no composed W8 frontier"
    for rec, comp in composed:
        assert comp.area_lo == pytest.approx(rec.area), \
            "record area must stay the documented lower bound"
        # the ceiling prices the glue adders; for a degenerate near-zero
        # LUT it may exceed the monolithic exact area — the bracket stays
        # honest rather than clamped
        assert comp.area_hi > comp.area_lo, \
            "composed operator must price its glue adders somewhere"


# ---------------------------------------------------------------------------
# offline cost report over synthetic ledgers
# ---------------------------------------------------------------------------
def _clock():
    t = [0.0]

    def tick():
        t[0] += 1.0
        return t[0]

    return tick


def _write_ledger(root, *, gap=False, unpriced=False, tag="w0"):
    led = ProvenanceLedger(root, tag=tag, clock=_clock())
    led.note_model(name="toy", macs=[10, 10])
    if unpriced:
        led.note_plan("p0", ["exact", "k1"])
    else:
        led.note_plan("p0", ["exact", "k1"], areas=[5.0, 2.0],
                      areas_hi=[5.0, 3.0], exact_area=5.0)
    led.record_range(rid=1, cls="gold", t0=0, t1=4, plan="exact",
                     level=None, drift=[])
    led.record_done(rid=1, cls="gold", gen_len=4, steps=5, preempts=0)
    t1 = 3 if gap else 4
    led.record_range(rid=2, cls="batch", t0=0, t1=t1, plan="p0", level=1,
                     drift=[0.01])
    led.record_done(rid=2, cls="batch", gen_len=4, steps=5, preempts=0)
    led.close()
    return read_ledger(root)


def test_cost_report_reconciles_and_attributes(tmp_path):
    rep = cost_report(_write_ledger(tmp_path))
    assert rep["reconciled"] is True and rep["mac_gap"] == 0
    assert rep["model"]["macs_per_token"] == 20
    # rid 1 decoded exact: full MACs, zero dividend; rid 2 on p0: layer 1
    # approximate for all 4 tokens
    assert rep["requests"][1]["approx_macs"] == 0
    r2 = rep["requests"][2]
    assert r2["mlp_macs"] == 80 and r2["approx_macs"] == 40
    assert r2["area_mac_saved"] == [pytest.approx(40 * (5 - 3)),
                                    pytest.approx(40 * (5 - 2))]
    assert r2["reconciled"] and r2["expected_macs"] == 80
    assert rep["totals"]["mlp_macs"] == 160
    assert rep["totals"]["approx_frac"] == pytest.approx(40 / 160)
    assert rep["classes"]["gold"]["area_mac_saved"] == [0.0, 0.0]
    assert rep["classes"]["batch"]["area_mac_saved"][0] > 0
    # layer attribution: only layer 1 earned anything
    assert set(rep["layers"]) == {"1"}
    assert rep["layers"]["1"]["area_mac_saved"][0] == pytest.approx(80.0)
    assert not rep["problems"]
    assert "reconciled=true" in render_report(rep)


def test_cost_report_gap_is_an_audit_failure(tmp_path):
    rep = cost_report(_write_ledger(tmp_path, gap=True))
    assert rep["reconciled"] is False
    assert rep["mac_gap"] == 20, "one missing token x 20 MACs/token"
    assert any("gap" in p for p in rep["problems"])
    assert rep["requests"][2]["reconciled"] is False


def test_cost_report_unpriced_plan_fails_reconciliation(tmp_path):
    rep = cost_report(_write_ledger(tmp_path, unpriced=True))
    assert rep["reconciled"] is False
    assert any("no area record" in p for p in rep["problems"])
    # MAC attribution still tiles — only the pricing is missing
    assert rep["requests"][2]["approx_macs"] == 40
    assert rep["requests"][2]["area_mac_saved"] == [0.0, 0.0]


def test_cost_report_without_model_record(tmp_path):
    led = ProvenanceLedger(tmp_path, tag="w0", clock=_clock())
    led.record_range(rid=1, cls="std", t0=0, t1=2, plan="exact",
                     level=None, drift=[])
    led.record_done(rid=1, cls="std", gen_len=2, steps=3, preempts=0)
    led.close()
    rep = cost_report(read_ledger(tmp_path))
    assert rep["reconciled"] is False
    assert any("no model record" in p for p in rep["problems"])


def test_audit_same_rid_on_two_replicas_disambiguates(tmp_path):
    """Satellite: two replicas sharing one trace dir may reuse rids —
    the audit groups by (rid, replica) so their ranges never blend into
    a false overlap, and report keys disambiguate only on collision."""
    led = ProvenanceLedger(tmp_path, tag="w0", clock=_clock())
    led.note_model(name="toy", macs=[10])
    for rep_name in ("a", "b"):
        led.record_range(rid=7, cls="std", t0=0, t1=4, plan="exact",
                         level=None, drift=[], replica=rep_name)
        led.record_done(rid=7, cls="std", gen_len=4, steps=5, preempts=0,
                        replica=rep_name)
    led.record_range(rid=8, cls="std", t0=0, t1=4, plan="exact",
                     level=None, drift=[], replica="a")
    led.record_done(rid=8, cls="std", gen_len=4, steps=5, preempts=0,
                    replica="a")
    led.close()

    rep = audit(read_ledger(tmp_path))
    assert rep["n_failed"] == 0, "same-rid replicas blended into overlap"
    assert set(rep["requests"]) == {"7@a", "7@b", 8}
    assert rep["requests"]["7@a"]["replica"] == "a"
    assert rep["requests"][8]["replica"] == "a", \
        "unique rids keep plain keys even with a replica stamp"

    costs = cost_report(read_ledger(tmp_path))
    assert costs["reconciled"] is True
    assert costs["replicas"]["a"]["tokens"] == 8
    assert costs["replicas"]["b"]["tokens"] == 4


# ---------------------------------------------------------------------------
# CLI: costs + export + the uniform no-trace exit-2 contract
# ---------------------------------------------------------------------------
def test_cli_costs_report_and_gate(tmp_path, capsys):
    _write_ledger(tmp_path)
    assert obs_main(["costs", "--trace", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "reconciled=true" in out and "area·MAC saved" in out

    assert obs_main(["costs", "--trace", str(tmp_path), "--json",
                     "--require-reconciled"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["reconciled"] is True
    assert doc["classes"]["batch"]["approx_macs"] == 40

    bad = tmp_path / "bad"
    bad.mkdir()
    _write_ledger(bad, gap=True)
    assert obs_main(["costs", "--trace", str(bad)]) == 0, \
        "without the gate flag a gap reports, it does not fail"
    capsys.readouterr()
    assert obs_main(["costs", "--trace", str(bad),
                     "--require-reconciled"]) == 1
    assert "did not reconcile" in capsys.readouterr().err


def test_cli_no_trace_exits_2_uniformly(tmp_path, capsys):
    """Satellite: every trace-reading subcommand answers a missing or
    empty --trace dir with one line on stderr and exit 2."""
    missing = tmp_path / "nope"
    empty = tmp_path / "empty"
    empty.mkdir()
    (empty / "notes.txt").write_text("not a trace artifact")
    for cmd in ("summary", "slowest", "requests", "provenance", "costs",
                "export"):
        for d in (missing, empty):
            assert obs_main([cmd, "--trace", str(d)]) == 2, (cmd, d)
            err = capsys.readouterr().err
            assert f"no trace at {d}" in err, (cmd, d, err)


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------
def _span(sid, name, t0, dur, parent=None, **attrs):
    return {"id": sid, "name": name, "t0": t0, "dur_s": dur,
            "parent": parent, "attrs": attrs}


def test_chrome_trace_preserves_parentage_and_packs_lanes():
    spans = [
        _span("a", "serve.batch", 100.0, 0.010, batch=0),
        _span("b", "serve.decode", 100.001, 0.002, parent="a"),
        _span("c", "serve.shadow", 100.0015, 0.0005, parent="b"),
        _span("d", "fleet.job", 100.005, 0.010),          # overlaps a
        _span("e", "serve.batch", 100.020, 0.005),        # after a: lane reuse
    ]
    doc = chrome_trace(spans)
    assert doc["displayTimeUnit"] == "ms"
    evs = {e["args"]["span_id"]: e for e in doc["traceEvents"]
           if e["ph"] == "X"}
    assert set(evs) == {"a", "b", "c", "d", "e"}
    # µs timestamps relative to the trace start
    assert evs["a"]["ts"] == 0.0 and evs["a"]["dur"] == pytest.approx(1e4)
    assert evs["b"]["ts"] == pytest.approx(1e3)
    # children ride their root's track and nest inside the parent window
    for child, parent in (("b", "a"), ("c", "b")):
        assert evs[child]["tid"] == evs[parent]["tid"]
        assert evs[child]["args"]["parent_id"] == parent
        assert evs[child]["ts"] >= evs[parent]["ts"]
        assert evs[child]["ts"] + evs[child]["dur"] \
            <= evs[parent]["ts"] + evs[parent]["dur"] + 1e-6
    # overlapping roots on separate tracks; a later root reuses a track
    assert evs["d"]["tid"] != evs["a"]["tid"]
    assert evs["e"]["tid"] == evs["a"]["tid"]
    assert evs["a"]["args"]["batch"] == 0, "span attrs must survive"
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in meta)


def test_chrome_trace_orphan_parent_becomes_root():
    doc = chrome_trace([_span("x", "serve.decode", 1.0, 0.5,
                              parent="torn-away")])
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(evs) == 1 and evs[0]["tid"] == 1


def test_cli_export_writes_loadable_chrome_trace(tmp_path, capsys):
    from repro.obs.trace import Tracer

    tr = Tracer(tmp_path, clock=_clock(), process_tag="w0")
    with tr.span("serve.batch", batch=0):
        with tr.span("serve.decode"):
            pass
    tr.close()

    out = tmp_path / "out" / "trace.json"
    assert obs_main(["export", "--trace", str(tmp_path), "--format",
                     "chrome", "--out", str(out)]) == 0
    assert "wrote" in capsys.readouterr().out
    doc = json.loads(out.read_text())
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in evs} == {"serve.batch", "serve.decode"}
    child = next(e for e in evs if e["name"] == "serve.decode")
    parent = next(e for e in evs if e["name"] == "serve.batch")
    assert child["args"]["parent_id"] == parent["args"]["span_id"]
    assert child["tid"] == parent["tid"]


# ---------------------------------------------------------------------------
# live scrape endpoint
# ---------------------------------------------------------------------------
def _get(port, path):
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}{path}", timeout=5) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def test_metrics_server_endpoints(tmp_path):
    _write_ledger(tmp_path)
    tel = Telemetry()
    tel.record_costs("gold", 4, {"macs": 20, "approx_macs": 10,
                                 "saved_lo": 6.0, "saved_hi": 8.0,
                                 "layers": {"1": 6.0}})
    state = {"state": "ok"}
    srv = MetricsServer(port=0, snapshot_providers=[tel.registry.snapshot],
                        health_provider=lambda: dict(state),
                        trace_dir=str(tmp_path))
    port = srv.start()
    try:
        status, body = _get(port, "/metrics")
        assert status == 200
        assert 'approx_macs_total{class="gold"} 40' in body
        assert 'area_mac_saved_total{class="gold",layer="_all"} 24' in body
        assert 'area_mac_saved_total{class="gold",layer="1"} 24' in body

        # live: a later increment shows up on the next scrape
        tel.record_costs("gold", 1, {"macs": 20, "approx_macs": 10,
                                     "saved_lo": 6.0, "saved_hi": 8.0,
                                     "layers": {}})
        assert 'approx_macs_total{class="gold"} 50' in _get(
            port, "/metrics")[1]

        for st, code in (("ok", 200), ("warn", 429), ("page", 503)):
            state["state"] = st
            status, body = _get(port, "/healthz")
            assert status == code and json.loads(body)["state"] == st

        status, body = _get(port, "/costs.json")
        assert status == 200
        doc = json.loads(body)
        assert doc["reconciled"] is True and doc["totals"]["tokens"] == 8

        assert _get(port, "/nope")[0] == 404
    finally:
        srv.stop()


def test_metrics_server_merges_trace_snapshots_and_survives_no_ledger(
        tmp_path):
    from repro.obs.export import dump_metrics

    other = MetricRegistry()
    other.counter("fleet_jobs").inc(3)
    dump_metrics(tmp_path, other, tag="fleet")

    srv = MetricsServer(port=0, trace_dir=str(tmp_path))
    port = srv.start()
    try:
        assert "fleet_jobs_total 3" in _get(port, "/metrics")[1]
        assert _get(port, "/healthz")[0] == 200, "no health plane -> ok"
        assert _get(port, "/costs.json")[0] == 404, "no ledger -> 404"
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# e2e: two-replica router serve, merged ledger, summed attribution
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def approx_setup(tmp_path_factory):
    root = tmp_path_factory.mktemp("costslib")
    fill_library(root / "lib", [benchmark("mul_i4"), trunc_mul2(),
                                zero_mul2()])
    compiled, exact_area, _ = load_mul_frontier(root / "lib")
    cfg = get_config("gemma3-1b", reduced=True).with_approx_mlp()
    params = init_model(cfg, jax.random.PRNGKey(0))
    ladder = PlanLadder.build(compiled, cfg.n_layers, exact_area=exact_area,
                              levels=4)
    return compiled, exact_area, cfg, params, ladder


def test_router_cost_attribution_e2e(tmp_path, approx_setup):
    """Tentpole e2e: a traced two-replica serve (gold homed on an exact
    replica, batch on a deep one) produces a merged ledger that audits
    clean and reconciles, with per-replica attribution summing to the
    router's fleet total and gold's dividend strictly under batch's."""
    compiled, exact_area, cfg, params, ladder = approx_setup

    def mk(level):
        return ContinuousServingEngine(
            cfg, params, max_slots=2, prompt_len=8, gen_len=8, page_size=4,
            plan=ladder.plan(level), compiled=compiled,
            exact_area=exact_area)

    trace_dir = tmp_path / "trace"
    obs_trace.configure(trace_dir, process_tag="serve")
    try:
        router = ReplicaRouter([
            Replica("gold-exact", mk(0), classes=("gold",)),
            Replica("batch-deep", mk(len(ladder) - 1), classes=("batch",)),
        ])
        prof = make_profile("ramp", ticks=4, per_tick=4, prompt_len=8,
                            gen_len=8,
                            class_mix=(("gold", 0.5), ("batch", 0.5)),
                            prompt_dist=("uniform", 3, 8))
        out = router.serve(prof, seed=0)
    finally:
        obs_trace.reset()
        obs_prov._ledgers.clear()

    assert out["requests"] == prof.total_requests
    rep = cost_report(read_ledger(trace_dir))
    assert rep["reconciled"] is True, rep["problems"]
    assert rep["n_done"] == rep["n_complete"] == prof.total_requests
    assert rep["mac_gap"] == 0
    assert set(rep["replicas"]) == {"gold-exact", "batch-deep"}
    # every request row names the replica that served it
    assert all(r.get("replica") in ("gold-exact", "batch-deep")
               for r in rep["requests"].values())

    # per-replica attribution sums exactly to the fleet totals
    for k in ("tokens", "mlp_macs", "approx_macs"):
        assert sum(r[k] for r in rep["replicas"].values()) \
            == rep["totals"][k], k
    for end in (0, 1):
        assert sum(r["area_mac_saved"][end]
                   for r in rep["replicas"].values()) \
            == pytest.approx(rep["totals"]["area_mac_saved"][end], rel=1e-6)

    # the dividend went where the routing sent the cheap traffic: under a
    # router each replica is homed to classes, so per-replica attribution
    # IS the class attribution (the engines themselves queue as "std")
    gold = rep["replicas"]["gold-exact"]["area_mac_saved"]
    batch = rep["replicas"]["batch-deep"]["area_mac_saved"]
    assert gold == [0.0, 0.0], "exact-homed gold must earn no dividend"
    assert batch[0] > 0 and batch[1] >= batch[0]
    assert rep["replicas"]["gold-exact"]["approx_macs"] == 0

    # the live telemetry rollup (router summary) agrees with the ledger
    assert out["costs"]["mlp_macs"] == rep["totals"]["mlp_macs"]
    assert out["costs"]["approx_macs"] == rep["totals"]["approx_macs"]
    assert out["costs"]["area_mac_saved"][0] == pytest.approx(
        rep["totals"]["area_mac_saved"][0], rel=1e-3)

    # and the CLI gate passes against the real artifacts
    assert obs_main(["costs", "--trace", str(trace_dir),
                     "--require-reconciled"]) == 0
    assert obs_main(["provenance", "--trace", str(trace_dir)]) == 0
