"""Pallas flash attention vs einsum oracle: shapes / dtypes / masks sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@pytest.mark.parametrize("B,H,Hkv,Lq,Lk,D", [
    (1, 2, 2, 128, 128, 64),     # MHA square
    (2, 4, 2, 256, 256, 64),     # GQA 2:1
    (1, 8, 1, 128, 128, 128),    # MQA
    (1, 2, 2, 128, 384, 64),     # kv prefix (prefill continuation)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_causal_matches_oracle(B, H, Hkv, Lq, Lk, D, dtype, rng):
    q = _rand(rng, (B, H, Lq, D), dtype)
    k = _rand(rng, (B, Hkv, Lk, D), dtype)
    v = _rand(rng, (B, Hkv, Lk, D), dtype)
    o_ref = ref.flash_attention(q, k, v, causal=True)
    o_pal = ops.flash_attention(q, k, v, causal=True, backend="pallas_interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.abs(o_ref.astype(jnp.float32) - o_pal.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("window", [64, 128, 200])
def test_sliding_window(window, rng):
    q = _rand(rng, (1, 2, 256, 64), jnp.float32)
    k = _rand(rng, (1, 2, 256, 64), jnp.float32)
    v = _rand(rng, (1, 2, 256, 64), jnp.float32)
    o_ref = ref.flash_attention(q, k, v, causal=True, window=window)
    o_pal = ops.flash_attention(q, k, v, causal=True, window=window,
                                backend="pallas_interpret")
    assert float(jnp.abs(o_ref - o_pal).max()) < 2e-5


def test_noncausal(rng):
    q = _rand(rng, (1, 2, 128, 64), jnp.float32)
    k = _rand(rng, (1, 2, 128, 64), jnp.float32)
    v = _rand(rng, (1, 2, 128, 64), jnp.float32)
    o_ref = ref.flash_attention(q, k, v, causal=False)
    o_pal = ops.flash_attention(q, k, v, causal=False, backend="pallas_interpret")
    assert float(jnp.abs(o_ref - o_pal).max()) < 2e-5


def test_oracle_matches_naive_softmax(rng):
    """The oracle itself against an explicit softmax (no streaming)."""
    q = _rand(rng, (1, 1, 64, 32), jnp.float32)
    k = _rand(rng, (1, 1, 64, 32), jnp.float32)
    v = _rand(rng, (1, 1, 64, 32), jnp.float32)
    o = ref.flash_attention(q, k, v, causal=True)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(32)
    mask = jnp.tril(jnp.ones((64, 64), bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, -1), v)
    assert float(jnp.abs(o - want).max()) < 1e-5
