"""Continuous batching: paged KV allocator, slot pool, weighted-fair
admission, SLO preemption, and the multi-replica router.

The contract under test everywhere: requests join/leave/preempt/resume
per decode step while the jitted step traces exactly once, and the page
allocator's conservation invariants hold at every boundary.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.arith import benchmark  # noqa: E402
from repro.library.compile import load_mul_frontier  # noqa: E402
from repro.models import (decode_fn, decode_paged_fn, init_caches,  # noqa: E402
                          init_model, init_paged_caches)
from repro.sensitivity.classes import ClassBook, ClassScheduler  # noqa: E402
from repro.serving import (ContinuousServingEngine, ControllerConfig,  # noqa: E402
                           OutOfPages, PageAllocator, PlanLadder,
                           QoSController, Replica, ReplicaRouter, SeqState,
                           SlotPool, Telemetry, WeightedFairQueues,
                           effective_load_ms, make_profile,
                           parse_prompt_dist)
from repro.serving.kvcache import SCRATCH_PAGE  # noqa: E402
from repro.serving.loadgen import synth_requests  # noqa: E402

from test_serving import fill_library, trunc_mul2, zero_mul2  # noqa: E402


# --------------------------------------------------------------------------
# page allocator
# --------------------------------------------------------------------------

def test_allocator_conservation_and_reuse():
    a = PageAllocator(n_pages=6, page_size=4)
    t1 = a.alloc(1, 10)          # 3 pages
    t2 = a.alloc(2, 5)           # 2 pages
    a.check_invariants()
    assert len(t1) == 3 and len(t2) == 2
    assert a.used_pages == 5 and a.free_pages == 1
    assert SCRATCH_PAGE not in t1 + t2
    assert a.free(1) == 3
    a.check_invariants()
    # LIFO reuse: the same admission sequence replays the same tables
    t3 = a.alloc(3, 10)
    assert t3 == t1
    a.check_invariants()


def test_allocator_double_alloc_and_foreign_free():
    a = PageAllocator(n_pages=4, page_size=4)
    a.alloc(7, 4)
    with pytest.raises(ValueError, match="already holds"):
        a.alloc(7, 4)
    with pytest.raises(ValueError, match="holds no pages"):
        a.free(8)
    a.check_invariants()


def test_allocator_out_of_pages_is_clean():
    a = PageAllocator(n_pages=2, page_size=4)
    a.alloc(1, 8)
    assert not a.can_alloc(1)
    with pytest.raises(OutOfPages):
        a.alloc(2, 1)
    # the failed alloc must not leak or corrupt anything
    a.check_invariants()
    assert a.free_pages == 0 and not a.holds(2)
    a.free(1)
    assert a.can_alloc(8)


def test_padded_table_scratch_fill():
    a = PageAllocator(n_pages=4, page_size=4)
    a.alloc(1, 6)   # 2 pages
    row = a.padded_table(1, 4)
    assert row.dtype == np.int32 and row.shape == (4,)
    assert tuple(row[:2]) == a.table(1)
    assert all(p == SCRATCH_PAGE for p in row[2:])
    empty = a.padded_table(None, 4)
    assert all(p == SCRATCH_PAGE for p in empty)


# --------------------------------------------------------------------------
# SLO class spec / drain weights
# --------------------------------------------------------------------------

def test_class_spec_slo_parse():
    book = ClassBook.parse("gold:0.02@8ms, std:0.05, batch:0.2@1500ms")
    assert book.get("gold").slo_ms == 8.0
    assert book.get("std").slo_ms is None
    assert book.get("batch").slo_ms == 1500.0
    assert [c.name for c in book] == ["gold", "std", "batch"]


def test_class_spec_slo_rejects_nonpositive():
    with pytest.raises(ValueError):
        ClassBook.parse("gold:0.02@0ms")
    with pytest.raises(ValueError):
        ClassBook.parse("gold:0.02@-5ms")


def test_drain_weights_priority_order():
    book = ClassBook.parse("gold:0.02,std:0.05,batch:0.2")
    w = book.drain_weights()
    assert w == {"gold": 4, "std": 2, "batch": 1}


# --------------------------------------------------------------------------
# prompt-length distributions
# --------------------------------------------------------------------------

def test_prompt_dist_parse():
    assert parse_prompt_dist("uniform:4-16", 16) == ("uniform", 4, 16)
    assert parse_prompt_dist("bimodal:2-8", 8) == ("bimodal", 2, 8)
    for bad in ("gauss:4-16", "uniform:0-16", "uniform:9-8",
                "uniform:4-17", "uniform"):
        with pytest.raises(ValueError):
            parse_prompt_dist(bad, 16)


def test_prompt_dist_deterministic_and_bounded():
    prof = make_profile("steady", ticks=3, per_tick=5, prompt_len=16,
                        gen_len=4, prompt_dist=("bimodal", 3, 16))
    a = synth_requests(prof, 128, seed=9)
    b = synth_requests(prof, 128, seed=9)
    lens = []
    for ta, tb in zip(a, b):
        for ra, rb in zip(ta, tb):
            assert np.array_equal(ra.tokens, rb.tokens)
            assert 3 <= len(ra.tokens) <= 16
            lens.append(len(ra.tokens))
    assert len(set(lens)) > 1, "bimodal draw produced uniform lengths"


def test_prompt_dist_tokens_are_fixed_length_prefix():
    """Length variation must not reshuffle content: each request's tokens
    are a prefix of the same request's fixed-length draw."""
    kw = dict(ticks=2, per_tick=4, prompt_len=12, gen_len=4)
    fixed = synth_requests(make_profile("steady", **kw), 128, seed=3)
    mixed = synth_requests(
        make_profile("steady", prompt_dist=("uniform", 2, 12), **kw),
        128, seed=3)
    for tf, tm in zip(fixed, mixed):
        for rf, rm in zip(tf, tm):
            assert np.array_equal(rm.tokens, rf.tokens[: len(rm.tokens)])


# --------------------------------------------------------------------------
# slot pool / weighted-fair queues / controller signal
# --------------------------------------------------------------------------

def _seq(rid, cls="std", prompt_len=4, gen_len=4):
    return SeqState(rid=rid, cls=cls,
                    prompt=np.arange(prompt_len, dtype=np.int32),
                    gen_len=gen_len, submitted_t=0.0)


def test_seqstate_decode_math():
    s = _seq(0, prompt_len=3, gen_len=2)
    outs = []
    fed = []
    while not s.done:
        fed.append(s.next_token())
        outs.append(s.advance(100 + s.pos))
    # prompt positions 0..1 are prefill; the step fed position 2 (the
    # last prompt token) produces the first generated token, so the whole
    # request takes prompt + gen - 1 = 4 steps
    assert outs == [(False, False), (False, False), (True, True),
                    (True, False)]
    assert fed == [0, 1, 2, 102]   # last fed token is generated[0]
    assert len(s.generated) == 2
    assert s.n_tokens == 5


def test_pick_victim_worst_class_then_youngest():
    pool = SlotPool(4)
    prio = {"gold": 0, "std": 1, "batch": 2}
    pool.place(0, _seq(11, "batch"))
    pool.place(1, _seq(5, "std"))
    pool.place(2, _seq(12, "batch"))
    pool.place(3, _seq(2, "gold"))
    # gold arrival (prio 0): worst tier wins, youngest rid breaks the tie
    assert pool.pick_victim(lambda c: prio[c], below=0) == 2
    pool.evict(2)
    assert pool.pick_victim(lambda c: prio[c], below=0) == 0
    pool.evict(0)
    assert pool.pick_victim(lambda c: prio[c], below=0) == 1
    # nothing strictly below std remains for a std arrival
    pool.evict(1)
    assert pool.pick_victim(lambda c: prio[c], below=1) is None


def test_weighted_fair_shares():
    q = WeightedFairQueues(("gold", "batch"), {"gold": 2, "batch": 1})
    for i in range(30):
        q.push("gold", f"g{i}")
        q.push("batch", f"b{i}")
    picks = [q.pick()[0] for _ in range(30)]
    assert picks.count("gold") == 20 and picks.count("batch") == 10
    # deterministic schedule: replay is bit-identical
    q2 = WeightedFairQueues(("gold", "batch"), {"gold": 2, "batch": 1})
    for i in range(30):
        q2.push("gold", f"g{i}")
        q2.push("batch", f"b{i}")
    assert [q2.pick()[0] for _ in range(30)] == picks


def test_weighted_fair_admissible_filter_and_resume_front():
    q = WeightedFairQueues(("gold", "batch"))
    q.push("gold", 1)
    q.push("batch", 2)
    # gold's head inadmissible (e.g. out of pages) -> batch is served,
    # gold stays queued rather than being dropped
    cls, item = q.pick(admissible=lambda it: it != 1)
    assert (cls, item) == ("batch", 2)
    assert q.peek("gold") == 1 and len(q) == 1
    # resume path: a preempted item re-enters at the head of its class
    q.push("gold", 3)
    q.push_front("gold", 99)
    assert q.pick()[1] == 99


def test_effective_load_uses_occupancy_and_queue():
    raw = 10.0
    # fixed-batch form: backlog against capacity
    assert effective_load_ms(raw, backlog=0, capacity=4) == raw
    assert effective_load_ms(raw, backlog=4, capacity=4) == 2 * raw
    # continuous form: slot occupancy replaces the implicit full batch
    assert effective_load_ms(raw, backlog=0, capacity=4,
                             occupancy=0.5) == 0.5 * raw
    assert effective_load_ms(raw, backlog=2, capacity=4,
                             occupancy=1.0) == 1.5 * raw


# --------------------------------------------------------------------------
# paged decode vs dense decode (exact numerics)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm():
    cfg = get_config("gemma3-1b", reduced=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def approx_setup(tmp_path_factory):
    root = tmp_path_factory.mktemp("contlib")
    store = fill_library(root / "lib", [benchmark("mul_i4"), trunc_mul2(),
                                        zero_mul2()])
    compiled, exact_area, _ = load_mul_frontier(root / "lib")
    cfg = get_config("gemma3-1b", reduced=True).with_approx_mlp()
    params = init_model(cfg, jax.random.PRNGKey(0))
    ladder = PlanLadder.build(compiled, cfg.n_layers, exact_area=exact_area,
                              levels=4)
    return root, store, compiled, exact_area, cfg, params, ladder


def test_paged_decode_matches_dense(lm):
    """Two requests staggered into a 3-slot pool, paged KV, vs each
    decoded alone in a dense cache — logits must match exactly."""
    cfg, params = lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 7)]
    joins = [0, 3]
    total, page_size, slots = 16, 4, 3
    n_pages = slots * (total // page_size) + 1

    dense_step = decode_fn(cfg)
    refs = []
    for p in prompts:
        caches = init_caches(cfg, 1, total)
        out = []
        for t in range(total - 1):
            tok = jnp.asarray([[p[t] if t < len(p) else out[-1]]],
                              dtype=jnp.int32)
            logits, caches = dense_step(cfg, params, caches, tok,
                                        jnp.asarray(t, jnp.int32))
            out.append(int(jnp.argmax(logits[0])))
        refs.append(out)

    pstep = decode_paged_fn(cfg)
    caches = init_paged_caches(cfg, slots, n_pages, page_size, total)
    alloc = PageAllocator(n_pages, page_size)
    tables = {i: alloc.alloc(i, total) for i in range(len(prompts))}
    pos = [0, 0]
    outs = [[], []]
    for step in range(total - 1 + max(joins)):
        toks = np.zeros((slots, 1), np.int32)
        posv = np.zeros(slots, np.int32)
        act = np.zeros(slots, bool)
        tab = np.full((slots, total // page_size), SCRATCH_PAGE, np.int32)
        for i, p in enumerate(prompts):
            if step < joins[i] or pos[i] >= total - 1:
                continue
            t = pos[i]
            toks[i, 0] = p[t] if t < len(p) else outs[i][-1]
            posv[i] = t
            act[i] = True
            tab[i] = tables[i]
        if not act.any():
            break
        logits, caches = pstep(cfg, params, caches, jnp.asarray(toks),
                               jnp.asarray(posv), jnp.asarray(act),
                               jnp.asarray(tab))
        samp = np.asarray(jnp.argmax(logits, axis=-1))
        for i in range(len(prompts)):
            if act[i]:
                outs[i].append(int(samp[i]))
                pos[i] += 1
    for i, ref in enumerate(refs):
        assert outs[i] == ref, f"request {i} diverged from dense decode"


# --------------------------------------------------------------------------
# continuous engine end to end
# --------------------------------------------------------------------------

def _profile(kind="ramp", ticks=4, per_tick=4, prompt_len=8, gen_len=8,
             class_mix=None, prompt_dist=("bimodal", 3, 8)):
    return make_profile(kind, ticks=ticks, per_tick=per_tick,
                        prompt_len=prompt_len, gen_len=gen_len,
                        class_mix=class_mix, prompt_dist=prompt_dist)


def _run_plain(cfg, params, compiled, exact_area, ladder, *, max_slots=2,
               n_pages=None, seed=0, profile=None):
    eng = ContinuousServingEngine(
        cfg, params, max_slots=max_slots, prompt_len=8, gen_len=8,
        page_size=4, n_pages=n_pages, plan=ladder.plan(0),
        compiled=compiled, exact_area=exact_area)
    tel = eng.serve(profile or _profile(), telemetry=Telemetry(), seed=seed)
    return eng, tel


def test_continuous_completes_all_trace_pinned(approx_setup):
    _, _, compiled, exact_area, cfg, params, ladder = approx_setup
    prof = _profile()
    eng, tel = _run_plain(cfg, params, compiled, exact_area, ladder,
                          profile=prof)
    assert eng.trace_count == 1, "join/leave churn retraced the step"
    assert len(eng.completions) == prof.total_requests
    assert all(len(g) == prof.gen_len for g in eng.completions.values())
    # drained pool returned every page
    eng._alloc.check_invariants()
    assert eng._alloc.used_pages == 0
    s = tel.summary()
    assert s["requests"] == prof.total_requests
    assert s["steps"] > prof.gen_len, "no continuous per-step accounting"

    # determinism: same seed, same completions
    eng2, _ = _run_plain(cfg, params, compiled, exact_area, ladder,
                         profile=prof)
    assert set(eng2.completions) == set(eng.completions)
    for rid, gen in eng.completions.items():
        assert np.array_equal(gen, eng2.completions[rid]), rid


def test_out_of_pages_blocks_admission_never_corrupts(approx_setup):
    _, _, compiled, exact_area, cfg, params, ladder = approx_setup
    # pool holds exactly one in-flight request's pages (4 of them) plus
    # one spare page: the second arrival MUST wait in queue, not corrupt
    prof = _profile(kind="steady", ticks=2, per_tick=3)
    eng = ContinuousServingEngine(
        cfg, params, max_slots=2, prompt_len=8, gen_len=8, page_size=4,
        n_pages=5, plan=ladder.plan(0), compiled=compiled,
        exact_area=exact_area)
    saw_block = []

    def on_step(e, step):
        e._alloc.check_invariants()
        if e.queue_depth > 0 and e._pool.n_active < e.max_slots:
            saw_block.append(step)   # a free slot existed but pages didn't

    tel = eng.serve(prof, telemetry=Telemetry(), seed=0, on_step_end=on_step)
    assert saw_block, "pool was never page-limited; test is vacuous"
    assert len(eng.completions) == prof.total_requests
    assert all(len(g) == prof.gen_len for g in eng.completions.values())
    assert eng._alloc.used_pages == 0
    assert eng.trace_count == 1


def _slo_stack(ladder, spec="gold:1e9@250ms,batch:1e9"):
    book = ClassBook.parse(spec)
    scheduler = ClassScheduler(book, ladder, shadow_every=4)
    controller = QoSController(ladder, ControllerConfig(
        target_ms_per_step=50.0, drift_budget=1e9, shadow_every=4))
    return book, scheduler, controller


def _preemption_run(cfg, params, compiled, exact_area, ladder, health=None):
    _, scheduler, controller = _slo_stack(ladder)
    prof = _profile(kind="spike", ticks=6, per_tick=5, gen_len=12,
                    class_mix=(("gold", 0.4), ("batch", 0.6)),
                    prompt_dist=("uniform", 3, 8))
    eng = ContinuousServingEngine(
        cfg, params, max_slots=2, prompt_len=8, gen_len=12, page_size=4,
        plan=ladder.plan(0), compiled=compiled, exact_area=exact_area)
    tel = eng.serve(prof, controller=controller, scheduler=scheduler,
                    telemetry=Telemetry(), seed=1, steps_per_tick=5,
                    health=health)
    preempted = [(e["step"], e["preempted_rid"]) for e in tel.events
                 if "preempted_rid" in e]
    return eng, tel, prof, preempted


def test_slo_preemption_fires_and_is_deterministic(approx_setup):
    _, _, compiled, exact_area, cfg, params, ladder = approx_setup
    eng, tel, prof, preempted = _preemption_run(cfg, params, compiled,
                                                exact_area, ladder)
    assert preempted, "SLO class never preempted a batch slot"
    assert eng.trace_count == 1, "preemption/resume retraced the step"
    assert len(eng.completions) == prof.total_requests
    assert eng._alloc.used_pages == 0
    s = tel.summary()
    assert s["preemptions"] == len(preempted)
    # preemptions are charged to the victim tier, never to gold
    assert "preemptions" not in s["classes"].get("gold", {})
    # gold's latency stayed inside its (generous, CPU-scale) SLO
    assert s["classes"]["gold"]["p95_ms_per_step"] <= 250.0
    # TTFT per class was recorded as a histogram
    assert s["classes"]["gold"]["p95_ttft_ms"] > 0
    assert s["ttft_ms"]["p95"] >= s["ttft_ms"]["p50"] > 0

    _, _, _, preempted2 = _preemption_run(cfg, params, compiled,
                                          exact_area, ladder)
    assert preempted2 == preempted, "preemption schedule is not deterministic"


def test_preempted_request_resumes_uncorrupted(approx_setup):
    """A preempted+resumed request must produce the same tokens as when
    the pool is large enough that it is never preempted.  Class budgets
    pin every level to exact so the LUT stack cannot differ."""
    _, _, compiled, exact_area, cfg, params, ladder = approx_setup
    prof = _profile(kind="spike", ticks=6, per_tick=5, gen_len=12,
                    class_mix=(("gold", 0.4), ("batch", 0.6)),
                    prompt_dist=("uniform", 3, 8))

    def run(max_slots):
        _, scheduler, _ = _slo_stack(ladder, "gold:1e-12@250ms,batch:1e-12")
        eng = ContinuousServingEngine(
            cfg, params, max_slots=max_slots, prompt_len=8, gen_len=12,
            page_size=4, plan=ladder.plan(0), compiled=compiled,
            exact_area=exact_area)
        tel = eng.serve(prof, scheduler=scheduler, telemetry=Telemetry(),
                        seed=1, steps_per_tick=5)
        return eng, tel

    tight, tel_tight = run(2)
    roomy, _ = run(8)
    assert tel_tight.preemptions >= 1, "tight pool never preempted"
    assert roomy.preemption_count == 0, "roomy pool should never preempt"
    assert set(tight.completions) == set(roomy.completions)
    for rid in tight.completions:
        assert np.array_equal(tight.completions[rid],
                              roomy.completions[rid]), (
            f"request {rid} corrupted by preemption/resume")


def test_request_lifecycle_and_provenance_e2e(tmp_path, approx_setup):
    """The tentpole e2e: a traced preemption run reconstructs a complete
    causal chain (queued -> admitted -> prefill -> decode -> preempt ->
    resume -> done) for EVERY request, with a breakdown that sums to the
    total, a gap-free provenance ledger, and both CLI gates passing — all
    while the decode step still traces exactly once."""
    from repro.obs import trace as obs_trace
    from repro.obs.__main__ import main as obs_main
    from repro.obs.provenance import _ledgers, audit, read_ledger
    from repro.obs.requests import BREAKDOWN_KEYS, build_timelines
    from repro.obs.trace import read_trace

    _, _, compiled, exact_area, cfg, params, ladder = approx_setup
    trace_dir = tmp_path / "trace"
    obs_trace.configure(trace_dir, process_tag="serve")
    try:
        eng, tel, prof, preempted = _preemption_run(cfg, params, compiled,
                                                    exact_area, ladder)
    finally:
        obs_trace.reset()
        _ledgers.clear()
    assert preempted, "run never preempted; lifecycle e2e is vacuous"
    assert eng.trace_count == 1, "lifecycle tracing retraced the step"

    tls = build_timelines(read_trace(trace_dir))
    assert len(tls) == prof.total_requests
    broken = {t.rid: t.problems for t in tls.values() if not t.complete}
    assert not broken, f"broken lifecycle chains: {broken}"
    resumed = [t for t in tls.values() if t.preempts > 0]
    assert resumed, "no preempted-and-resumed request completed a chain"
    for t in tls.values():
        assert set(t.breakdown) == set(BREAKDOWN_KEYS)
        assert t.steps is not None and t.steps >= prof.gen_len
        assert t.total_ms is not None and t.total_ms > 0
    assert any(t.breakdown["suspension_ms"] > 0 for t in resumed), \
        "resumed requests recorded no suspension time"

    # ledger: every completed request's ranges tile [0, gen_len) and the
    # drift samples the engine measured were attributed to ranges
    rep = audit(read_ledger(trace_dir))
    assert rep["n_done"] == prof.total_requests
    assert rep["n_failed"] == 0
    assert rep["n_complete"] == prof.total_requests
    assert all(r["tokens_covered"] == prof.gen_len
               for r in rep["requests"].values())
    assert sum(r["drift_samples"] for r in rep["requests"].values()) > 0
    # resumed requests still tile their ledger (the victim pick prefers
    # the youngest slot, so preemption usually lands mid-prefill and the
    # decode window stays one contiguous range — a mid-decode preempt
    # would seal and split, which the unit audit tests pin down)
    for t in resumed:
        assert rep["requests"][t.rid]["complete"], rep["requests"][t.rid]

    # both CI gates pass against the real artifacts
    assert obs_main(["requests", "--trace", str(trace_dir),
                     "--require-complete"]) == 0
    assert obs_main(["provenance", "--trace", str(trace_dir)]) == 0

    # per-class queueing-delay and suspension histograms rode telemetry
    reg = tel.registry
    assert reg.find("serve_queue_delay_ms", **{"class": "gold"}).count > 0
    assert reg.find("serve_suspension_ms", **{"class": "_all"}).count \
        == len(preempted)


def test_resume_mirrors_into_health_event_log(approx_setup):
    """Satellite of the lifecycle work: every resume is a *control*
    event — it lands in the health plane's attribution log (paired with
    the preempt that caused it), so an anomaly right after a resume
    pins to the resume instead of a stale earlier swap."""
    class _StubHealth:
        def __init__(self):
            self.noted = []

        def observe_step(self, **kw):
            return {"state": "ok"}

        def note_event(self, name, **kw):
            self.noted.append((name, kw))

        def record_crash(self, e):
            pass

    _, _, compiled, exact_area, cfg, params, ladder = approx_setup
    hp = _StubHealth()
    _, _, _, preempted = _preemption_run(cfg, params, compiled, exact_area,
                                         ladder, health=hp)
    assert preempted
    resumes = [kw for name, kw in hp.noted if name == "serve.resume"]
    assert len(resumes) == len(preempted), \
        "every preempted request that came back must note serve.resume"
    assert all("rid" in kw and "cls" in kw and "step" in kw
               for kw in resumes)
    preempt_rids = sorted(rid for _, rid in preempted)
    assert sorted(kw["rid"] for kw in resumes) == preempt_rids


def test_prov_range_seals_on_plan_change_and_preempt(tmp_path):
    """The engine's range bookkeeping, driven directly: contiguous same-
    plan tokens extend one range; a plan change or a preemption seals it;
    the resumed tail still tiles [0, gen_len) for the audit."""
    from repro.obs.provenance import ProvenanceLedger, audit, read_ledger

    class _Plan:
        def __init__(self, pid):
            self.plan_id, self.choices = pid, []

    eng = ContinuousServingEngine.__new__(ContinuousServingEngine)
    eng._provenance = ProvenanceLedger(tmp_path, tag="w")
    eng._prov_open = {}
    eng._width_map = None
    seq = SeqState(rid=1, cls="gold", prompt=np.array([1, 2], np.int32),
                   gen_len=6, submitted_t=0.0)
    p0, p1 = _Plan("p0"), _Plan("p1")
    eng._prov_extend(seq, 0, p0, 0)
    eng._prov_extend(seq, 1, p0, 0)     # contiguous same-plan: extends
    eng._prov_extend(seq, 2, p1, 1)     # plan change: seals [0, 2)
    eng._prov_close(1)                  # preemption: seals [2, 3)
    eng._prov_extend(seq, 3, p1, 1)     # resume reopens
    eng._prov_extend(seq, 4, p1, 1)
    eng._prov_extend(seq, 5, p1, 1)
    eng._prov_close(1)
    eng._provenance.record_done(rid=1, cls="gold", gen_len=6, steps=7,
                                preempts=1)
    eng._provenance.close()

    rep = audit(read_ledger(tmp_path))
    req = rep["requests"][1]
    assert req["complete"], req["problems"]
    assert [(r["t0"], r["t1"], r["plan"], r["level"])
            for r in req["ranges"]] \
        == [(0, 2, "p0", 0), (2, 3, "p1", 1), (3, 6, "p1", 1)]


# --------------------------------------------------------------------------
# multi-replica router
# --------------------------------------------------------------------------

def test_router_affinity_and_per_replica_plans(approx_setup):
    from repro.library import OperatorSignature
    from repro.core.synth import area as circuit_area
    from repro.serving import LibraryWatcher

    root, store, compiled, exact_area, cfg, params, ladder = approx_setup

    def mk(level):
        return ContinuousServingEngine(
            cfg, params, max_slots=2, prompt_len=8, gen_len=8, page_size=4,
            plan=ladder.plan(level), compiled=compiled,
            exact_area=exact_area)

    with pytest.raises(ValueError, match="at least 2"):
        ReplicaRouter([Replica("solo", mk(0))])

    router = ReplicaRouter([
        Replica("gold-exact", mk(0), classes=("gold",)),
        Replica("batch-deep", mk(len(ladder) - 1), classes=("batch",)),
    ], watcher=LibraryWatcher(root / "lib", min_poll_s=0.0))
    prof = _profile(kind="ramp", ticks=4, per_tick=4,
                    class_mix=(("gold", 0.5), ("batch", 0.5)),
                    prompt_dist=("uniform", 3, 8))
    out = router.serve(prof, seed=0)

    assert out["requests"] == prof.total_requests
    assert sum(router.routed.values()) == prof.total_requests
    assert all(v > 0 for v in router.routed.values()), router.routed
    per = out["replicas"]
    assert all(r["trace_count"] == 1 for r in per.values())
    # per-replica plan state: exact-tile replica vs deep-level replica
    assert per["gold-exact"]["plan"] != per["batch-deep"]["plan"]
    for r in router.replicas:
        assert r.engine._alloc.used_pages == 0


def test_router_routes_by_class_affinity(approx_setup):
    from repro.serving.loadgen import Request

    _, _, compiled, exact_area, cfg, params, ladder = approx_setup

    def mk():
        return ContinuousServingEngine(
            cfg, params, max_slots=2, prompt_len=8, gen_len=8, page_size=4,
            plan=ladder.plan(0), compiled=compiled, exact_area=exact_area)

    router = ReplicaRouter([Replica("a", mk(), classes=("gold",)),
                            Replica("b", mk(), classes=("batch",))])
    router.start()
    tok = np.arange(4, dtype=np.int32)
    assert router.route(Request(0, tok, qos_class="gold")).name == "a"
    assert router.route(Request(1, tok, qos_class="batch")).name == "b"
    # unhomed class falls back to least-loaded (both idle -> first)
    assert router.route(Request(2, tok, qos_class="std")).name == "a"
