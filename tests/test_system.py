"""End-to-end behaviour of the paper's system: ALS -> LUT -> approximate
inference, the full Layer A -> Layer B pipeline (DESIGN.md §2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.arith import benchmark
from repro.core.miter import worst_case_error
from repro.core.synth import area
from repro.models import forward_fn, init_model
from repro.quant import build_lut, exact_mul_lut
from repro.kernels import ops


ET = 4


@pytest.fixture(scope="module")
def approx_mult():
    """A sound ET=4 approximate 4-bit multiplier.

    Primary source: MUSCAT-like pruning (fast and sound at n=8 scale).
    The SMT/SHARED path is exercised on the adder benchmarks in
    tests/test_search.py — at mul_i8 + tight ET its 2-level SoP needs a
    product pool beyond quick-test budgets (the paper ran 3-hour
    timeouts), so the system-integration test uses the pruning engine.
    """
    from repro.core.baselines import muscat_like

    exact = benchmark("mul_i8")
    res = muscat_like(exact, et=ET, restarts=2, wall_budget_s=60)
    assert res.wce <= ET

    class _Best:
        circuit = res.circuit
        area = res.area

    return exact, _Best()


def test_found_multiplier_is_sound_and_smaller(approx_mult):
    exact, best = approx_mult
    assert worst_case_error(exact, best.circuit) <= ET
    assert best.area < area(exact)


def test_lut_error_bounded_by_et(approx_mult):
    exact, best = approx_mult
    lut = build_lut(best.circuit)
    err = np.abs(lut - exact_mul_lut())
    assert err.max() <= ET


def test_approx_inference_logit_drift_is_bounded(approx_mult):
    """Route a reduced LM's MLP matmuls through the approximate multiplier
    and check logits stay close to the exact-int4 baseline — the paper's
    'small accuracy loss' claim at system level."""
    _, best = approx_mult
    lut_approx = jnp.asarray(build_lut(best.circuit))
    lut_exact = jnp.asarray(exact_mul_lut())

    cfg = get_config("stablelm-1.6b", reduced=True).with_approx_mlp()
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}

    logits_exact4, _ = forward_fn(cfg)(cfg, params, batch, lut=lut_exact)
    logits_approx, _ = forward_fn(cfg)(cfg, params, batch, lut=lut_approx)
    logits_float, _ = forward_fn(cfg)(
        cfg, params, batch, lut=None)

    # int4 quantization moves logits; the *additional* approximate-multiplier
    # drift must be comparable, not catastrophic
    drift_quant = float(jnp.abs(logits_float - logits_exact4).mean())
    drift_approx = float(jnp.abs(logits_exact4 - logits_approx).mean())
    assert np.isfinite(drift_approx)
    assert drift_approx < 10 * max(drift_quant, 1e-3), (drift_quant, drift_approx)


def test_logit_drift_is_monotone_in_et():
    """More operator approximation -> more logit drift, and ET=0 -> none.

    (Random-init reduced models have no trained redundancy, so absolute
    agreement metrics are meaningless here; the monotone dose-response of
    drift vs ET is the system invariant that survives random init.)"""
    from repro.core.baselines import muscat_like

    exact = benchmark("mul_i8")
    cfg = get_config("qwen3-4b", reduced=True).with_approx_mlp()
    key = jax.random.PRNGKey(1)
    params = init_model(cfg, key)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab_size)}
    le, _ = forward_fn(cfg)(cfg, params, batch, lut=jnp.asarray(exact_mul_lut()))

    drifts = {}
    for et in (0, 4, 32):
        if et == 0:
            lut = exact_mul_lut()
        else:
            lut = build_lut(muscat_like(exact, et=et, restarts=1,
                                        wall_budget_s=30).circuit)
        la, _ = forward_fn(cfg)(cfg, params, batch, lut=jnp.asarray(lut))
        drifts[et] = float(jnp.abs(le - la).mean())
    assert drifts[0] == 0.0
    assert drifts[0] < drifts[4] <= drifts[32] * 1.05, drifts
