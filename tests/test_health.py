"""SLO health plane: burn-rate math against hand-computed values,
robust streaming detectors (step/spike/ramp fire exactly once, seeded
noise never fires), anomaly attribution to control events, the flight
recorder's bounded ring + atomic post-mortems, the bench regression
sentinel, trace segment rotation, and the serving e2e drill (induced
latency spike -> paged SLO + anomaly pinned to the exact swap event id +
readable post-mortem bundle, with the decode step still traced once).
"""

import json
import time

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.__main__ import main as obs_main
from repro.obs.anomaly import (AnomalyPlane, EventLog, RobustDetector,
                               robust_zscores)
from repro.obs.flight import FlightRecorder, read_postmortems
from repro.obs.health import (BurnRate, HealthPlane, SLOMonitor,
                              state_penalty, state_rank)
from repro.obs.metrics import MetricRegistry
from repro.obs.regress import (Rule, compare_bench, flatten, load_rules,
                               record_history)
from repro.obs.trace import Tracer, read_trace


@pytest.fixture(autouse=True)
def _isolate_obs_globals():
    """Every test gets a pristine global tracer and registry."""
    obs_trace.reset()
    prev = obs_metrics.set_registry(MetricRegistry())
    yield
    obs_trace.reset()
    obs_metrics.set_registry(prev)


def _fixed_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return clock


# alpha=1.0 turns the EWMA into the raw sample, so the detector math in
# these tests is exactly hand-checkable
DET = dict(window=32, warmup=8, threshold=6.0, alpha=1.0)


# ---------------------------------------------------------------------------
# robust z-scores (batch form, used by fleet outlier flagging)
# ---------------------------------------------------------------------------
def test_robust_zscores_hand_computed():
    # median 4, MAD 2 -> scale 1.4826 * 2
    zs = robust_zscores([2.0, 4.0, 6.0])
    assert zs[1] == 0.0
    assert zs[0] == pytest.approx(-2.0 / (1.4826 * 2.0))
    assert zs[2] == pytest.approx(+2.0 / (1.4826 * 2.0))
    # zero MAD: exact-median samples score 0, departures score huge
    zs = robust_zscores([1.0, 1.0, 1.0, 1.0, 9.0])
    assert zs[:4] == [0.0] * 4 and zs[4] > 1e6
    # degenerate inputs never divide by zero
    assert robust_zscores([]) == []
    assert robust_zscores([5.0]) == [0.0]


# ---------------------------------------------------------------------------
# streaming detector: step / spike / ramp fire exactly once
# ---------------------------------------------------------------------------
def test_step_change_fires_exactly_once():
    det = RobustDetector("ms", **DET)
    fires = [det.observe(1.0, i) for i in range(20)]
    fires += [det.observe(5.0, 20 + i) for i in range(40)]
    fired = [f for f in fires if f is not None]
    assert len(fired) == 1 and det.fired == 1
    a = fired[0]
    assert a.signal == "ms" and a.step == 20 and a.direction == "up"
    assert a.baseline == pytest.approx(1.0)
    assert a.value == pytest.approx(5.0)


def test_single_spike_fires_exactly_once_then_recovers():
    det = RobustDetector("ms", **DET)
    fired = []
    for step, v in enumerate([2.0] * 15 + [50.0] + [2.0] * 25):
        a = det.observe(v, step)
        if a is not None:
            fired.append(a)
    assert [a.step for a in fired] == [15]
    assert fired[0].direction == "up"
    # after re-baselining at the spike, the return to normal is the new
    # normal's own level, not a second anomaly
    assert det.fired == 1


def test_ramp_fires_exactly_once():
    det = RobustDetector("ms", **DET)
    fired = []
    for i in range(20):
        assert det.observe(1.0, i) is None
    for k in range(1, 60):
        a = det.observe(1.0 + 0.5 * k, 20 + k)
        if a is not None:
            fired.append(a)
    # the departure from the flat baseline fires; once re-baselined
    # mid-ramp, the constant slope never scores 6 sigma again
    assert [a.step for a in fired] == [21]
    assert fired[0].direction == "up"


def test_downward_step_fires_with_down_direction():
    det = RobustDetector("tok_s", **DET)
    fired = [det.observe(v, i) for i, v in
             enumerate([100.0] * 15 + [20.0] * 15)]
    fired = [f for f in fired if f]
    assert len(fired) == 1 and fired[0].direction == "down"


def test_steady_noise_zero_false_positives_10k_steps():
    det = RobustDetector("ms")   # production defaults
    rng = np.random.default_rng(0)
    for step, v in enumerate(5.0 + 0.5 * rng.standard_normal(10_000)):
        assert det.observe(float(v), step) is None
    assert det.fired == 0


def test_detector_validation():
    with pytest.raises(ValueError):
        RobustDetector("x", alpha=0.0)
    with pytest.raises(ValueError):
        RobustDetector("x", warmup=1)
    with pytest.raises(ValueError):
        RobustDetector("x", window=4, warmup=8)


# ---------------------------------------------------------------------------
# attribution: event log + anomaly plane
# ---------------------------------------------------------------------------
def test_event_log_nearest_prior_within_horizon():
    log = EventLog()
    log.note("serve.swap", 5, "e0")
    log.note("serve.refresh", 18, "e1")
    log.note("serve.control", 40, "e2")
    assert log.nearest(20).event_id == "e1"     # most recent prior
    assert log.nearest(18).event_id == "e1"     # at-step counts
    assert log.nearest(4) is None               # nothing prior
    assert log.nearest(100).event_id == "e2"    # 60 steps back, in horizon
    assert log.nearest(110) is None             # 70 steps back, beyond it
    # bounded ring
    small = EventLog(capacity=2)
    for i in range(5):
        small.note("ev", i)
    assert [e.step for e in small.events()] == [3, 4]


def test_anomaly_plane_attributes_to_nearest_event():
    plane = AnomalyPlane(configs={"ms": DET})
    plane.note_event("serve.swap", 3, "ev-old", reason="early")
    for i in range(20):
        if i == 18:
            plane.note_event("serve.swap", 18, "ev-swap", reason="drill")
        assert plane.observe("ms", 1.0, i) is None
    fired = plane.observe("ms", 9.0, 20)
    assert fired is not None
    assert fired.cause.name == "serve.swap"
    assert fired.cause.event_id == "ev-swap"
    assert fired.cause.attrs == {"reason": "drill"}
    doc = fired.to_doc()
    assert doc["cause"]["distance"] == 2
    assert "ev-swap" in fired.describe()
    assert plane.fired_total == 1
    assert plane.to_doc()["by_signal"] == {"ms": 1}


def test_anomaly_attributes_to_resume_event():
    """``serve.resume`` is a first-class control event: a latency spike
    right after a preempted request re-enters its slot pins to the
    resume, not to some stale earlier swap."""
    plane = AnomalyPlane(configs={"ms": DET})
    plane.note_event("serve.swap", 2, "ev-swap", reason="early")
    for i in range(20):
        if i == 19:
            plane.note_event("serve.resume", 19, "ev-res", rid=7,
                             cls="batch")
        assert plane.observe("ms", 1.0, i) is None
    fired = plane.observe("ms", 9.0, 20)
    assert fired is not None
    assert fired.cause.name == "serve.resume"
    assert fired.cause.event_id == "ev-res"
    assert fired.cause.attrs == {"rid": 7, "cls": "batch"}


def test_anomaly_without_recent_event_has_no_cause():
    plane = AnomalyPlane(configs={"ms": DET})
    for i in range(20):
        plane.observe("ms", 1.0, i)
    fired = plane.observe("ms", 9.0, 20)
    assert fired is not None and fired.cause is None
    assert "no recent event" in fired.describe()
    assert "cause" not in fired.to_doc()


# ---------------------------------------------------------------------------
# burn rates: hand-computed multi-window math + hysteresis
# ---------------------------------------------------------------------------
def test_burn_rate_hand_computed_sequence():
    br = BurnRate(budget=0.25, short_window=4, long_window=8,
                  warn_burn=1.0, page_burn=2.0, clear_patience=2,
                  min_count=2)
    assert br.observe(False) == "ok"       # 1 obs < min_count: cold start
    assert br.observe(True) == "page"      # 1 bad / 2 obs: burn 2.0 both
    assert br.burn_short == pytest.approx((1 / 2) / 0.25)
    assert br.burn_long == pytest.approx(2.0)
    assert br.observe(False) == "page"     # target warn, hysteresis holds
    assert br.burn_short == pytest.approx((1 / 3) / 0.25)
    assert br.observe(False) == "warn"     # 2nd calm eval: de-escalate
    assert br.burn_short == pytest.approx((1 / 4) / 0.25)
    assert br.observe(False) == "warn"     # long window calm: patience 1 of 2
    assert br.burn_short == pytest.approx(1.0)   # short deque [1,0,0,0]
    assert br.burn_long == pytest.approx((1 / 5) / 0.25)
    assert br.observe(False) == "ok"       # patience 2 of 2
    assert br.burn_short == 0.0
    assert br.burn_long == pytest.approx((1 / 6) / 0.25)
    assert br.observations == 6 and br.violations == 1
    doc = br.to_doc()
    assert doc["state"] == "ok" and doc["budget"] == 0.25


def test_burn_rate_pages_need_both_windows_hot():
    # short window saturates instantly but the long window refuses to
    # page on a blip: 2 bad out of 20 long obs = burn 0.4 < 2.0
    br = BurnRate(budget=0.25, short_window=2, long_window=20,
                  min_count=1, clear_patience=1)
    for _ in range(18):
        br.observe(False)
    br.observe(True)
    state = br.observe(True)
    assert br.burn_short == pytest.approx(4.0)      # 2/2 / 0.25
    assert br.burn_long == pytest.approx((2 / 20) / 0.25)
    assert state == "ok", "a blip paged despite a calm long window"


def test_burn_rate_cold_start_guard_and_validation():
    br = BurnRate(budget=0.5, short_window=8, long_window=8, min_count=4)
    assert [br.observe(True) for _ in range(3)] == ["ok"] * 3
    assert br.observe(True) == "page"   # 4th obs clears min_count
    for bad in (dict(budget=0.0), dict(budget=1.5),
                dict(budget=0.1, short_window=8, long_window=4),
                dict(budget=0.1, warn_burn=2.0, page_burn=1.0)):
        with pytest.raises(ValueError):
            BurnRate(**bad)


def test_slo_monitor_built_from_class_book():
    from repro.sensitivity.classes import ClassBook

    book = ClassBook.parse("gold:0.02@8ms,batch:0.2")
    mon = SLOMonitor(book, min_count=1, short_window=4, long_window=8)
    assert bool(mon)
    assert set(mon.latency) == {"gold"}          # only gold declared an SLO
    assert set(mon.drift) == {"gold", "batch"}   # both have finite budgets
    assert mon.latency["gold"].budget == pytest.approx(0.05)  # 1 - p95
    assert mon.slo_ms["gold"] == 8.0
    assert mon.drift_budget["batch"] == pytest.approx(0.2)
    # feeds route by class; unknown classes are ignored, not invented
    assert mon.observe_latency("gold", 20.0) is not None
    assert mon.observe_latency("nope", 20.0) is None
    assert mon.observe_drift("batch", 0.5) is not None
    assert mon.class_state("gold") in ("ok", "warn", "page")
    assert mon.classes == ["batch", "gold"]
    doc = mon.to_doc()
    assert doc["gold"]["latency"]["slo_ms"] == 8.0
    assert "latency" not in doc["batch"]
    assert not SLOMonitor(None), "empty monitor should be falsy"


# ---------------------------------------------------------------------------
# health plane: monitors + attribution + recorder + gauges, end to end
# ---------------------------------------------------------------------------
def test_health_plane_pages_attributes_and_dumps(tmp_path):
    from repro.sensitivity.classes import ClassBook

    reg = MetricRegistry()
    hp = HealthPlane(
        ClassBook.parse("gold:1e9@8ms"), registry=reg,
        postmortem_dir=tmp_path, tag="t",
        monitor_config=dict(short_window=4, long_window=8, min_count=2,
                            clear_patience=1000),
        anomaly_config=dict(configs={"ms_per_step": DET}))
    for i in range(20):
        out = hp.observe_step(step=i, step_ms=1.0, classes={"gold": {}})
        assert out["state"] == "ok" and not out["anomalies"]
    assert hp.penalty == 0.0
    assert reg.find("serve_slo_ok", **{"class": "gold"}).value == 1.0

    hp.note_event("serve.swap", step=19, event_id="ev-1", reason="drill")
    out = hp.observe_step(step=20, step_ms=50.0, classes={"gold": {}},
                          backlog=3, occupancy=0.5, preemptions=1,
                          plan_id="p0", level=1,
                          pages={"used": 3, "free": 5, "total": 8})
    # one bad obs: short burn (1/4)/0.05 = 5, long (1/8)/0.05 = 2.5 ->
    # both >= 2.0, so the transition pages immediately
    assert out["state"] == "page"
    assert [(t["class"], t["to"]) for t in out["transitions"]] \
        == [("gold", "page")]
    # the detector fired on the same step and pinned the swap
    spikes = [a for a in out["anomalies"] if a.signal == "ms_per_step"]
    assert len(spikes) == 1
    assert spikes[0].cause.event_id == "ev-1"
    # both triggers dumped a bundle
    assert len(out["dumps"]) == 2
    assert hp.pages == 1 and hp.worst_state == "page"
    assert hp.penalty == state_penalty("page") == 4.0

    # gauges rode the registry (the Prometheus series)
    assert reg.find("serve_slo_ok", **{"class": "gold"}).value == 0.0
    assert reg.find("health_state", **{"class": "gold"}).value \
        == state_rank("page")
    assert reg.find("health_anomalies").value >= 1

    bundles = read_postmortems(tmp_path)
    assert {doc["reason"] for _, doc in bundles} == {"slo_breach", "anomaly"}
    _, doc = bundles[0]
    assert doc["context"]["plan_id"] == "p0"
    assert doc["context"]["pages"]["used"] == 3
    kinds = {f["kind"] for f in doc["frames"]}
    assert {"step", "event", "anomaly", "slo"} <= kinds
    assert doc["health"]["state"] == "page"

    rep = hp.report()
    assert rep["state"] == "page" and rep["pages"] == 1
    assert rep["recent_anomalies"][-1]["cause"]["event_id"] == "ev-1"
    assert rep["classes"]["gold"]["latency"]["violations"] == 1


def test_health_plane_record_crash_dumps_bundle(tmp_path):
    hp = HealthPlane(None, registry=MetricRegistry(),
                     postmortem_dir=tmp_path, tag="c")
    hp.observe_step(step=0, step_ms=5.0)
    path = hp.record_crash(RuntimeError("boom"))
    assert path is not None
    _, doc = read_postmortems(tmp_path)[0]
    assert doc["reason"] == "crash" and "boom" in doc["detail"]
    # without a dir the crash hook is a no-op, never a second crash
    assert HealthPlane(None, registry=MetricRegistry()).record_crash(
        RuntimeError("x")) is None


def test_health_plane_overhead_is_negligible():
    from repro.sensitivity.classes import ClassBook

    hp = HealthPlane(ClassBook.parse("gold:0.02@8ms,batch:0.2"),
                     registry=MetricRegistry())
    n = 2000
    t0 = time.perf_counter()
    for i in range(n):
        hp.observe_step(step=i, step_ms=10.0 + 0.1 * (i % 7),
                        classes={"gold": {}, "batch": {}}, drift=0.01,
                        backlog=i % 3, occupancy=0.5, preemptions=0,
                        plan_id="p", level=1)
    per_call_ms = 1e3 * (time.perf_counter() - t0) / n
    # the acceptance budget: <= 2% of a 10 ms decode step
    assert per_call_ms < 0.2, f"health plane costs {per_call_ms:.3f} ms/step"


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------
def test_flight_recorder_ring_is_bounded():
    rec = FlightRecorder(capacity=4, tag="t")
    for i in range(10):
        rec.note("step", step=i)
    assert [f["step"] for f in rec.frames] == [6, 7, 8, 9]
    rec.set_context(plan_id="p", level=None)
    assert rec.context == {"plan_id": "p", "level": None}
    assert rec.dump("x") is None, "no dir configured must be a no-op"


def test_flight_recorder_dump_cap_and_restart_numbering(tmp_path):
    rec = FlightRecorder(capacity=8, postmortem_dir=tmp_path, tag="t",
                         max_bundles=2)
    rec.note("step", step=0)
    assert rec.dump("one").name == "postmortem-t-0000.json"
    assert rec.dump("two").name == "postmortem-t-0001.json"
    assert rec.dump("three") is None     # cap hit
    assert rec.dumps == 2 and rec.dumps_suppressed == 1
    # a restarted recorder into the same dir never overwrites bundles
    rec2 = FlightRecorder(postmortem_dir=tmp_path, tag="t", max_bundles=2)
    assert rec2.dump("four").name == "postmortem-t-0002.json"
    bundles = read_postmortems(tmp_path)
    assert [doc["reason"] for _, doc in bundles] == ["one", "two", "four"]
    # atomic writes leave no temp files behind
    assert all(p.suffix == ".json" for p in tmp_path.iterdir())
    # foreign/unreadable files are skipped, not fatal
    (tmp_path / "postmortem-t-9999.json").write_text("{torn")
    assert len(read_postmortems(tmp_path)) == 3


# ---------------------------------------------------------------------------
# bench regression sentinel
# ---------------------------------------------------------------------------
def test_compare_bench_direction_aware_defaults():
    base = {"decode_tok_s": 200.0, "prefill_tok_s": 100.0,
            "ms_per_step": 10.0, "trace_count": 1,
            "wall_s": 5.0, "requests": 8,
            "classes": {"gold": {"p95_ms_per_step": 8.0}}}
    cur = {"decode_tok_s": 90.0,     # drop 110 > tol 100: regression
           "prefill_tok_s": 260.0,   # rise 160 > tol 50: improvement
           "ms_per_step": 2.0,       # better, but inside rel_tol 1.0: quiet
           "trace_count": 2,         # exact: any change regresses
           "wall_s": 50.0,           # ignored
           "requests": 6}            # unmatched -> catch-all ignore
    res = compare_bench(cur, base)
    regs = {f["metric"]: f for f in res["regressions"]}
    assert set(regs) == {"decode_tok_s", "trace_count",
                         "classes.gold.p95_ms_per_step"}
    assert regs["classes.gold.p95_ms_per_step"]["kind"] == "missing"
    assert regs["trace_count"]["rule"] == "*trace_count*"
    assert {f["metric"] for f in res["improvements"]} == {"prefill_tok_s"}
    assert res["compared"] == 4

    # within tolerance: a 25% tok/s wobble is CI noise, not a regression
    ok = compare_bench({"decode_tok_s": 150.0, "prefill_tok_s": 120.0,
                        "ms_per_step": 12.0,
                        "trace_count": 1, "wall_s": 1.0, "requests": 8,
                        "classes": {"gold": {"p95_ms_per_step": 9.0}}},
                       base)
    assert ok["regressions"] == [] and ok["improvements"] == []


def test_rule_judging_and_validation():
    assert Rule("x", "higher", rel_tol=0.1).judge(100, 120) == "improvement"
    assert Rule("x", "lower", rel_tol=0.1).judge(100, 120) == "regression"
    assert Rule("x", "both", rel_tol=0.1).judge(100, 105) is None
    assert Rule("x", "exact").judge("a", "b") == "regression"
    # bools never take the numeric path (True == 1 would judge by "tolerance")
    assert Rule("x", "higher").judge(True, False) == "regression"
    with pytest.raises(ValueError):
        Rule("x", "weird")
    with pytest.raises(ValueError):
        Rule("x", rel_tol=-1.0)


def test_flatten_load_rules_and_history(tmp_path):
    assert flatten({"a": {"b": [1, {"c": 2}]}, "d": 3}) \
        == {"a.b.0": 1, "a.b.1.c": 2, "d": 3}
    # loaded rules take precedence, defaults backstop
    tol = tmp_path / "tolerances.json"
    tol.write_text(json.dumps(
        {"rules": [{"pattern": "*requests*", "direction": "exact"}]}))
    rules = load_rules(tol)
    res = compare_bench({"requests": 6}, {"requests": 8}, rules)
    assert res["regressions"][0]["metric"] == "requests"
    assert load_rules(None) == load_rules(tmp_path / "missing.json")
    # history: seq-numbered, never overwrites
    p0 = record_history(tmp_path / "hist", "BENCH_x.json", {"v": 1})
    p1 = record_history(tmp_path / "hist", "BENCH_x.json", {"v": 2})
    assert (p0.name, p1.name) == ("BENCH_x-0000.json", "BENCH_x-0001.json")
    assert json.loads(p1.read_text()) == {"v": 2}


def test_obs_cli_diff_gate(tmp_path, capsys):
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    (baselines / "BENCH_x.json").write_text(json.dumps(
        {"decode_tok_s": 200.0, "trace_count": 1}))
    cur = tmp_path / "BENCH_x.json"
    cur.write_text(json.dumps({"decode_tok_s": 90.0, "trace_count": 1}))
    hist = tmp_path / "hist"

    rc = obs_main(["diff", "--bench", str(cur),
                   "--baseline-dir", str(baselines),
                   "--history-dir", str(hist)])
    out = capsys.readouterr().out
    assert rc == 1 and "REGRESSION decode_tok_s" in out
    assert list(hist.glob("BENCH_x-*.json")), "history not recorded"

    cur.write_text(json.dumps({"decode_tok_s": 190.0, "trace_count": 1}))
    assert obs_main(["diff", "--bench", str(cur),
                     "--baseline-dir", str(baselines)]) == 0

    # no baseline: informative skip by default, hard gate on demand
    other = tmp_path / "BENCH_y.json"
    other.write_text("{}")
    assert obs_main(["diff", "--bench", str(other),
                     "--baseline-dir", str(baselines)]) == 0
    assert obs_main(["diff", "--bench", str(other),
                     "--baseline-dir", str(baselines),
                     "--require-baseline"]) == 1

    # committed tolerances.json in the baseline dir is picked up by default
    (baselines / "tolerances.json").write_text(json.dumps(
        {"rules": [{"pattern": "*tok_s*", "direction": "higher",
                    "rel_tol": 0.9}]}))
    cur.write_text(json.dumps({"decode_tok_s": 90.0, "trace_count": 1}))
    assert obs_main(["diff", "--bench", str(cur),
                     "--baseline-dir", str(baselines)]) == 0


# ---------------------------------------------------------------------------
# trace segment rotation
# ---------------------------------------------------------------------------
def test_trace_rotation_merges_and_tolerates_torn_tail(tmp_path):
    tr = Tracer(tmp_path, clock=_fixed_clock(), process_tag="w0",
                max_segment_bytes=600)
    ids = [tr.event("tick", i=i) for i in range(30)]
    tr.close()
    assert all(ids) and len(set(ids)) == 30   # event() returns span ids
    segments = sorted(tmp_path.glob("spans-w0.*.jsonl"))
    assert len(segments) >= 2, "no rotation under a 600-byte cap"
    assert (tmp_path / "spans-w0.jsonl").exists(), "active file renamed away"
    # rotation happens at line boundaries: sealed segments are never torn
    for seg in segments:
        text = seg.read_text()
        assert text.endswith("\n")
        for line in text.splitlines():
            json.loads(line)
    spans = read_trace(tmp_path)
    assert {s["id"] for s in spans} == set(ids)
    assert [s["attrs"]["i"] for s in spans] == list(range(30))
    # only the active tail can tear; the reader skips it as before
    with open(tmp_path / "spans-w0.jsonl", "a") as f:
        f.write('{"id": "to')
    assert len(read_trace(tmp_path)) == 30


def test_rotation_disabled_and_module_event_off():
    assert obs_trace.event("x") == "", "unconfigured event must return ''"


def test_rotation_zero_disables(tmp_path):
    tr = Tracer(tmp_path, clock=_fixed_clock(), process_tag="w0",
                max_segment_bytes=0)
    for i in range(50):
        tr.event("tick", i=i)
    tr.close()
    assert list(tmp_path.glob("spans-w0.*.jsonl")) == []
    assert len(read_trace(tmp_path)) == 50


# ---------------------------------------------------------------------------
# CLI: health gate, postmortem reader, summary --json
# ---------------------------------------------------------------------------
def test_obs_cli_health_gate(tmp_path, capsys):
    report = {"state": "page", "anomalies_fired": 2, "pages": 1, "dumps": 1,
              "classes": {"gold": {"state": "page", "latency": {
                  "slo_ms": 8.0, "state": "page", "budget": 0.05,
                  "burn_short": 5.0, "burn_long": 2.5,
                  "observations": 21, "violations": 1}}},
              "recent_anomalies": [{
                  "signal": "ms_per_step", "step": 20, "value": 50.0,
                  "zscore": 9.0, "baseline": 1.0, "direction": "up",
                  "cause": {"event": "serve.swap", "step": 19,
                            "event_id": "ev-1", "attrs": {},
                            "distance": 1}}]}
    bench = tmp_path / "BENCH_serve.json"
    bench.write_text(json.dumps({"decode_tok_s": 10.0, "health": report}))
    assert obs_main(["health", "--bench", str(bench)]) == 1
    out = capsys.readouterr().out
    assert "page" in out and "ev-1" in out and "burn" in out
    assert obs_main(["health", "--bench", str(bench),
                     "--max-state", "page"]) == 0
    # a bare health-report JSON works too
    bare = tmp_path / "health.json"
    bare.write_text(json.dumps({**report, "state": "ok"}))
    assert obs_main(["health", "--bench", str(bare)]) == 0
    # no health section / no file are usage errors, not gate failures
    nohealth = tmp_path / "plain.json"
    nohealth.write_text(json.dumps({"decode_tok_s": 1.0}))
    assert obs_main(["health", "--bench", str(nohealth)]) == 2
    assert obs_main(["health", "--bench", str(tmp_path / "nope.json")]) == 2


def test_obs_cli_postmortem_gate(tmp_path, capsys):
    rec = FlightRecorder(postmortem_dir=tmp_path, tag="t")
    rec.note("step", step=1)
    rec.dump("slo_breach", detail="gold: ok->page")
    assert obs_main(["postmortem", "--dir", str(tmp_path),
                     "--require", "1", "--last"]) == 0
    out = capsys.readouterr().out
    assert "slo_breach" in out and "gold: ok->page" in out
    assert obs_main(["postmortem", "--dir", str(tmp_path),
                     "--require", "2"]) == 1
    assert obs_main(["postmortem", "--dir", str(tmp_path / "empty"),
                     "--require", "1"]) == 1


def test_obs_cli_summary_json(tmp_path, capsys):
    tr = Tracer(tmp_path, clock=_fixed_clock(), process_tag="w0")
    with tr.span("fleet.job", engine="anneal", n_results=2):
        pass
    tr.close()
    assert obs_main(["summary", "--trace", str(tmp_path), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["n_spans"] == 1 and doc["n_span_files"] == 1
    assert doc["span_totals"]["fleet.job"]["count"] == 1
    assert doc["engines"]["anneal"]["results"] == 2
    assert doc["slowest"][0]["name"] == "fleet.job"
    # gates still apply in --json mode
    assert obs_main(["summary", "--trace", str(tmp_path), "--json",
                     "--require-span", "serve.decode"]) == 1


def test_page_pool_gauges_exported():
    from repro.obs.export import prometheus_text
    from repro.serving.telemetry import Telemetry

    tel = Telemetry()
    tel.record_pages(used=3, total=8)
    assert tel.registry.find("serve_page_pool_used").value == 3
    assert tel.registry.find("serve_page_pool_occupancy").value \
        == pytest.approx(0.375)
    text = prometheus_text(tel.registry)
    assert "serve_page_pool_occupancy" in text
    tel.record_pages(used=0, total=0)   # never divides by zero
    assert tel.registry.find("serve_page_pool_occupancy").value == 0.0


# ---------------------------------------------------------------------------
# fleet: wall-time outlier flagging
# ---------------------------------------------------------------------------
def test_flag_outlier_jobs_groups_and_threshold():
    from repro.core.engine import SearchJob
    from repro.fleet.worker import JobResult, flag_outlier_jobs

    def res(seed, engine_s, status="ok"):
        return JobResult(SearchJob("adder", 2, 1, "anneal", seed=seed),
                         status, n_results=1, wall_s=engine_s,
                         engine_s=engine_s)

    results = [res(i, 1.0 + 0.01 * i) for i in range(5)] + [res(9, 50.0)]
    flagged = flag_outlier_jobs(results)
    assert len(flagged) == 1
    r, z = flagged[0]
    assert r.engine_s == 50.0 and z > 4.0
    reg = obs_metrics.get_registry()
    assert reg.find("fleet_job_outliers_total", engine="anneal").value == 1
    # groups below min_group are skipped (median over 3 flags noise)
    assert flag_outlier_jobs([res(i, s) for i, s in
                              enumerate((1.0, 1.0, 99.0))]) == []
    # failed jobs never enter the statistics
    failed = [res(i, 1.0) for i in range(4)] + [res(8, 99.0, "failed")]
    assert flag_outlier_jobs(failed) == []


# ---------------------------------------------------------------------------
# router: health-aware routing (unit, with stub engines)
# ---------------------------------------------------------------------------
def test_router_sheds_load_from_degraded_replica():
    pytest.importorskip("jax")
    from repro.serving import Replica, ReplicaRouter
    from repro.serving.loadgen import Request

    class _Eng:
        def __init__(self, load):
            self.load_score = load

    class _H:
        def __init__(self, state):
            self.state = state

        @property
        def penalty(self):
            return state_penalty(self.state)

    degraded = Replica("degraded", _Eng(0.0), health=_H("page"))
    healthy = Replica("healthy", _Eng(0.0))
    router = ReplicaRouter([degraded, healthy])
    tok = np.arange(4, dtype=np.int32)
    homes = [router.route(Request(i, tok)).name for i in range(16)]
    # equal raw load: the paged replica's +4.0 penalty sheds every arrival
    assert set(homes) == {"healthy"}
    assert degraded.routing_score == pytest.approx(4.0)
    # ...without black-holing it: a busy-enough healthy peer still loses
    healthy.engine.load_score = 10.0
    assert router.route(Request(99, tok)).name == "degraded"
    degraded.health.state = "warn"
    assert degraded.routing_score == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# e2e drill: induced latency spike on a live continuous serve
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def drill_setup(tmp_path_factory):
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.core.arith import benchmark
    from repro.library.compile import load_mul_frontier
    from repro.models import init_model
    from repro.serving import PlanLadder

    from test_serving import fill_library, trunc_mul2, zero_mul2

    root = tmp_path_factory.mktemp("healthlib")
    fill_library(root / "lib", [benchmark("mul_i4"), trunc_mul2(),
                                zero_mul2()])
    compiled, exact_area, _ = load_mul_frontier(root / "lib")
    cfg = get_config("gemma3-1b", reduced=True).with_approx_mlp()
    params = init_model(cfg, jax.random.PRNGKey(0))
    ladder = PlanLadder.build(compiled, cfg.n_layers,
                              exact_area=exact_area, levels=4)
    return cfg, params, compiled, exact_area, ladder


def test_e2e_drill_spike_pages_and_attributes_to_swap(drill_setup, tmp_path):
    """The acceptance drill: a two-class continuous serve with an induced
    mid-run latency spike must page the SLO, pin the anomaly to the exact
    swap event id in the trace, and leave a readable post-mortem bundle —
    all with the decode step still traced exactly once."""
    from repro.sensitivity.classes import ClassBook, ClassScheduler
    from repro.serving import ContinuousServingEngine, Telemetry, \
        make_profile

    cfg, params, compiled, exact_area, ladder = drill_setup
    trace_dir = tmp_path / "trace"
    pm_dir = tmp_path / "pm"
    obs_trace.configure(trace_dir, process_tag="drill")

    # the 50ms SLO rides BOTH classes so the spike pages whichever class
    # happens to occupy the pool while the injected delay is live
    book = ClassBook.parse("gold:1e9@50ms,batch:1e9@50ms")
    scheduler = ClassScheduler(book, ladder, shadow_every=4)
    hp = HealthPlane(
        book, postmortem_dir=pm_dir, tag="drill",
        monitor_config=dict(short_window=6, long_window=12, min_count=3,
                            clear_patience=10_000),
        # alpha 1.0 scores the raw step; threshold 12 sits far above CPU
        # timing jitter yet far below the +1000ms injected spike's z
        anomaly_config=dict(configs={
            "ms_per_step": dict(window=32, warmup=8, threshold=12.0,
                                alpha=1.0)}))
    prof = make_profile("steady", ticks=3, per_tick=2, prompt_len=8,
                        gen_len=6,
                        class_mix=(("gold", 0.5), ("batch", 0.5)))
    eng = ContinuousServingEngine(
        cfg, params, max_slots=2, prompt_len=8, gen_len=6, page_size=4,
        plan=ladder.plan(0), compiled=compiled, exact_area=exact_area)

    INJECT_AT = 14   # past detector warmup, past the last arrival tick

    def chaos(e, step):
        if step == INJECT_AT:
            e.swap_plan(ladder.plan(1), ladder.luts(1), reason="drill",
                        telemetry=e.telemetry, batch_idx=step)
            e.inject_step_delay = 1.0   # +1000ms, 20x the 50ms SLO
        elif step == INJECT_AT + 4:
            # 4 slow steps page the monitors (min_count 3) and fire the
            # detector; the latch (clear_patience) keeps the state paged
            e.inject_step_delay = 0.0

    tel = eng.serve(prof, scheduler=scheduler, telemetry=Telemetry(),
                    seed=0, steps_per_tick=5, health=hp, on_step_end=chaos)

    # the serve completed correctly under chaos, decode traced once
    assert eng.trace_count == 1
    assert len(eng.completions) == prof.total_requests
    assert eng._alloc.used_pages == 0
    assert tel.summary()["steps"] > INJECT_AT + 3

    # SLO paged and stayed paged (clear_patience pinned for the assert)
    assert hp.worst_state == "page" and hp.pages >= 1
    assert sum(m.violations for m in hp.slo.latency.values()) >= 3

    # the spike anomaly fired after injection and is pinned to the swap
    spikes = [a for a in hp.anomaly.anomalies
              if a.signal == "ms_per_step" and a.step > INJECT_AT]
    assert spikes, "induced latency spike never detected"
    cause = spikes[0].cause
    assert cause is not None and cause.name == "serve.swap"
    assert cause.attrs.get("reason") == "drill"

    # ... and the attribution names the *exact* trace event id
    obs_trace.reset(clear_env=True)
    swaps = [s for s in read_trace(trace_dir)
             if s["name"] == "serve.swap"
             and s.get("attrs", {}).get("reason") == "drill"]
    assert len(swaps) == 1
    assert cause.event_id == swaps[0]["id"]

    # post-mortem bundles landed and the CLI reads/gates them
    bundles = read_postmortems(pm_dir)
    assert bundles
    reasons = {doc["reason"] for _, doc in bundles}
    assert "slo_breach" in reasons
    _, last = bundles[-1]
    assert {"step", "event"} <= {f["kind"] for f in last["frames"]}
    assert obs_main(["postmortem", "--dir", str(pm_dir),
                     "--require", "1"]) == 0

    # the bench-level health gate fails by default, passes when page is
    # explicitly allowed
    bench = tmp_path / "BENCH_drill.json"
    bench.write_text(json.dumps(
        {"steps": tel.summary()["steps"], "health": hp.report()}))
    assert obs_main(["health", "--bench", str(bench)]) == 1
    assert obs_main(["health", "--bench", str(bench),
                     "--max-state", "page"]) == 0


def test_e2e_router_sheds_admissions_from_paged_replica(drill_setup):
    """A replica whose health plane reports page must receive measurably
    fewer admissions than its healthy peer on the same profile."""
    from repro.serving import (ContinuousServingEngine, Replica,
                               ReplicaRouter, make_profile)

    cfg, params, compiled, exact_area, ladder = drill_setup

    def mk():
        return ContinuousServingEngine(
            cfg, params, max_slots=2, prompt_len=8, gen_len=6, page_size=4,
            plan=ladder.plan(0), compiled=compiled, exact_area=exact_area)

    degraded_hp = HealthPlane(
        None, registry=MetricRegistry(),
        monitor_config=dict(short_window=4, long_window=8, min_count=1,
                            clear_patience=10 ** 9))
    degraded_hp.slo.add_latency_slo("gold", 1.0, budget=0.05)
    for i in range(8):
        degraded_hp.observe_step(step=i, step_ms=999.0,
                                 classes={"gold": {}})
    assert degraded_hp.worst_state == "page"

    router = ReplicaRouter([
        Replica("degraded", mk(), health=degraded_hp),
        Replica("healthy", mk(),
                health=HealthPlane(None, registry=MetricRegistry())),
    ])
    prof = make_profile("steady", ticks=3, per_tick=4, prompt_len=8,
                        gen_len=6)
    out = router.serve(prof, seed=0)
    assert out["requests"] == prof.total_requests
    # listed first, so without the penalty the degraded replica would win
    # every load tie; with it, the healthy peer takes the bulk
    assert router.routed["healthy"] > router.routed["degraded"], \
        router.routed
    assert out["replicas"]["degraded"]["health"]["state"] == "page"
    for r in router.replicas:
        assert r.engine.trace_count == 1
