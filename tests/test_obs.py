"""Observability plane: histogram quantile exactness, deterministic span
traces, cross-process merge idempotence, exporters, the instrumented
telemetry, the trace-inspector CLI gates, and the classed serve e2e
(per-class p95 present, decode traced once, spans in the trace dir)."""

import json

import numpy as np
import pytest

from repro.core.arith import benchmark
from repro.core.circuits import Circuit, Op
from repro.core.synth import area
from repro.library import OperatorSignature, OperatorStore
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.export import dump_metrics, prometheus_text, read_metrics
from repro.obs.metrics import Histogram, MetricRegistry
from repro.obs.trace import Tracer, read_trace


@pytest.fixture(autouse=True)
def _isolate_obs_globals():
    """Every test gets a pristine global tracer and registry."""
    obs_trace.reset()
    prev = obs_metrics.set_registry(MetricRegistry())
    yield
    obs_trace.reset()
    obs_metrics.set_registry(prev)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_histogram_quantiles_match_numpy_while_exact():
    h = Histogram(buckets=(0.5, 1.0, 5.0, 10.0))
    rng = np.random.default_rng(7)
    vals = rng.uniform(0.01, 12.0, size=500).tolist()
    for v in vals:
        h.observe(v)
    assert h.exact
    for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        assert h.quantile(q) == pytest.approx(
            np.percentile(vals, q * 100), rel=1e-12)
    ps = h.percentiles()
    assert set(ps) == {"p50", "p95", "p99"}
    assert h.mean == pytest.approx(np.mean(vals))
    assert h.min == min(vals) and h.max == max(vals)


def test_histogram_bucket_counts_and_wrapped_quantiles():
    h = Histogram(buckets=(1.0, 2.0, 4.0), max_samples=4)
    vals = [0.5, 1.5, 3.0, 3.5, 5.0, 8.0, 0.2, 1.1]
    for v in vals:
        h.observe(v)
    # bucket counts stay exact regardless of the reservoir
    assert h.counts == [2, 2, 2, 2]   # <=1, <=2, <=4, overflow
    assert h.count == len(vals) and not h.exact
    # wrapped quantiles degrade to bucket interpolation but stay bounded
    for q in (0.1, 0.5, 0.9):
        assert h.min <= h.quantile(q) <= h.max
    assert h.quantile(0.0) >= h.min and h.quantile(1.0) <= h.max


def test_histogram_empty_and_bad_quantile():
    h = Histogram(buckets=(1.0,))
    assert h.quantile(0.5) is None and h.mean == 0.0
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram(buckets=())


def test_registry_kind_conflicts_and_find():
    reg = MetricRegistry()
    reg.counter("jobs", engine="anneal").inc(3)
    with pytest.raises(TypeError):
        reg.gauge("jobs", engine="anneal")
    assert reg.find("jobs", engine="anneal").value == 3
    assert reg.find("jobs", engine="tensor") is None
    assert reg.with_name("jobs")[0][0] == {"engine": "anneal"}
    with pytest.raises(ValueError):
        reg.counter("jobs", engine="anneal").inc(-1)


def test_snapshot_merge_semantics():
    a, b = MetricRegistry(), MetricRegistry()
    for reg, n, depth in ((a, 2, 5), (b, 3, 9)):
        reg.counter("jobs").inc(n)
        reg.gauge("depth").set(depth)
        h = reg.histogram("lat", buckets=(1.0, 10.0))
        for v in (0.5, 2.0):
            h.observe(v * n)
    merged = MetricRegistry.from_snapshots([a.snapshot(), b.snapshot()])
    assert merged.find("jobs").value == 5           # counters sum
    assert merged.find("depth").value == 9          # gauges keep the max
    h = merged.find("lat")
    assert h.count == 4 and h.sum == pytest.approx(1.0 + 4.0 + 1.5 + 6.0)
    # merging histograms with different buckets is refused, not mangled
    c = MetricRegistry()
    c.histogram("lat", buckets=(2.0, 20.0)).observe(1.0)
    with pytest.raises(ValueError):
        merged.merge(c.snapshot())


def test_prometheus_text_format_and_escaping():
    reg = MetricRegistry()
    reg.counter("fleet_jobs", engine='an"ne\\al\n').inc(2)
    reg.gauge("depth", **{"class": "gold"}).set(4)
    h = reg.histogram("lat_ms", buckets=(1.0, 5.0), **{"class": "gold"})
    for v in (0.5, 3.0, 9.0):
        h.observe(v)
    text = prometheus_text(reg)
    assert "# TYPE fleet_jobs_total counter" in text
    assert 'engine="an\\"ne\\\\al\\n"' in text     # escaped label value
    assert 'depth{class="gold"} 4' in text
    # cumulative buckets + +Inf + sum/count triplet
    assert 'lat_ms_bucket{class="gold",le="1"} 1' in text
    assert 'lat_ms_bucket{class="gold",le="5"} 2' in text
    assert 'lat_ms_bucket{class="gold",le="+Inf"} 3' in text
    assert 'lat_ms_count{class="gold"} 3' in text


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------
def _fixed_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return clock


def test_span_nesting_and_deterministic_ids(tmp_path):
    def run(root):
        tr = Tracer(root, clock=_fixed_clock(), process_tag="w0")
        with tr.span("fleet.job", engine="anneal") as outer:
            with tr.span("search.run"):
                pass
            outer.set(status="ok")
        tr.event("serve.swap", reason="qos-load")
        tr.close()
        return read_trace(root)

    spans_a = run(tmp_path / "a")
    spans_b = run(tmp_path / "b")
    # injected clock + pinned tag -> byte-identical traces across runs
    assert spans_a == spans_b
    by_name = {s["name"]: s for s in spans_a}
    assert by_name["search.run"]["parent"] == by_name["fleet.job"]["id"]
    assert by_name["serve.swap"]["parent"] is None
    assert by_name["fleet.job"]["attrs"] == {"engine": "anneal",
                                             "status": "ok"}
    assert by_name["fleet.job"]["dur_s"] == pytest.approx(3.0)
    assert len({s["id"] for s in spans_a}) == 3


def test_trace_merge_is_idempotent_and_skips_torn_lines(tmp_path):
    tr = Tracer(tmp_path, clock=_fixed_clock(), process_tag="w0")
    for i in range(3):
        tr.event("tick", i=i)
    tr.close()
    spans = read_trace(tmp_path)
    assert len(spans) == 3
    # a crashed writer tears at most the trailing line; reader skips it
    src = tmp_path / "spans-w0.jsonl"
    with open(src, "a") as f:
        f.write('{"name": "torn", "id": "zz')
    # a re-copied file (same span ids) must not double anything
    (tmp_path / "spans-w0-copy.jsonl").write_text(src.read_text())
    assert read_trace(tmp_path) == spans


def test_trace_merge_spans_rotated_segments_across_tags(tmp_path):
    """Two writers (a router's engines, a fleet's workers) rotating into
    one trace dir: the merge unions every active AND rotated segment of
    every tag, stays deduped under re-copied rotated files, and keeps
    the global (t0, id) order."""
    clock = _fixed_clock()
    writers = {name: Tracer(tmp_path, clock=clock, process_tag=name,
                            max_segment_bytes=256)   # a few lines/segment
               for name in ("eng-a", "eng-b")}
    for i in range(30):
        writers["eng-a" if i % 2 == 0 else "eng-b"].event(
            "tick", i=i, pad="x" * 32)
    for tr in writers.values():
        tr.close()

    # both tags actually rotated — otherwise the test is vacuous
    for name in writers:
        rotated = list(tmp_path.glob(f"spans-{name}.*.jsonl"))
        assert rotated, f"{name} never rotated"
        assert (tmp_path / f"spans-{name}.jsonl").exists()

    spans = read_trace(tmp_path)
    assert [s["attrs"]["i"] for s in spans] == list(range(30))
    assert len({s["id"] for s in spans}) == 30

    # re-copying a rotated segment (backup restore, scp -r twice) must
    # not double its spans
    seg = sorted(tmp_path.glob("spans-eng-a.*.jsonl"))[0]
    (tmp_path / "spans-eng-a-restored.jsonl").write_text(seg.read_text())
    assert read_trace(tmp_path) == spans


def test_global_tracer_configure_and_noop(tmp_path):
    # unconfigured: spans are free no-ops, handles still accept set()
    assert not obs_trace.tracing_enabled()
    with obs_trace.span("x") as sp:
        sp.set(ok=True)
    obs_trace.event("y")
    assert list(tmp_path.glob("spans-*.jsonl")) == []

    import os
    obs_trace.configure(tmp_path, clock=_fixed_clock(), process_tag="t")
    assert os.environ[obs_trace.TRACE_DIR_ENV] == str(tmp_path)
    with obs_trace.span("job"):
        pass
    assert [s["name"] for s in read_trace(tmp_path)] == ["job"]
    obs_trace.reset()
    assert os.environ.get(obs_trace.TRACE_DIR_ENV) is None


def test_metric_snapshots_roundtrip_through_trace_dir(tmp_path):
    reg = MetricRegistry()
    reg.counter("jobs", engine="anneal").inc(4)
    dump_metrics(tmp_path, reg, tag="w0")
    reg2 = MetricRegistry()
    reg2.counter("jobs", engine="anneal").inc(1)
    dump_metrics(tmp_path, reg2, tag="w1")
    merged = read_metrics(tmp_path)
    assert merged.find("jobs", engine="anneal").value == 5


# ---------------------------------------------------------------------------
# telemetry on the metric core
# ---------------------------------------------------------------------------
def _record_batches(tel, n, *, cls=None, decode_s=0.2):
    for b in range(n):
        tel.record_batch(batch=b, tick=b, n_requests=2, prefill_s=0.1,
                         decode_s=decode_s, prefill_tokens=8,
                         decode_tokens=16, decode_steps=8, plan_id="p",
                         drift=0.01, qos_class=cls)


def test_telemetry_per_class_percentiles_and_isolation():
    from repro.serving.telemetry import Telemetry

    tel = Telemetry()
    _record_batches(tel, 4, cls="gold", decode_s=0.08)
    _record_batches(tel, 4, cls="batch", decode_s=0.8)
    s = tel.summary()
    assert s["batches"] == 8 and set(s["classes"]) == {"gold", "batch"}
    gold, batch = s["classes"]["gold"], s["classes"]["batch"]
    for row in (gold, batch):
        for k in ("p50_ms_per_step", "p95_ms_per_step", "p99_ms_per_step"):
            assert k in row
    assert gold["p95_ms_per_step"] == pytest.approx(10.0)
    assert batch["p95_ms_per_step"] == pytest.approx(100.0)
    assert s["latency_ms_per_step"]["p99"] <= 100.0
    # two Telemetry instances never share counters
    assert Telemetry().summary()["batches"] == 0


def test_telemetry_dump_is_atomic_and_creates_parents(tmp_path):
    from repro.serving.telemetry import Telemetry

    tel = Telemetry(capacity=2)
    _record_batches(tel, 5)
    tel.record_queue("gold", 3, [0.01, 0.02])
    out = tmp_path / "deep" / "nested" / "tele.json"
    doc = tel.dump(out)
    on_disk = json.loads(out.read_text())
    assert on_disk == json.loads(json.dumps(doc))
    assert len(on_disk["events"]) == 2                 # ring stayed bounded
    assert on_disk["summary"]["batches"] == 5          # counters did not
    # no leftover temp files from the atomic write
    assert [p.name for p in out.parent.iterdir()] == ["tele.json"]
    assert tel.registry.find("serve_queue_depth",
                             **{"class": "gold"}).value == 3
    assert tel.registry.find("serve_queue_wait_s",
                             **{"class": "gold"}).count == 2


def test_class_scheduler_backoff_metrics():
    from repro.sensitivity.classes import ClassBook, ClassScheduler

    class _Plan:
        def __init__(self, p):
            self.predicted_total = p

    class _Ladder:
        plans = [_Plan(0.0), _Plan(0.1), _Plan(0.5)]

        def __len__(self):
            return len(self.plans)

    reg = MetricRegistry()
    s = ClassScheduler(ClassBook.parse("gold:0.2,batch:2.0"), _Ladder(),
                       relax_patience=1, registry=reg)
    assert s.observe("gold", 10.0)     # overrun -> tighten
    assert reg.find("class_backoff_moves_total", move="tighten",
                    **{"class": "gold"}).value == 1
    assert reg.find("class_backoff_level", **{"class": "gold"}).value == 1
    assert s.observe("gold", 0.0)      # calm -> relax
    assert reg.find("class_backoff_moves_total", move="relax",
                    **{"class": "gold"}).value == 1
    assert reg.find("class_backoff_level", **{"class": "gold"}).value == 0


# ---------------------------------------------------------------------------
# instrumented search + fleet
# ---------------------------------------------------------------------------
def test_fleet_job_spans_and_receipt_timing(tmp_path):
    from repro.core.engine import SearchJob
    from repro.fleet.worker import RECEIPT_DIR, run_job

    trace_dir = tmp_path / "trace"
    obs_trace.configure(trace_dir, process_tag="w0")
    job = SearchJob("adder", 2, 1, "anneal", budget_s=5.0)
    res = run_job(job, tmp_path / "lib",
                  engine_opts={"anneal": {"steps": 300, "restarts": 1}})
    assert res.status == "ok" and res.stats["steps"] > 0

    receipts = list((tmp_path / "lib" / RECEIPT_DIR).glob("*.json"))
    assert len(receipts) == 1
    receipt = json.loads(receipts[0].read_text())
    assert receipt["engine_s"] > 0 and receipt["commit_s"] >= 0
    assert receipt["wall_s"] >= receipt["engine_s"]

    spans = {s["name"]: s for s in read_trace(trace_dir)}
    fj = spans["fleet.job"]
    assert fj["attrs"]["engine"] == "anneal"
    assert fj["attrs"]["status"] == "ok"
    assert fj["attrs"]["key"] == job.key()
    assert spans["search.run"]["parent"] == fj["id"]   # nested
    # the worker flushed its metric snapshot into the trace dir
    merged = read_metrics(trace_dir)
    assert merged.find("fleet_jobs_total", engine="anneal",
                       status="ok").value == 1
    assert merged.find("search_evaluations_total",
                       engine="anneal").value > 0


def test_smt_outcome_carries_solver_time():
    z3 = pytest.importorskip("z3")
    from repro.core.engine import SearchJob, get_engine

    out = get_engine("shared").run(
        SearchJob("adder", 2, 1, "shared", budget_s=20.0))
    assert out.stats["grid_points_tried"] > 0
    assert out.stats["smt_solve_s"] > 0
    assert out.stats["smt_solve_s"] <= out.wall_s


# ---------------------------------------------------------------------------
# the inspector CLI
# ---------------------------------------------------------------------------
def _seed_trace(trace_dir):
    tr = Tracer(trace_dir, clock=_fixed_clock(), process_tag="w0")
    with tr.span("fleet.job", engine="anneal", n_results=3):
        pass
    tr.close()
    reg = MetricRegistry()
    from repro.obs.__main__ import MS_PER_STEP_METRIC
    for cls, v in (("_all", 2.0), ("gold", 1.0), ("gold", 3.0)):
        reg.histogram(MS_PER_STEP_METRIC, **{"class": cls}).observe(v)
    dump_metrics(trace_dir, reg, tag="w0")


def test_obs_cli_summary_and_gates(tmp_path, capsys):
    from repro.obs.__main__ import main

    _seed_trace(tmp_path)
    rc = main(["summary", "--trace", str(tmp_path),
               "--require-span", "fleet.job",
               "--require-class-latency"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "fleet.job" in out and "gold" in out
    assert "anneal" in out     # per-engine table

    # missing span -> gate fails
    assert main(["summary", "--trace", str(tmp_path),
                 "--require-span", "serve.decode"]) == 1
    # count-qualified gate
    assert main(["summary", "--trace", str(tmp_path),
                 "--require-span", "fleet.job=2"]) == 1
    assert main(["summary", "--trace", str(tmp_path),
                 "--require-span", "fleet.job=1"]) == 0
    # nonexistent dir -> usage error
    assert main(["summary", "--trace", str(tmp_path / "nope")]) == 2


def test_obs_cli_prom_and_slowest(tmp_path, capsys):
    from repro.obs.__main__ import main

    _seed_trace(tmp_path)
    assert main(["prom", "--trace", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "serve_ms_per_step" in out and 'class="gold"' in out
    assert main(["slowest", "--trace", str(tmp_path),
                 "--name", "fleet"]) == 0
    assert "fleet.job" in capsys.readouterr().out


def test_empty_class_latency_gate_fails(tmp_path):
    from repro.obs.__main__ import main

    tr = Tracer(tmp_path, clock=_fixed_clock(), process_tag="w0")
    tr.event("fleet.job")
    tr.close()
    assert main(["summary", "--trace", str(tmp_path),
                 "--require-class-latency"]) == 1


# ---------------------------------------------------------------------------
# classed serve e2e: spans + per-class p95 + single trace
# ---------------------------------------------------------------------------
def _trunc_mul2() -> Circuit:
    c = Circuit.empty(4, "trunc_mul2")
    a0, a1, b0, b1 = 0, 1, 2, 3
    p0 = c.add(Op.AND, a0, b0)
    p1 = c.add(Op.XOR, c.add(Op.AND, a1, b0), c.add(Op.AND, a0, b1))
    z = c.const(False)
    for out in (p0, p1, z, z):
        c.mark_output(out)
    return c


def test_e2e_classed_serve_traces_and_percentiles(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.library.compile import load_mul_frontier
    from repro.models import init_model
    from repro.obs.__main__ import main as obs_main
    from repro.sensitivity.classes import ClassBook, ClassScheduler
    from repro.serving import PlanLadder, ServingEngine, Telemetry, steady

    lib = tmp_path / "lib"
    store = OperatorStore(lib)
    exact = benchmark("mul_i4")
    exact_vals = exact.eval_words().astype(np.int64)
    for circ in (exact, _trunc_mul2()):
        wce = int(np.abs(circ.eval_words().astype(np.int64)
                         - exact_vals).max())
        store.put_circuit(circ, OperatorSignature("mul", 2, "wce",
                                                  max(1, wce)),
                          area=area(circ), source="test")
    compiled, exact_area, _ = load_mul_frontier(lib)

    trace_dir = tmp_path / "trace"
    obs_trace.configure(trace_dir, process_tag="serve")

    cfg = get_config("gemma3-1b", reduced=True).with_approx_mlp()
    params = init_model(cfg, jax.random.PRNGKey(0))
    ladder = PlanLadder.build(compiled, cfg.n_layers,
                              exact_area=exact_area, levels=4)
    scheduler = ClassScheduler(ClassBook.parse("gold:1e9,batch:1e9"),
                               ladder, shadow_every=2)
    engine = ServingEngine(cfg, params, batch=2, prompt_len=4, gen_len=4,
                           plan=ladder.plan(0), compiled=compiled,
                           exact_area=exact_area)
    profile = steady(4, 3, prompt_len=4, gen_len=4,
                     class_mix=(("gold", 0.5), ("batch", 0.5)))
    tel = engine.serve(profile, scheduler=scheduler, telemetry=Telemetry())

    # the one-trace invariant holds with spans enabled
    assert engine.trace_count == 1
    s = tel.summary()
    assert s["batches"] > 0
    for row in s["classes"].values():
        assert "p95_ms_per_step" in row and row["p95_ms_per_step"] > 0
        assert row["p95_ms_per_step"] >= row["p50_ms_per_step"]

    # spans landed: one serve.batch/prefill/decode per batch
    obs_trace.reset(clear_env=True)
    spans = read_trace(trace_dir)
    counts = {}
    for sp in spans:
        counts[sp["name"]] = counts.get(sp["name"], 0) + 1
    assert counts["serve.batch"] == s["batches"]
    assert counts["serve.prefill"] == s["batches"]
    assert counts["serve.decode"] == s["batches"]
    by_id = {sp["id"]: sp for sp in spans}
    for sp in spans:
        if sp["name"] == "serve.decode":
            assert by_id[sp["parent"]]["name"] == "serve.batch"

    # the CLI gate passes on the dumped per-class metrics
    dump_metrics(trace_dir, tel.registry, tag="serve")
    assert obs_main(["summary", "--trace", str(trace_dir),
                     "--require-span", "serve.decode",
                     "--require-class-latency"]) == 0
