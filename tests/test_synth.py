"""Light synthesizer: function preservation + area monotonicity."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.arith import BENCHMARKS, benchmark
from repro.core.circuits import Circuit, Op
from repro.core.synth import NANGATE45_AREA, area, binarize, synthesize
from repro.core.templates import SharedTemplate


@pytest.mark.parametrize("name", BENCHMARKS)
def test_synthesis_preserves_function(name):
    c = benchmark(name)
    s = synthesize(c)
    assert np.array_equal(c.eval_words(), s.eval_words())


def test_cse_rewards_product_sharing(rng):
    """The pass that makes SHARED win: two identical products collapse."""
    tpl = SharedTemplate(4, 2, pit=2)
    p = tpl.random_params(rng)
    p.lits[1] = p.lits[0]          # duplicate product
    p.sel[:] = [[True, False], [False, True]]  # each output uses "its own"
    circ = synthesize(tpl.instantiate(p))
    # after CSE the duplicated AND tree exists once
    n_and = sum(1 for g in circ.nodes if g.op is Op.AND)
    lits_used = int((p.lits[0] != 2).sum())
    assert n_and <= max(0, lits_used - 1) + 2  # one tree + (<=2) output wiring


def test_constant_folding():
    c = Circuit.empty(2)
    one = c.const(True)
    a = c.add(Op.AND, 0, one)      # AND(x, 1) -> x
    o = c.add(Op.OR, a, c.const(False))
    c.mark_output(o)
    s = synthesize(c)
    assert s.gate_count() == 0     # output is just input 0
    assert np.array_equal(s.eval_words(), c.eval_words())


def test_double_negation():
    c = Circuit.empty(1)
    n1 = c.add(Op.NOT, 0)
    n2 = c.add(Op.NOT, n1)
    c.mark_output(n2)
    s = synthesize(c)
    assert s.gate_count() == 0


def test_inverter_fusion_prefers_cheap_cells():
    c = Circuit.empty(2)
    a = c.add(Op.AND, 0, 1)
    n = c.add(Op.NOT, a)
    c.mark_output(n)
    s = synthesize(c)
    assert any(g.op is Op.NAND for g in s.nodes)
    assert area(s, presynthesized=True) == pytest.approx(NANGATE45_AREA[Op.NAND])


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_synthesis_never_increases_area(seed):
    """vs the binarized raw netlist (n-ary gates are not standard cells)."""
    rng = np.random.default_rng(seed)
    tpl = SharedTemplate(6, 4, pit=6)
    p = tpl.random_params(rng)
    raw = tpl.instantiate(p)
    syn = synthesize(raw)
    assert np.array_equal(raw.eval_words(), syn.eval_words())
    assert area(syn, presynthesized=True) <= area(binarize(raw), presynthesized=True) + 1e-9
