"""AdamW optimizer + schedule unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optim import (
    OptimizerConfig, apply_updates, global_norm, init_opt_state, schedule,
)


def _params():
    return {"a": jnp.ones((4, 4), jnp.bfloat16), "b": jnp.zeros((3,), jnp.float32)}


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]          # warming up
    assert lrs[2] >= lrs[3] >= lrs[4]        # decaying
    assert lrs[4] >= 0.1 * cfg.lr * 0.99     # floor at 10%


def test_clip_bounds_update_norm():
    cfg = OptimizerConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    p = _params()
    st = init_opt_state(p)
    g = jax.tree.map(lambda x: jnp.full(x.shape, 100.0, jnp.float32), p)
    _, _, metrics = apply_updates(cfg, p, g, st)
    assert float(metrics["grad_norm"]) > 100
    # clipped: effective grad norm is 1 -> first-step Adam update ~ lr * sign
    # just check no explosion
    newp, _, _ = apply_updates(cfg, p, g, st)
    assert all(np.isfinite(np.asarray(x, dtype=np.float32)).all()
               for x in jax.tree.leaves(newp))


def test_moments_are_f32_and_sharded_like_params():
    p = _params()
    st = init_opt_state(p)
    for leaf in jax.tree.leaves(st["mu"]):
        assert leaf.dtype == jnp.float32
    assert jax.tree.structure(st["mu"]) == jax.tree.structure(p)


def test_weight_decay_shrinks_weights():
    cfg = OptimizerConfig(lr=1e-2, weight_decay=0.5, clip_norm=1e9)
    p = {"w": jnp.full((8,), 2.0, jnp.float32)}
    st = init_opt_state(p)
    g = {"w": jnp.zeros((8,), jnp.float32)}
    newp, _, _ = apply_updates(cfg, p, g, st)
    assert float(newp["w"][0]) < 2.0


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))
