"""repro.sensitivity: profiler determinism, online-EWMA convergence,
class-budget isolation, mixed-width plan/stack validation, and the
end-to-end class-aware mixed-width serve with a single decode trace."""

import copy
import json

import numpy as np
import pytest

from repro.core.arith import benchmark
from repro.core.circuits import Circuit, Op
from repro.core.synth import area
from repro.library import OperatorSignature, OperatorStore, select_plan
from repro.library.qos import validate_lut_stack
from repro.precision.plans import (
    build_mixed_ladder,
    choose_mixed_budget,
    exact_mixed_stacks,
    group_layers,
    load_mixed_frontier,
    mixed_comparison,
    select_width_map,
    width_of_key,
)
from repro.precision.widths import exact_table
from repro.sensitivity import (
    ClassBook,
    ClassScheduler,
    OnlineSensitivity,
    parse_class_mix,
)
from repro.sensitivity.profile import (
    SensitivityProfile,
    costs_for,
    truncation_probe,
)
from repro.serving.loadgen import make_profile, synth_requests


# ---------------------------------------------------------------------------
# handcrafted operators: a deterministic two-width frontier
# ---------------------------------------------------------------------------
def trunc_mul2() -> Circuit:
    """Exact low 2 product bits, upper bits dropped."""
    c = Circuit.empty(4, "trunc_mul2")
    a0, a1, b0, b1 = 0, 1, 2, 3
    p0 = c.add(Op.AND, a0, b0)
    p1 = c.add(Op.XOR, c.add(Op.AND, a1, b0), c.add(Op.AND, a0, b1))
    z = c.const(False)
    for out in (p0, p1, z, z):
        c.mark_output(out)
    return c


def trunc_mul4() -> Circuit:
    """The exact 4-bit multiplier with its two low product bits zeroed."""
    c = copy.deepcopy(benchmark("mul_i8"))
    c.name = "trunc_mul4"
    z = c.const(False)
    c.outputs[0] = z
    c.outputs[1] = z
    return c


@pytest.fixture()
def mixed_library(tmp_path):
    """One 4-bit block (modest saving, low error) + one 2-bit block (tiny
    area, coarse): the native frontier holds the 4-bit block, the
    composed W8A8 frontier prices both — a real two-width trade."""
    root = tmp_path / "lib"
    store = OperatorStore(root)
    a4 = area(benchmark("mul_i8"))
    t4 = trunc_mul4()
    exact4 = benchmark("mul_i8").eval_words().astype(np.int64)
    w4 = int(np.abs(t4.eval_words().astype(np.int64) - exact4).max())
    store.put_circuit(t4, OperatorSignature("mul", 4, "wce", max(1, w4)),
                      area=0.6 * a4, source="test")
    t2 = trunc_mul2()
    exact2 = benchmark("mul_i4").eval_words().astype(np.int64)
    w2 = int(np.abs(t2.eval_words().astype(np.int64) - exact2).max())
    store.put_circuit(t2, OperatorSignature("mul", 2, "wce", max(1, w2)),
                      area=2.0, source="test")
    return root


# ---------------------------------------------------------------------------
# probes / offline profile
# ---------------------------------------------------------------------------
def test_truncation_probe_deterministic_and_sound():
    for bits in (4, 8):
        p1 = truncation_probe(bits)
        p2 = truncation_probe(bits)
        np.testing.assert_array_equal(p1.lut, p2.lut)
        assert p1.mae == p2.mae > 0
        side = 1 << bits
        assert p1.lut.shape == (side, side)
        # truncation keeps the high product bits exact
        exact = exact_table("mul", bits)
        assert ((exact - p1.lut) >= 0).all()
        assert ((exact - p1.lut) < (1 << p1.drop)).all()


@pytest.fixture(scope="module")
def reduced_model():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_model

    cfg = get_config("gemma3-1b", reduced=True).with_approx_mlp()
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    return cfg, params, batch


def test_profile_deterministic_and_roundtrips(reduced_model, tmp_path):
    from repro.sensitivity.profile import load_profile, measure_profile

    cfg, params, batch = reduced_model
    p1 = measure_profile(cfg, params, batch, widths=(4,))
    p2 = measure_profile(cfg, params, batch, widths=(4,))
    np.testing.assert_array_equal(p1.sens[4], p2.sens[4])
    assert (p1.sens[4] > 0).all()

    path = p1.save(tmp_path / "prof.json")
    back = load_profile(path)
    assert back.model == cfg.name and back.n_layers == cfg.n_layers
    np.testing.assert_allclose(back.sens[4], p1.sens[4])
    # the JSON document is plain data (re-serializable)
    json.loads(path.read_text())


def test_profile_measures_frontier_cost_matrix(reduced_model, mixed_library):
    from repro.library.compile import load_mul_frontier
    from repro.sensitivity.profile import measure_profile

    cfg, params, batch = reduced_model
    prof = measure_profile(cfg, params, batch, widths=(4,),
                           library=mixed_library)
    keys, matrix = prof.costs[4]
    compiled, _, _ = load_mul_frontier(mixed_library)
    assert keys == [rec.key for rec, _ in compiled]
    assert matrix.shape == (cfg.n_layers, len(compiled))
    assert (matrix >= 0).all()

    # costs_for: measured columns for known keys, linear fallback otherwise
    costs = costs_for(prof, 4, compiled, cfg.n_layers)
    np.testing.assert_allclose(costs, matrix)
    import dataclasses

    fake = [(dataclasses.replace(rec, key="unseen"), comp)
            for rec, comp in compiled]
    lin = costs_for(prof, 4, fake, cfg.n_layers)
    np.testing.assert_allclose(
        lin, prof.sens[4][:, None]
        * np.array([c.mae for _, c in compiled])[None, :])


# ---------------------------------------------------------------------------
# online estimator
# ---------------------------------------------------------------------------
def test_online_converges_to_offline_on_synthetic_drift():
    true = np.array([4.0, 1.0, 0.25])
    est = OnlineSensitivity(3, alpha=0.5)
    # varied plans (the controller/class traffic walking the ladder):
    # each sample's drift is the offline model's prediction for that plan
    plans = [np.array([0.5, 0.0, 0.0]),      # layer-isolating samples
             np.array([0.0, 0.5, 0.0]),
             np.array([0.0, 0.0, 0.5]),
             np.array([0.5, 0.5, 0.5])]      # and a joint one
    for _ in range(12):
        for maes in plans:
            est.update(maes, float((true * maes).sum()))
    np.testing.assert_allclose(est.sensitivities(), true, rtol=1e-3)
    assert est.n_updates == 48


def test_online_ignores_exact_samples_and_seeds_from_profile():
    prof = SensitivityProfile(model="m", n_layers=2,
                              sens={4: np.array([2.0, 0.5]),
                                    8: np.array([1.0, 1.0])})
    est = OnlineSensitivity.from_profile(prof, 4)
    np.testing.assert_allclose(est.sensitivities(), [2.0, 0.5])
    est.update(np.zeros(2), 123.0)          # all-exact: no signal
    assert est.n_updates == 0
    np.testing.assert_allclose(est.sensitivities(), [2.0, 0.5])
    mixed = OnlineSensitivity.from_profile(prof, None, width_map=(4, 8))
    np.testing.assert_allclose(mixed.sensitivities(), [2.0, 1.0])


# ---------------------------------------------------------------------------
# QoS classes
# ---------------------------------------------------------------------------
def test_classbook_parse_priority_and_routing():
    book = ClassBook.parse("gold:0.02,std:0.05,batch:0.2")
    assert book.names == ("gold", "std", "batch")     # listed order drains
    assert book.get("gold").drift_budget == 0.02
    assert book.route("std") == "std"
    assert book.route("nosuch") == "batch"            # best-effort tier
    with pytest.raises(ValueError, match="bad class spec"):
        ClassBook.parse("gold=0.02")
    mix = parse_class_mix("gold:1,batch:3")
    assert mix == (("gold", 0.25), ("batch", 0.75))


def test_loadgen_class_mix_tags_without_touching_tokens():
    p_plain = make_profile("steady", ticks=3, per_tick=8, prompt_len=6,
                           gen_len=2)
    p_mix = make_profile("steady", ticks=3, per_tick=8, prompt_len=6,
                         gen_len=2,
                         class_mix=(("gold", 0.25), ("batch", 0.75)))
    r_plain = synth_requests(p_plain, vocab_size=64, seed=7)
    r_mix = synth_requests(p_mix, vocab_size=64, seed=7)
    flat_p = [r for tick in r_plain for r in tick]
    flat_m = [r for tick in r_mix for r in tick]
    for a, b in zip(flat_p, flat_m):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.qos_class == "std"
    classes = {r.qos_class for r in flat_m}
    assert classes <= {"gold", "batch"} and len(classes) == 2
    # deterministic tagging
    r_mix2 = synth_requests(p_mix, vocab_size=64, seed=7)
    assert [r.qos_class for tick in r_mix2 for r in tick] == \
        [r.qos_class for r in flat_m]


def _toy_ladder(preds):
    """A stand-in ladder: only .plans[i].predicted_total and len() are
    read by the scheduler's cap computation."""
    class P:
        def __init__(self, t):
            self.predicted_total = t

    class Ladder:
        def __init__(self):
            self.plans = [P(t) for t in preds]

        def __len__(self):
            return len(self.plans)
    return Ladder()


def test_class_budget_isolation():
    """Tightening ``batch`` (budget or measured backoff) never changes
    ``gold``'s level — the per-class state is disjoint."""
    ladder = _toy_ladder([0.0, 0.01, 0.1, 1.0])
    loose = ClassScheduler(ClassBook.parse("gold:0.05,batch:2.0"), ladder)
    tight = ClassScheduler(ClassBook.parse("gold:0.05,batch:0.005"), ladder)
    for g in range(4):
        assert loose.level_for("gold", g) == tight.level_for("gold", g)
    assert tight.cap("batch") < loose.cap("batch")

    # measured overruns on batch back off batch only
    before = loose.level_for("gold", 3)
    for _ in range(5):
        loose.observe("batch", 100.0)
    assert loose.level_for("gold", 3) == before
    assert loose.cap("batch") < 3

    # and gold's own overrun does not touch batch
    batch_cap = tight.cap("batch")
    tight.observe("gold", 1.0)
    assert tight.cap("batch") == batch_cap


def test_class_scheduler_caps_and_relax():
    ladder = _toy_ladder([0.0, 0.01, 0.1, 1.0])
    book = ClassBook.parse("gold:0.05,batch:2.0")
    s = ClassScheduler(book, ladder, relax_patience=2)
    assert s.cap("gold") == 1 and s.cap("batch") == 3
    assert s.level_for("gold", 3) == 1       # global level capped
    assert s.level_for("batch", 2) == 2      # global level binds
    assert s.observe("batch", 50.0)          # overrun: tighten
    assert s.cap("batch") == 2
    for _ in range(2):
        s.observe("batch", 0.0)              # sustained headroom: relax
    assert s.cap("batch") == 3


def test_class_shadow_cadence_is_per_class():
    """Shadow sampling counts each class's own batches — a class that
    always lands on odd global batch indices still gets measured."""
    ladder = _toy_ladder([0.0, 1.0])
    s = ClassScheduler(ClassBook.parse("gold:1,batch:1"), ladder,
                       shadow_every=2)
    # interleaved drain: gold, batch, gold, batch, ...
    got = [(name, s.wants_shadow(name))
           for name in ("gold", "batch") * 3]
    assert got == [("gold", True), ("batch", True),
                   ("gold", False), ("batch", False),
                   ("gold", True), ("batch", True)]


def test_class_spec_validation_raises():
    with pytest.raises(ValueError, match="negative drift budget"):
        ClassBook.parse("gold:-0.1")
    with pytest.raises(ValueError, match="duplicate"):
        ClassBook.parse("gold:0.1,gold:0.2")
    with pytest.raises(ValueError, match="negative fraction"):
        parse_class_mix("gold:-1,std:2")
    with pytest.raises(ValueError, match="sums to 0"):
        parse_class_mix("gold:0,std:0")


# ---------------------------------------------------------------------------
# allowed-mask selection (library.qos generalization)
# ---------------------------------------------------------------------------
def test_select_plan_respects_allowed_mask(mixed_library):
    mixed = load_mixed_frontier(mixed_library)
    costs = np.ones((3, len(mixed.compiled)))
    allowed = np.zeros((3, len(mixed.compiled)), dtype=bool)
    allowed[:, 0] = True                     # only the first operator
    plan = select_plan(mixed.compiled, costs, 1e9,
                       exact_area=mixed.exact_area(4), allowed=allowed)
    keys = {c.key for c in plan.choices}
    assert keys <= {None, mixed.compiled[0][0].key}


# ---------------------------------------------------------------------------
# mixed-width plans
# ---------------------------------------------------------------------------
@pytest.fixture()
def mixed_setup(mixed_library):
    mixed = load_mixed_frontier(mixed_library)
    n_layers = 4
    # layer 0 is 10x more sensitive: it must stay on the native tile
    sens = {b: np.array([10.0, 1.0, 1.0, 1.0]) for b in mixed.widths}
    return mixed, sens, n_layers


def test_mixed_plan_beats_best_uniform_at_equal_budget(mixed_setup):
    """The acceptance pin: at the auto-chosen budget the mixed plan uses
    both widths and its composed area is *strictly* below the best
    uniform-width plan's."""
    mixed, sens, L = mixed_setup
    budget = choose_mixed_budget(mixed, sens, L)
    report, width_map, plan = mixed_comparison(mixed, sens, budget, L)
    assert set(width_map) == {4, 8}
    assert report["mixed_area"] < report["best_uniform_area"]
    assert report["mixed_area"] == pytest.approx(plan.total_area)
    # the sensitive layer kept its native tile
    assert width_map[0] == 4
    assert plan.predicted_total <= budget


def test_width_map_and_stacks(mixed_setup):
    mixed, sens, L = mixed_setup
    budget = choose_mixed_budget(mixed, sens, L)
    width_map, plan = select_width_map(mixed, sens, budget, L)
    for c in plan.choices:
        assert width_of_key(c.key, mixed.native_bits) == width_map[c.layer]
    ladder = build_mixed_ladder(mixed, width_map, sens, levels=4)
    stacks = ladder.luts(len(ladder) - 1)
    assert set(stacks) == set(width_map)
    for bits, arr in stacks.items():
        side = 1 << bits
        assert arr.shape == (len(group_layers(width_map, bits)), side, side)
        assert arr.dtype == np.int32
    # level 0 is all-exact: group stacks equal the exact mixed stacks
    exact = exact_mixed_stacks(width_map)
    for bits, arr in ladder.luts(0).items():
        np.testing.assert_array_equal(arr, exact[bits])


def test_mixed_ladder_monotone_and_width_frozen(mixed_setup):
    mixed, sens, L = mixed_setup
    budget = choose_mixed_budget(mixed, sens, L)
    width_map, _ = select_width_map(mixed, sens, budget, L)
    ladder = build_mixed_ladder(mixed, width_map, sens, levels=4)
    areas = [p.total_area for p in ladder.plans]
    drifts = [p.predicted_total for p in ladder.plans]
    assert all(a > b for a, b in zip(areas, areas[1:])), areas
    assert all(a <= b for a, b in zip(drifts, drifts[1:])), drifts
    # every level's non-exact choices stay inside the frozen width map
    for p in ladder.plans:
        for c in p.choices:
            if c.key is not None:
                assert width_of_key(c.key) == width_map[c.layer]


def test_validate_lut_stack_mixed_groups():
    a = {4: np.zeros((2, 16, 16), np.int32),
         8: np.zeros((1, 256, 256), np.int32)}
    b = {4: np.ones((2, 16, 16), np.int32),
         8: np.ones((1, 256, 256), np.int32)}
    validate_lut_stack(a, b)                  # same groups: fine
    with pytest.raises(ValueError, match="width map is frozen"):
        validate_lut_stack(a, {4: a[4]})      # a group vanished
    with pytest.raises(ValueError, match="refusing"):
        validate_lut_stack(a, {4: a[4], 8: np.zeros((2, 256, 256),
                                                    np.int32)})
    with pytest.raises(ValueError, match="width map is frozen"):
        validate_lut_stack(a[4], b)           # uniform vs mixed


# ---------------------------------------------------------------------------
# end-to-end: class-aware mixed-width adaptive serve, one trace
# ---------------------------------------------------------------------------
def zero_mul2() -> Circuit:
    """Constant-zero 2-bit multiplier — a mid-serve fleet arrival."""
    c = Circuit.empty(4, "zero_mul2")
    z = c.const(False)
    for _ in range(4):
        c.mark_output(z)
    return c


def test_e2e_mixed_class_serve_single_trace(mixed_library):
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_model
    from repro.serving import (ControllerConfig, LibraryWatcher,
                               QoSController, ServingEngine, Telemetry,
                               steady)

    mixed = load_mixed_frontier(mixed_library)
    cfg = get_config("gemma3-1b", reduced=True).with_approx_mlp()
    L = cfg.n_layers
    params = init_model(cfg, jax.random.PRNGKey(0))
    sens_vecs = {b: np.array([10.0] + [1.0] * (L - 1))
                 for b in mixed.widths}
    # a measured profile (linear model only): the engine re-prices the
    # refreshed frontier through it when the watcher fires mid-serve
    prof = SensitivityProfile(model=cfg.name, n_layers=L, sens=sens_vecs)
    budget = choose_mixed_budget(mixed, sens_vecs, L)
    width_map, _ = select_width_map(mixed, sens_vecs, budget, L)
    assert set(width_map) == {4, 8}
    ladder = build_mixed_ladder(mixed, width_map, sens_vecs, levels=4)

    book = ClassBook.parse("gold:1.0,batch:1e9")
    scheduler = ClassScheduler(book, ladder, shadow_every=1)
    ctrl = QoSController(ladder, ControllerConfig(
        target_ms_per_step=1e-6, drift_budget=1e9, patience=1, cooldown=0,
        shadow_every=1, ewma_alpha=1.0))
    online = OnlineSensitivity(L)
    watcher = LibraryWatcher(mixed_library, min_poll_s=0.0,
                             widths=mixed.widths)
    store = OperatorStore(mixed_library)

    def densify_midrun(engine, batch_idx):
        if batch_idx == 1:   # a background fleet sweep lands a cheaper op
            circ = zero_mul2()
            store.put_circuit(circ, OperatorSignature("mul", 2, "wce", 9),
                              area=area(circ), source="fleet")

    engine = ServingEngine(cfg, params, batch=2, prompt_len=4, gen_len=4,
                           plan=ladder.plan(0), compiled=mixed.compiled,
                           sensitivities=sens_vecs, width_map=width_map,
                           sens_profile=prof)
    profile = steady(4, 3, prompt_len=4, gen_len=4,
                     class_mix=(("gold", 0.5), ("batch", 0.5)))
    tel = engine.serve(profile, controller=ctrl, scheduler=scheduler,
                       watcher=watcher, online=online,
                       telemetry=Telemetry(), on_batch_end=densify_midrun)

    # one trace across every class stack, controller move and refresh
    assert engine.trace_count == 1
    s = tel.summary()
    classes = s["classes"]
    assert set(classes) == {"gold", "batch"}
    for name, row in classes.items():
        assert row["drift_samples"] >= 1
        assert row["mean_drift"] <= book.get(name).drift_budget
    # gold decodes more exactly than batch in the same serve
    assert classes["gold"]["mean_drift"] <= classes["batch"]["mean_drift"]
    # the load-driven controller walked the global ladder
    assert any(r.startswith("qos-") for r in s["swaps_by_reason"])
    # the mid-serve store put was picked up: the scheduler's ladder now
    # prices the composed arrival (refresh survived the changed operator
    # count because the engine re-priced through its profile)
    assert watcher.refreshes >= 1
    assert len(scheduler.ladder.compiled) > len(mixed.compiled)
    # online estimator folded the shadow samples in
    assert online.n_updates >= 1
