"""Template semantics: eval == instantiate == synthesized instantiate."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.miter import values_from_tables
from repro.core.synth import synthesize
from repro.core.templates import (
    IGNORE, NEG, USE, NonsharedTemplate, SharedTemplate, TemplateParams,
)


@pytest.mark.parametrize("cls,kw", [
    (SharedTemplate, {"pit": 5}),
    (NonsharedTemplate, {"ppo": 3}),
])
def test_eval_matches_instantiation(cls, kw, rng):
    tpl = cls(4, 3, **kw)
    for _ in range(100):
        p = tpl.random_params(rng)
        direct = values_from_tables(tpl.eval_outputs(p), 4)
        circ = tpl.instantiate(p)
        assert np.array_equal(direct, circ.eval_words())
        assert np.array_equal(direct, synthesize(circ).eval_words())


def test_shared_template_is_as_expressive_as_nonshared(rng):
    """Any nonshared instantiation is representable in the shared template
    with T = m*K (paper §II.C: expressiveness is preserved)."""
    ns = NonsharedTemplate(4, 3, ppo=2)
    for _ in range(50):
        p = ns.random_params(rng)
        # flatten banks into a global pool; select per output
        T = 3 * 2
        lits = p.lits.reshape(T, 4)
        sel = np.zeros((3, T), dtype=bool)
        for i in range(3):
            sel[i, i * 2:(i + 1) * 2] = p.sel[i]
        sh = SharedTemplate(4, 3, pit=T)
        sp = TemplateParams(lits, sel)
        assert np.array_equal(
            values_from_tables(ns.eval_outputs(p), 4),
            values_from_tables(sh.eval_outputs(sp), 4),
        )


def test_proxies_shared():
    tpl = SharedTemplate(4, 2, pit=4)
    lits = np.full((4, 4), IGNORE, dtype=np.int8)
    lits[0, 0] = USE
    lits[1, :2] = NEG
    sel = np.array([[1, 1, 0, 0], [0, 1, 0, 0]], dtype=bool)
    prox = tpl.proxies(TemplateParams(lits, sel))
    assert prox == {"PIT": 2, "ITS": 2}


def test_proxies_nonshared():
    tpl = NonsharedTemplate(4, 2, ppo=3)
    lits = np.full((2, 3, 4), IGNORE, dtype=np.int8)
    lits[0, 0, :3] = USE
    sel = np.zeros((2, 3), dtype=bool)
    sel[0, 0] = True
    sel[0, 1] = True
    prox = tpl.proxies(TemplateParams(lits, sel))
    assert prox == {"LPP": 3, "PPO": 2}


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_constant_one_product_saturates(seed):
    """An all-IGNORE product selected into a sum makes that output constant 1
    (Eq. 2's ⊤ member)."""
    rng = np.random.default_rng(seed)
    tpl = SharedTemplate(4, 2, pit=3)
    p = tpl.random_params(rng)
    p.lits[0, :] = IGNORE
    p.sel[0, 0] = True
    vals = values_from_tables(tpl.eval_outputs(p), 4)
    assert bool(np.all(vals & 1 == 1))
