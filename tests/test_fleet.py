"""Fleet invariants: deterministic planning, concurrent-writer safety,
and the end-to-end smoke sweep densifying the frontier."""

import dataclasses
import json
import multiprocessing

import numpy as np
import pytest

from repro.core.arith import benchmark
from repro.core.engine import (
    Candidate,
    SearchJob,
    SearchOutcome,
    UnsoundResultError,
    available_engines,
    get_engine,
    harvest,
    verify_circuit,
)
from repro.core.templates import SharedTemplate, TemplateParams
from repro.fleet import SweepSpec, load_spec, plan_jobs, run_job, run_sweep
from repro.fleet.worker import RECEIPT_DIR
from repro.library import OperatorSignature, OperatorStore, frontier_sizes

SPEC = SweepSpec(
    name="test",
    benchmarks=("adder", "mul"),
    bits=(2,),
    ets=(2,),
    engines=("anneal",),
    budget_s=30.0,
    engine_opts={"anneal": {"steps": 3000, "restarts": 2, "keep": 3}},
)


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------
def test_plan_expansion_is_deterministic_and_seed_stable():
    jobs1 = plan_jobs(SPEC)
    jobs2 = plan_jobs(SPEC)
    assert jobs1 == jobs2
    assert len(jobs1) == 2  # 2 benchmarks x 1 bits x 1 et x 1 engine
    assert [j.benchmark for j in jobs1] == ["adder", "mul"]

    # per-job seeds derive from the job's own fields: adding a benchmark
    # must not reshuffle the seeds of existing jobs
    wider = dataclasses.replace(SPEC, ets=(1, 2))
    by_fields = {(j.benchmark, j.bits, j.et, j.engine): j.seed
                 for j in plan_jobs(wider)}
    for j in jobs1:
        assert by_fields[(j.benchmark, j.bits, j.et, j.engine)] == j.seed

    # a different base seed changes every job seed, nothing else
    reseeded = plan_jobs(dataclasses.replace(SPEC, seed=1))
    assert [(j.benchmark, j.et) for j in reseeded] == [(j.benchmark, j.et) for j in jobs1]
    assert all(a.seed != b.seed for a, b in zip(reseeded, jobs1))


def test_plan_et_fracs_scale_with_operator_range():
    spec = SweepSpec(name="t", benchmarks=("mul",), bits=(2,),
                     et_fracs=(0.25,), engines=("anneal",))
    (job,) = plan_jobs(spec)
    assert job.et == round(0.25 * 9)  # 2-bit mul: max value 3*3
    spec_a = dataclasses.replace(spec, benchmarks=("adder",))
    (job_a,) = plan_jobs(spec_a)
    assert job_a.et == round(0.25 * 6)  # 2-bit adder: max value 3+3


def test_load_spec_rejects_unknown_engine_and_missing_grid():
    with pytest.raises(ValueError, match="unknown engine"):
        SweepSpec(name="t", benchmarks=("mul",), bits=(2,), ets=(1,),
                  engines=("no-such-engine",))
    with pytest.raises(ValueError, match="neither ets nor et_fracs"):
        SweepSpec(name="t", benchmarks=("mul",), bits=(2,),
                  engines=("anneal",))
    assert load_spec("smoke").name == "smoke"
    assert load_spec("smoke", budget_s=1.0).budget_s == 1.0
    with pytest.raises(FileNotFoundError):
        load_spec("no-such-sweep")


# ---------------------------------------------------------------------------
# unified engine layer
# ---------------------------------------------------------------------------
def test_job_key_is_stable_and_field_sensitive():
    j = SearchJob(benchmark="mul", bits=2, et=1, engine="anneal")
    assert j.key() == SearchJob(benchmark="mul", bits=2, et=1,
                                engine="anneal").key()
    assert j.key() != dataclasses.replace(j, et=2).key()
    assert j.signature() == OperatorSignature("mul", 2, "wce", 1)
    assert j.benchmark_name == "mul_i4"


def test_anneal_engine_emits_verified_candidates():
    job = SearchJob(benchmark="adder", bits=2, et=2, engine="anneal",
                    budget_s=20.0, seed=1)
    out = get_engine("anneal", steps=3000, restarts=2).run(job)
    assert isinstance(out, SearchOutcome) and out.engine == "anneal"
    assert out.results, "annealer found nothing at the easy ET"
    exact = benchmark("adder_i4").eval_words().astype(np.int64)
    for cand in out.results:
        assert isinstance(cand, Candidate)
        got = cand.circuit.eval_words().astype(np.int64)
        assert np.abs(got - exact).max() <= 2
    assert out.best.area == min(c.area for c in out.results)


def test_harvest_raises_descriptive_error_on_unsound_params():
    """The shared harvest replaces the old bare asserts: an unsound result
    must name the engine and the measured violation."""
    exact = benchmark("adder_i4")
    tpl = SharedTemplate(exact.n_inputs, exact.n_outputs, pit=2)
    # all-IGNORE products selected everywhere => constant-1 outputs: way off
    params = TemplateParams(
        np.full((2, exact.n_inputs), 2, dtype=np.int8),
        np.ones((exact.n_outputs, 2), dtype=bool),
    )
    with pytest.raises(UnsoundResultError, match="wce .* > ET 0"):
        harvest(tpl, params, exact.eval_words(), 0, engine="test")
    with pytest.raises(UnsoundResultError, match="re-verification"):
        verify_circuit(tpl.instantiate(params), exact.eval_words(), 0)


def test_available_engines_always_include_solver_free_ones():
    names = available_engines()
    for engine in ("tensor", "anneal", "muscat", "mecals"):
        assert engine in names


# ---------------------------------------------------------------------------
# concurrent writers
# ---------------------------------------------------------------------------
def _put_worker(args):
    root, n_inputs, nodes, outputs, name, area_ = args
    from repro.core.circuits import Circuit, Gate, Op

    c = Circuit(n_inputs=n_inputs, name=name)
    c.nodes = [Gate(Op(op), tuple(a)) for op, a in nodes]
    c.outputs = list(outputs)
    store = OperatorStore(root)
    rec = store.put_circuit(c, OperatorSignature("mul", 2, "wce", 2),
                            area=area_, source="muscat")
    return rec.key


def test_concurrent_puts_of_same_netlist_are_idempotent(tmp_path):
    """Two workers committing the same netlist into one store must land
    exactly one record, never torn JSON."""
    from repro.core.baselines import muscat_like

    res = muscat_like(benchmark("mul_i4"), et=2, restarts=1, wall_budget_s=5)
    payload = (str(tmp_path / "lib"), res.circuit.n_inputs,
               [[g.op.value, list(g.args)] for g in res.circuit.nodes],
               list(res.circuit.outputs), res.circuit.name, res.area)
    # spawn, not fork: the pytest process has jax (multithreaded) loaded,
    # and fork-with-threads can deadlock — same trap run_sweep dodges
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(2) as pool:
        keys = pool.map(_put_worker, [payload] * 4)
    assert len(set(keys)) == 1
    store = OperatorStore(tmp_path / "lib")
    assert len(store) == 1
    # and the published record parses cleanly
    (rec,) = store.records(OperatorSignature("mul", 2, "wce", 2))
    assert rec.key == keys[0] and rec.wce <= 2
    # no leftover temp files
    assert not list((tmp_path / "lib").rglob("*.tmp"))


# ---------------------------------------------------------------------------
# end-to-end sweep
# ---------------------------------------------------------------------------
def test_smoke_sweep_densifies_frontier_and_resumes_as_noop(tmp_path):
    lib = tmp_path / "lib"
    results = run_sweep(SPEC, lib, workers=0, log=lambda *_: None)
    assert all(r.status == "ok" for r in results)
    store = OperatorStore(lib)
    sizes = frontier_sizes(store)
    assert len(sizes) >= 2, sizes        # >= 2 distinct signatures populated
    assert all(front >= 1 for _, front in sizes.values())
    n_records = len(store)
    assert n_records > 0

    # receipts were written and a re-run is a complete no-op
    receipts = list((lib / RECEIPT_DIR).glob("*.json"))
    assert len(receipts) == len(results)
    assert all(json.loads(p.read_text())["status"] == "ok" for p in receipts)
    again = run_sweep(SPEC, lib, workers=0, log=lambda *_: None)
    assert all(r.status == "skipped" for r in again)
    assert len(store) == n_records

    # even without receipts the searches are deterministic: same netlists,
    # same content keys, 0 new records
    for p in receipts:
        p.unlink()
    rerun = run_sweep(SPEC, lib, workers=0, log=lambda *_: None)
    assert all(r.status == "ok" for r in rerun)
    assert len(store) == n_records

    # changed engine options must re-run the jobs, not skip on receipts
    deeper = dataclasses.replace(SPEC, engine_opts={
        "anneal": {"steps": 3500, "restarts": 2, "keep": 3}})
    assert all(r.status == "ok"
               for r in run_sweep(deeper, lib, workers=0, log=lambda *_: None))


def test_failed_job_writes_receipt_and_is_retried(tmp_path):
    job = SearchJob(benchmark="mul", bits=2, et=1, engine="shared",
                    budget_s=1.0)
    from repro.core.miter import HAVE_Z3

    if HAVE_Z3:
        pytest.skip("needs a z3-less image to exercise the failure path")
    res = run_job(job, tmp_path / "lib")
    assert res.status == "failed" and "z3" in res.error
    from repro.fleet.worker import _receipt_path

    doc = json.loads(_receipt_path(tmp_path / "lib", job, {}).read_text())
    assert doc["status"] == "failed"
    # failed receipts do not block a retry
    assert run_job(job, tmp_path / "lib").status == "failed"


def test_fleet_cli_reports_densification(tmp_path, capsys):
    from repro.fleet.__main__ import main

    spec_file = tmp_path / "spec.json"
    spec_file.write_text(json.dumps({
        "name": "cli-test",
        "benchmarks": ["adder"],
        "bits": [2],
        "ets": [2],
        "engines": ["anneal"],
        "budget_s": 20.0,
        "engine_opts": {"anneal": {"steps": 3000, "restarts": 2, "keep": 3}},
    }))
    rc = main(["--library", str(tmp_path / "lib"), "--sweep", str(spec_file),
               "--workers", "0", "--min-new", "1"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "frontier densification" in out
    assert "adder2b_wce2" in out
