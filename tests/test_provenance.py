"""Request-lifecycle timelines + the approximation-provenance ledger.

Unit coverage for the two new obs modules — ledger write/read/audit
semantics, chain reconstruction and completeness validation from
synthetic span streams — plus the ``requests`` / ``provenance`` CLI
subcommands and the lifecycle-event overhead bound.  The traced serving
e2e (real preemption, real ledger) lives in ``tests/test_continuous.py``.
"""

import json
import time

import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import provenance as obs_prov
from repro.obs import trace as obs_trace
from repro.obs.__main__ import main as obs_main
from repro.obs.metrics import MetricRegistry
from repro.obs.provenance import (ProvenanceLedger, audit, ledger_for,
                                  read_ledger)
from repro.obs.requests import (BREAKDOWN_KEYS, build_timelines,
                                critical_path, request_events)
from repro.obs.trace import Tracer, read_trace


@pytest.fixture(autouse=True)
def _isolate_obs_globals():
    obs_trace.reset()
    prev = obs_metrics.set_registry(MetricRegistry())
    obs_prov._ledgers.clear()
    yield
    obs_trace.reset()
    obs_metrics.set_registry(prev)
    obs_prov._ledgers.clear()


def _clock():
    t = [0.0]

    def tick():
        t[0] += 1.0
        return t[0]

    return tick


# ---------------------------------------------------------------------------
# ledger: write / read / dedup
# ---------------------------------------------------------------------------
def test_ledger_roundtrip_dedup_and_torn_lines(tmp_path):
    led = ProvenanceLedger(tmp_path, tag="w0", clock=_clock())
    led.note_plan("p0", ["exact", "mul2_t1"], width_map=(8, 8))
    led.note_plan("p0", ["exact", "mul2_t1"])   # dup: written once
    led.record_range(rid=1, cls="gold", t0=0, t1=4, plan="p0", level=1,
                     drift=[0.01, 0.02])
    led.record_done(rid=1, cls="gold", gen_len=4, steps=7, preempts=0)
    led.close()

    recs = read_ledger(tmp_path)
    assert [r["k"] for r in recs] == ["plan", "range", "done"]
    assert recs[0]["width_map"] == [8, 8]
    # a re-copied file (same writer/seq) and a torn tail change nothing
    src = tmp_path / "prov-w0.jsonl"
    (tmp_path / "prov-w0-copy.jsonl").write_text(src.read_text())
    with open(src, "a") as f:
        f.write('{"k": "range", "w": "w0"')
    assert read_ledger(tmp_path) == recs


def test_ledger_for_is_shared_per_root_and_tag(tmp_path):
    a = ledger_for(tmp_path, "t")
    b = ledger_for(tmp_path, "t")
    assert a is b, "router replicas must share one sequence counter"
    assert ledger_for(tmp_path, "other") is not a
    a.record_done(rid=1, cls="std", gen_len=2, steps=3, preempts=0)
    b.record_done(rid=2, cls="std", gen_len=2, steps=3, preempts=0)
    recs = read_ledger(tmp_path)
    assert [r["n"] for r in recs] == [0, 1], "shared writer reused a seq"


# ---------------------------------------------------------------------------
# audit semantics
# ---------------------------------------------------------------------------
def _ledger(tmp_path, *records):
    led = ProvenanceLedger(tmp_path, tag="w0", clock=_clock())
    for kind, kw in records:
        getattr(led, kind)(**kw)
    led.close()
    return read_ledger(tmp_path)


def test_audit_gap_free_cover_is_complete(tmp_path):
    recs = _ledger(
        tmp_path,
        ("note_plan", dict(plan_id="p0", layers=["exact"])),
        ("note_plan", dict(plan_id="p1", layers=["mul2_t1"])),
        ("record_range", dict(rid=1, cls="gold", t0=0, t1=3, plan="p0",
                              level=0, drift=[0.01])),
        ("record_range", dict(rid=1, cls="gold", t0=3, t1=8, plan="p1",
                              level=2, drift=[0.03, 0.05])),
        ("record_done", dict(rid=1, cls="gold", gen_len=8, steps=11,
                             preempts=1)),
    )
    rep = audit(recs)
    assert rep["n_done"] == rep["n_complete"] == 1 and not rep["n_failed"]
    req = rep["requests"][1]
    assert req["complete"] and not req["problems"]
    assert req["tokens_covered"] == 8 and req["preempts"] == 1
    assert [r["plan"] for r in req["ranges"]] == ["p0", "p1"]
    assert req["drift_samples"] == 3
    assert req["mean_drift"] == pytest.approx(0.03)
    assert req["max_drift"] == pytest.approx(0.05)


def test_audit_flags_gap_overlap_and_dangling_plan(tmp_path):
    recs = _ledger(
        tmp_path,
        ("record_range", dict(rid=1, cls="gold", t0=0, t1=3, plan="exact",
                              level=None, drift=[])),
        ("record_range", dict(rid=1, cls="gold", t0=5, t1=8, plan="ghost",
                              level=1, drift=[])),    # gap [3,5) + no plan
        ("record_done", dict(rid=1, cls="gold", gen_len=8, steps=9,
                             preempts=0)),
        ("record_range", dict(rid=2, cls="batch", t0=0, t1=4, plan="exact",
                              level=None, drift=[])),
        ("record_range", dict(rid=2, cls="batch", t0=2, t1=6, plan="exact",
                              level=None, drift=[])),  # overlap at 2
        ("record_done", dict(rid=2, cls="batch", gen_len=6, steps=7,
                             preempts=0)),
        ("record_range", dict(rid=3, cls="batch", t0=0, t1=2, plan="exact",
                              level=None, drift=[])),  # no done: in flight
    )
    rep = audit(recs)
    assert rep["n_done"] == 2 and rep["n_failed"] == 2
    p1 = rep["requests"][1]["problems"]
    assert any("gap at tokens [3, 5)" in p for p in p1)
    assert any("plan ghost has no plan record" in p for p in p1)
    assert any("overlap at token 2" in p
               for p in rep["requests"][2]["problems"])
    # in-flight: reported, never counted as a failure
    r3 = rep["requests"][3]
    assert not r3["complete"]
    assert r3["problems"] == ["no done record (in flight or crashed)"]


def test_audit_short_cover_fails_even_without_gap(tmp_path):
    recs = _ledger(
        tmp_path,
        ("record_range", dict(rid=1, cls="std", t0=0, t1=5, plan="exact",
                              level=None, drift=[])),
        ("record_done", dict(rid=1, cls="std", gen_len=8, steps=9,
                             preempts=0)),
    )
    rep = audit(recs)
    assert rep["n_failed"] == 1
    assert any("cover 5/8 tokens" in p
               for p in rep["requests"][1]["problems"])


# ---------------------------------------------------------------------------
# timelines from synthetic span chains
# ---------------------------------------------------------------------------
def _emit_chain(tr, rid, *, cls="gold", preempts=0, drop=(), replica=""):
    extra = {"replica": replica} if replica else {}
    susp = 2.0 * preempts
    ev = [
        ("req.queued", dict(rid=rid, cls=cls, prompt_len=4)),
        ("req.admitted", dict(rid=rid, cls=cls, slot=0, queue_ms=1.0)),
        ("req.prefill", dict(rid=rid, cls=cls, slot=0, prompt_len=4)),
        ("req.decode", dict(rid=rid, cls=cls, ttft_ms=5.0, prefill_ms=4.0)),
    ]
    for _ in range(preempts):
        ev.append(("req.preempt", dict(rid=rid, cls=cls, step=3,
                                       by="gold")))
        ev.append(("req.resume", dict(rid=rid, cls=cls, slot=1,
                                      suspended_ms=2.0)))
    ev.append(("req.done", dict(rid=rid, cls=cls, steps=8,
                                preempts=preempts, resumes=preempts,
                                queue_ms=1.0, prefill_ms=4.0,
                                decode_ms=10.0, suspension_ms=susp,
                                total_ms=15.0 + susp)))
    for name, attrs in ev:
        if name not in drop:
            tr.event(name, **attrs, **extra)


def test_build_timelines_complete_and_broken_chains(tmp_path):
    tr = Tracer(tmp_path, clock=_clock(), process_tag="w0")
    _emit_chain(tr, 1, preempts=2, replica="gold-a")
    _emit_chain(tr, 2, cls="batch")
    _emit_chain(tr, 3, preempts=1, drop=("req.resume",))   # never resumed
    tr.event("serve.swap", reason="noise")          # non-lifecycle: ignored
    tr.close()

    spans = read_trace(tmp_path)
    assert all("rid" in e["attrs"] for e in request_events(spans))
    tls = build_timelines(spans)
    assert set(tls) == {1, 2, 3}

    t1 = tls[1]
    assert t1.complete and t1.preempts == t1.resumes == 2
    assert t1.cls == "gold" and t1.replica == "gold-a"
    assert t1.total_ms == pytest.approx(19.0)
    assert set(t1.breakdown) == set(BREAKDOWN_KEYS)
    assert critical_path(t1.breakdown) == "decode_ms"
    assert tls[2].complete and tls[2].preempts == 0

    t3 = tls[3]
    assert not t3.complete
    assert any("0 resume(s)" in p for p in t3.problems)


def test_build_timelines_flags_lost_events_and_bad_breakdown(tmp_path):
    tr = Tracer(tmp_path, clock=_clock(), process_tag="w0")
    _emit_chain(tr, 1, drop=("req.admitted",))       # lost admission event
    _emit_chain(tr, 2, drop=("req.done",))           # still in flight
    tr.event("req.queued", rid=3, cls="std", prompt_len=2)
    tr.event("req.admitted", rid=3, cls="std", slot=0, queue_ms=1.0)
    tr.event("req.prefill", rid=3, cls="std", slot=0, prompt_len=2)
    tr.event("req.decode", rid=3, cls="std", ttft_ms=3.0)
    tr.event("req.done", rid=3, cls="std", steps=4, preempts=0, resumes=0,
             queue_ms=1.0, prefill_ms=-2.0, decode_ms=9.0,
             suspension_ms=0.0, total_ms=99.0)   # negative + bad sum
    tr.close()

    tls = build_timelines(read_trace(tmp_path))
    assert any("0x req.admitted" in p for p in tls[1].problems)
    assert any("0x req.done" in p for p in tls[2].problems)
    p3 = tls[3].problems
    assert any("negative prefill_ms" in p for p in p3)
    assert not any("sums to" in p for p in p3), \
        "sum check must not fire on an already-incomplete breakdown"

    tr2 = Tracer(tmp_path / "b", clock=_clock(), process_tag="w0")
    _emit_chain(tr2, 4)
    tr2.close()
    spans = read_trace(tmp_path / "b")
    for s in spans:
        if s["name"] == "req.done":
            s["attrs"]["total_ms"] = 40.0    # breakdown says 15
    tls = build_timelines(spans)
    assert any("sums to" in p for p in tls[4].problems)


# ---------------------------------------------------------------------------
# CLI: requests + provenance subcommands
# ---------------------------------------------------------------------------
def test_cli_requests_gate_and_json(tmp_path, capsys):
    tr = Tracer(tmp_path, clock=_clock(), process_tag="w0")
    _emit_chain(tr, 1, preempts=1)
    _emit_chain(tr, 2, cls="batch")
    tr.close()

    assert obs_main(["requests", "--trace", str(tmp_path),
                     "--require-complete"]) == 0
    out = capsys.readouterr().out
    assert "2 request(s)" in out and "2 complete chain(s)" in out

    assert obs_main(["requests", "--trace", str(tmp_path), "--json",
                     "--rid", "1"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["n_requests"] == 1 and doc["n_complete"] == 1
    req = doc["requests"][0]
    assert req["rid"] == 1 and req["preempts"] == 1
    assert req["critical_path"] == "decode_ms"
    assert req["events"][0] == "req.queued"
    assert req["events"][-1] == "req.done"

    # a broken chain fails the gate with exit 1
    tr2 = Tracer(tmp_path, clock=_clock(), process_tag="w1")
    _emit_chain(tr2, 9, preempts=1, drop=("req.resume",))
    tr2.close()
    assert obs_main(["requests", "--trace", str(tmp_path),
                     "--require-complete"]) == 1
    assert "broken lifecycle" in capsys.readouterr().err

    # no lifecycle events at all: exit 2 (missing input, not a failure)
    empty = tmp_path / "empty"
    empty.mkdir()
    assert obs_main(["requests", "--trace", str(empty)]) == 2


def test_cli_provenance_gate_and_json(tmp_path, capsys):
    _ledger(
        tmp_path,
        ("note_plan", dict(plan_id="p0", layers=["mul2_t1"])),
        ("record_range", dict(rid=1, cls="gold", t0=0, t1=6, plan="p0",
                              level=1, drift=[0.02])),
        ("record_done", dict(rid=1, cls="gold", gen_len=6, steps=9,
                             preempts=0)),
    )
    assert obs_main(["provenance", "--trace", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "1 with gap-free provenance" in out

    assert obs_main(["provenance", "--trace", str(tmp_path),
                     "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["n_complete"] == 1 and doc["n_failed"] == 0
    assert doc["plans"]["p0"]["layers"] == ["mul2_t1"]

    # a gapped request fails the audit with exit 1
    bad = tmp_path / "bad"
    bad.mkdir()
    _ledger(
        bad,
        ("record_range", dict(rid=1, cls="std", t0=2, t1=4, plan="exact",
                              level=None, drift=[])),
        ("record_done", dict(rid=1, cls="std", gen_len=4, steps=5,
                             preempts=0)),
    )
    assert obs_main(["provenance", "--trace", str(bad)]) == 1
    assert "without" in capsys.readouterr().err

    empty = tmp_path / "empty"
    empty.mkdir()
    assert obs_main(["provenance", "--trace", str(empty)]) == 2


# ---------------------------------------------------------------------------
# overhead: lifecycle events must be near-free when tracing is off
# ---------------------------------------------------------------------------
def test_lifecycle_event_overhead_is_negligible_when_off():
    # the engine emits a handful of req.* events per request through
    # trace_event; with tracing unconfigured each call must stay far
    # below the CI budget (<=2% of a multi-ms decode step)
    t0 = time.perf_counter()
    for i in range(2000):
        obs_trace.event("req.queued", rid=i, cls="gold", prompt_len=8)
    per_call_ms = 1e3 * (time.perf_counter() - t0) / 2000
    assert per_call_ms < 0.05, f"untraced req event {per_call_ms:.4f} ms"
