"""End-to-end ALS search behaviour (small/fast configurations)."""

import numpy as np
import pytest

from repro.core.arith import benchmark
from repro.core.baselines import mecals_like, muscat_like, random_sound
from repro.core.engine import Candidate, SearchOutcome
from repro.core.miter import HAVE_Z3, MiterZ3, worst_case_error
from repro.core.search import progressive_search
from repro.core.synth import area
from repro.core.templates import SharedTemplate
from repro.core.tensor_search import tensor_search

needs_z3 = pytest.mark.skipif(not HAVE_Z3, reason="z3-solver not installed")


@pytest.fixture(scope="module")
def adder4():
    return benchmark("adder_i4")


@needs_z3
def test_progressive_shared_beats_exact_area(adder4):
    rep = progressive_search(adder4, et=1, method="shared",
                             wall_budget_s=90, timeout_ms=15_000)
    assert isinstance(rep, SearchOutcome) and rep.engine == "shared"
    assert rep.stats["sat_points"] == len(rep.results) > 0
    assert rep.best is not None
    assert rep.best.area < area(adder4)
    for r in rep.results:
        assert isinstance(r, Candidate)
        assert worst_case_error(adder4, r.circuit) <= 1


@needs_z3
def test_progressive_xpat_finds_sound_result(adder4):
    rep = progressive_search(adder4, et=1, method="xpat",
                             wall_budget_s=90, timeout_ms=15_000)
    assert rep.best is not None
    assert worst_case_error(adder4, rep.best.circuit) <= 1


@needs_z3
def test_shared_at_most_xpat_area(adder4):
    """The paper's headline claim at benchmark scale (ET=2)."""
    rs = progressive_search(adder4, et=2, method="shared",
                            wall_budget_s=90, timeout_ms=15_000)
    rx = progressive_search(adder4, et=2, method="xpat",
                            wall_budget_s=90, timeout_ms=15_000)
    assert rs.best is not None and rx.best is not None
    assert rs.best.area <= rx.best.area + 1e-9


def test_muscat_like_sound(adder4):
    res = muscat_like(adder4, et=2, restarts=2, wall_budget_s=15)
    assert res.wce <= 2
    assert res.area <= area(adder4)


def test_mecals_like_sound(adder4):
    res = mecals_like(adder4, et=2, wall_budget_s=15)
    assert res.wce <= 2
    assert res.area <= area(adder4)


def test_random_sound_cloud(adder4):
    cloud = random_sound(adder4, et=2, count=30, max_batches=10)
    assert len(cloud) > 0
    for a, prox in cloud:
        assert a >= 0 and prox["PIT"] >= 0


@needs_z3
def test_tensor_search_with_smt_seed(adder4):
    tpl = SharedTemplate(4, 3, pit=6)
    seed = MiterZ3(adder4, tpl).solve(et=2, its=6, timeout_ms=30_000)
    assert seed is not None
    rep = tensor_search(adder4, et=2, pit=6, population=1024,
                        generations=30, seeds=[seed])
    assert isinstance(rep, SearchOutcome) and rep.engine == "tensor"
    assert rep.best is not None
    assert worst_case_error(adder4, rep.best.circuit) <= 2
    assert rep.best.area <= area(tpl.instantiate(seed))
