"""Data-pipeline determinism + HLO collective-parser unit tests."""

import numpy as np

from repro.configs import get_config
from repro.launch.analysis import _group_size, _shape_bytes, collective_stats
from repro.train.data import DataState, next_batch, synth_batch


def test_pipeline_is_deterministic_per_step():
    cfg = get_config("stablelm-1.6b", reduced=True)
    a = synth_batch(cfg, 4, 32, DataState(seed=7, step=3))
    b = synth_batch(cfg, 4, 32, DataState(seed=7, step=3))
    c = synth_batch(cfg, 4, 32, DataState(seed=7, step=4))
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_next_batch_advances_state():
    cfg = get_config("stablelm-1.6b", reduced=True)
    _, st = next_batch(cfg, 2, 8, DataState(seed=0, step=0))
    assert st.step == 1


def test_tokens_in_vocab():
    cfg = get_config("stablelm-1.6b", reduced=True)
    batch = synth_batch(cfg, 8, 64, DataState(seed=1, step=0))
    toks = np.asarray(batch["tokens"])
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size


# --------------------------------------------------------------------- HLO
def test_shape_bytes():
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("f32[4,4]") == 64
    assert _shape_bytes("(bf16[8], f32[2,2])") == 16 + 16


def test_group_size_formats():
    assert _group_size("... replica_groups=[16,16]<=[256] ...") == 16
    assert _group_size("... replica_groups={{0,1,2,3}} ...") == 4


def test_collective_stats_parsing():
    hlo = """
  %ag = bf16[32,128]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[64]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%sum
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    stats = collective_stats(hlo)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1, "collective-permute": 1}
    ag_payload = 32 * 128 * 2
    assert stats.payload_bytes["all-gather"] == ag_payload
    # ring wire: (g-1)/g * payload for AG, 2*(g-1)/g for AR, payload for CP
    want = (15 / 16) * ag_payload + 2 * (3 / 4) * 256 + 32
    assert abs(stats.wire_bytes_total - want) < 1e-6


def test_async_pairs_counted_once():
    hlo = """
  %s = bf16[128]{0} all-gather-start(%x), replica_groups=[2,128]<=[256]
  %d = bf16[128]{0} all-gather-done(%s)
"""
    stats = collective_stats(hlo)
    assert stats.counts == {"all-gather": 1}
