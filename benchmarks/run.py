"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * fig4_*      — proxy<->area correlation runs (paper Fig. 4)
  * fig5_*      — best area per (benchmark, ET, method) (paper Fig. 5)
  * kernel rows — micro-benchmarks of the three kernels' workloads
  * roofline_*  — per (arch x shape x mesh) ideal step time + bottleneck
                  (from the dry-run artifacts, if present)
"""

from __future__ import annotations

import os
import sys


def main() -> None:
    quick = os.environ.get("BENCH_QUICK", "0") == "1"
    budget = 30.0 if quick else 75.0
    rows: list[tuple[str, float, str]] = []

    from . import fig4_proxy_area, fig5_area_vs_et, kernels_bench, roofline

    for r in fig4_proxy_area.main(budget_s=budget):
        rows.append((
            f"fig4_{r['bench']}_et{r['et']}", r["wall_s"] * 1e6,
            f"corr_pit_its={r['pearson_pit_its_vs_area']:.3f};"
            f"shared={r['shared_best']};xpat={r['xpat_best']};"
            f"random={r['random_best']};exact={r['exact_area']}",
        ))

    for r in fig5_area_vs_et.main(budget_s=budget):
        rows.append((
            f"fig5_{r['bench']}_et{r['et']}", r["wall_s"] * 1e6,
            f"shared={r['shared']};xpat={r['xpat']};"
            f"muscat~={r['muscat']};mecals~={r['mecals']};"
            f"hybrid={r['hybrid']};exact={r['exact_area']}",
        ))

    kernels_bench.main(rows)
    roofline.main(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    # paper-claim assertions (soft: report, don't crash the harness)
    problems = []
    for name, _, derived in rows:
        if name.startswith("fig5_"):
            vals = dict(kv.split("=") for kv in derived.split(";"))
            sh, xp = vals.get("shared"), vals.get("xpat")
            if sh not in (None, "None") and xp not in (None, "None"):
                if float(sh) > float(xp) + 1e-6:
                    problems.append(f"{name}: SHARED({sh}) > XPAT({xp})")
    if problems:
        print("CLAIM-CHECK FAILURES:", *problems, sep="\n  ", file=sys.stderr)
    else:
        print("# claim-check: SHARED <= XPAT area on every fig5 row", file=sys.stderr)


if __name__ == "__main__":
    main()
