"""Fig. 4 reproduction: proxy values vs synthesized area, fixed ET.

For each benchmark circuit we collect (proxy, area) points from
* SHARED (several satisfying assignments, like the paper),
* XPAT (nonshared),
* the random sound cloud (the paper's red dots),
and report the Pearson correlation of the template's proxy score with
synthesized area — the paper's claim (1): PIT/ITS is a close area proxy.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.arith import benchmark
from repro.core.baselines import random_sound
from repro.core.search import progressive_search
from repro.core.synth import area


def _pearson(x, y) -> float:
    x, y = np.asarray(x, float), np.asarray(y, float)
    if len(x) < 3 or x.std() == 0 or y.std() == 0:
        return float("nan")
    return float(np.corrcoef(x, y)[0, 1])


def run(bench: str = "adder_i4", et: int = 1, budget_s: float = 120.0,
        rows: list | None = None) -> dict:
    exact = benchmark(bench)
    t0 = time.time()

    shared = progressive_search(exact, et=et, method="shared",
                                wall_budget_s=budget_s, timeout_ms=20_000,
                                explore_after_sat=6)
    xpat = progressive_search(exact, et=et, method="xpat",
                              wall_budget_s=budget_s, timeout_ms=20_000,
                              explore_after_sat=6)
    cloud = random_sound(exact, et=et, count=300, max_batches=40)

    sh_pts = [(sum(r.proxies.values()), r.area) for r in shared.results]
    xp_pts = [(sum(r.proxies.values()), r.area) for r in xpat.results]
    rd_pts = [(sum(p.values()), a) for a, p in cloud]

    all_shared = sh_pts + rd_pts      # PIT+ITS proxy space
    corr_shared = _pearson([p for p, _ in all_shared], [a for _, a in all_shared])
    corr_xpat = _pearson([p for p, _ in xp_pts], [a for _, a in xp_pts])

    out = {
        "bench": bench, "et": et,
        "exact_area": area(exact),
        "shared_best": shared.best.area if shared.best else None,
        "xpat_best": xpat.best.area if xpat.best else None,
        "random_best": min((a for _, a in rd_pts), default=None),
        "n_shared_pts": len(sh_pts), "n_random_pts": len(rd_pts),
        "pearson_pit_its_vs_area": corr_shared,
        "pearson_lpp_ppo_vs_area": corr_xpat,
        "wall_s": round(time.time() - t0, 1),
    }
    if rows is not None:
        us = out["wall_s"] * 1e6
        rows.append((f"fig4_{bench}_et{et}", us,
                     f"corr={corr_shared:.3f};shared={out['shared_best']};xpat={out['xpat_best']}"))
    return out


def main(budget_s: float = 90.0) -> list[dict]:
    results = []
    for bench, et in [("adder_i4", 1), ("mul_i4", 1)]:
        results.append(run(bench, et, budget_s))
    return results


if __name__ == "__main__":
    for r in main():
        print(r)
