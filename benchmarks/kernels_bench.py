"""Kernel micro-benchmarks (CPU timings of the jnp reference path; the
Pallas kernels themselves are TPU-targeted and validated in interpret
mode, so what we time here is the semantic workload).

Per-iteration timings land in a :class:`repro.obs.metrics.MetricRegistry`
histogram per kernel, and the JSON the trajectory CI tracks
(``--json BENCH_kernels.json``) is a view over that registry — the rows
carry p50/p95 across iterations next to the mean, and the file is written
with the same atomic ``os.replace`` discipline as every other bench
artifact.  The LUT-matmul rows are decode-step shaped (M tokens through a
K x N projection) and report tokens/s and ms/step at both serving widths,
so the 4-bit-vs-8-bit cost of routing a model through searched operators
is one diff away.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arith import benchmark
from repro.core.circuits import input_truth_tables
from repro.kernels import ops
from repro.obs.export import write_bench_json
from repro.obs.metrics import MetricRegistry, get_registry

# per-iteration kernel latency in microseconds, sub-ms to multi-second
US_BUCKETS = (10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1e3, 2.5e3, 5e3,
              1e4, 2.5e4, 5e4, 1e5, 2.5e5, 5e5, 1e6, 5e6)


def _time(fn, *args, iters=5, hist=None) -> float:
    """Mean per-call microseconds; every timed iteration is also observed
    into ``hist`` so the JSON can state iteration spread, not just mean."""
    out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    total_us = 0.0
    for _ in range(iters):
        t0 = time.time()
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
        dt_us = (time.time() - t0) * 1e6
        total_us += dt_us
        if hist is not None:
            hist.observe(dt_us)
    return total_us / iters


def main(rows: list | None = None,
         registry: MetricRegistry | None = None
         ) -> list[tuple[str, float, str]]:
    registry = registry if registry is not None else get_registry()

    def hist(name: str):
        return registry.histogram("kernel_iter_us", buckets=US_BUCKETS,
                                  kernel=name)

    rng = np.random.default_rng(0)
    out = []

    # template_eval: population scoring throughput
    exact = benchmark("mul_i8")
    in_tt = jnp.asarray(input_truth_tables(8))
    ev = jnp.asarray(exact.eval_words().astype(np.int32))
    P, T = 8192, 12
    lits = jnp.asarray(rng.integers(0, 3, size=(P, T, 8)), dtype=jnp.int32)
    sel = jnp.asarray((rng.random((P, 8, T)) < 0.4), dtype=jnp.int32)
    f = jax.jit(lambda l, s: ops.template_eval(l, s, in_tt, ev, backend="ref"))
    us = _time(f, lits, sel, hist=hist("template_eval_8k_pop"))
    out.append(("template_eval_8k_pop", us, f"{P/(us/1e6):.0f} cands/s"))

    # approx_matmul: LUT matmul vs float matmul
    M = K = N = 512
    a = jnp.asarray(rng.integers(0, 16, (M, K)), dtype=jnp.int32)
    b = jnp.asarray(rng.integers(0, 16, (K, N)), dtype=jnp.int32)
    lut = jnp.asarray(rng.integers(0, 226, (16, 16)), dtype=jnp.int32)
    f = jax.jit(lambda x, y: ops.approx_matmul(x, y, lut, backend="ref"))
    us = _time(f, a, b, hist=hist(f"approx_matmul_{M}"))
    gflops = 2 * M * K * N / (us / 1e6) / 1e9
    out.append((f"approx_matmul_{M}", us, f"{gflops:.2f} eq-GFLOP/s"))

    # width comparison: a decode-step-shaped LUT matmul (M tokens through
    # one K x N projection) at W4A4 vs composed W8A8 tables
    from repro.precision import compose, exact_table

    Mt, Kd, Nd = 64, 256, 256
    lut8 = jnp.asarray(
        compose.tile_to_width(exact_table("mul", 4)).astype(np.int32))
    for bits, table in ((4, lut), (8, lut8)):
        side = table.shape[-1]
        aw = jnp.asarray(rng.integers(0, side, (Mt, Kd)), dtype=jnp.int32)
        bw = jnp.asarray(rng.integers(0, side, (Kd, Nd)), dtype=jnp.int32)
        f = jax.jit(lambda x, y, t=table: ops.approx_matmul(
            x, y, t, backend="ref"))
        name = f"lut_matmul_w{bits}_tok{Mt}"
        us = _time(f, aw, bw, hist=hist(name))
        out.append((name, us,
                    f"{Mt / (us / 1e6):.0f} tok/s, {us / 1e3:.3f} ms/step"))

    # flash_attention reference path
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), dtype=jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, backend="ref"))
    us = _time(f, q, k, v, hist=hist("attention_1k_gqa"))
    out.append(("attention_1k_gqa", us, "B1 H8 L1024 D64"))

    if rows is not None:
        rows.extend(out)
    return out


def rows_to_json(rows: list[tuple[str, float, str]],
                 registry: MetricRegistry | None = None) -> dict:
    """Structured view of the bench rows: mean microseconds, iteration
    p50/p95 from the registry histograms, plus the derived per-step
    numbers for the LUT-matmul width rows."""
    doc: dict = {}
    for name, us, note in rows:
        entry: dict = {"us": round(us, 3), "note": note}
        if registry is not None:
            h = registry.find("kernel_iter_us", kernel=name)
            if h is not None and h.count:
                entry["p50_us"] = round(h.quantile(0.5), 3)
                entry["p95_us"] = round(h.quantile(0.95), 3)
                entry["iters"] = h.count
        if name.startswith("lut_matmul_w"):
            toks = int(name.rsplit("tok", 1)[1])
            entry["ms_per_step"] = round(us / 1e3, 4)
            entry["tokens_per_s"] = round(toks / (us / 1e6), 1)
            entry["width_bits"] = int(name.split("_w")[1].split("_")[0])
        doc[name] = entry
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write the rows as JSON, e.g. BENCH_kernels.json")
    args = ap.parse_args()
    registry = MetricRegistry()
    rows = main(registry=registry)
    for r in rows:
        print(r)
    if args.json:
        write_bench_json(args.json, rows_to_json(rows, registry))
        print(f"bench rows -> {args.json}")
