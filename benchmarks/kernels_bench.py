"""Kernel micro-benchmarks (CPU timings of the jnp reference path; the
Pallas kernels themselves are TPU-targeted and validated in interpret
mode, so what we time here is the semantic workload)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arith import benchmark
from repro.core.circuits import input_truth_tables
from repro.kernels import ops


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / iters * 1e6  # us


def main(rows: list | None = None) -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    out = []

    # template_eval: population scoring throughput
    exact = benchmark("mul_i8")
    in_tt = jnp.asarray(input_truth_tables(8))
    ev = jnp.asarray(exact.eval_words().astype(np.int32))
    P, T = 8192, 12
    lits = jnp.asarray(rng.integers(0, 3, size=(P, T, 8)), dtype=jnp.int32)
    sel = jnp.asarray((rng.random((P, 8, T)) < 0.4), dtype=jnp.int32)
    f = jax.jit(lambda l, s: ops.template_eval(l, s, in_tt, ev, backend="ref"))
    us = _time(f, lits, sel)
    out.append(("template_eval_8k_pop", us, f"{P/(us/1e6):.0f} cands/s"))

    # approx_matmul: LUT matmul vs float matmul
    M = K = N = 512
    a = jnp.asarray(rng.integers(0, 16, (M, K)), dtype=jnp.int32)
    b = jnp.asarray(rng.integers(0, 16, (K, N)), dtype=jnp.int32)
    lut = jnp.asarray(rng.integers(0, 226, (16, 16)), dtype=jnp.int32)
    f = jax.jit(lambda x, y: ops.approx_matmul(x, y, lut, backend="ref"))
    us = _time(f, a, b)
    gflops = 2 * M * K * N / (us / 1e6) / 1e9
    out.append((f"approx_matmul_{M}", us, f"{gflops:.2f} eq-GFLOP/s"))

    # flash_attention reference path
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), dtype=jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, backend="ref"))
    us = _time(f, q, k, v)
    out.append(("attention_1k_gqa", us, "B1 H8 L1024 D64"))

    if rows is not None:
        rows.extend(out)
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
