"""Kernel micro-benchmarks (CPU timings of the jnp reference path; the
Pallas kernels themselves are TPU-targeted and validated in interpret
mode, so what we time here is the semantic workload).

``--json BENCH_kernels.json`` additionally dumps the rows as structured
JSON — the bench trajectory CI tracks alongside ``BENCH_serve.json``.
The LUT-matmul rows are decode-step shaped (M tokens through a K x N
projection) and report tokens/s and ms/step at both serving widths, so
the 4-bit-vs-8-bit cost of routing a model through searched operators is
one diff away.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arith import benchmark
from repro.core.circuits import input_truth_tables
from repro.kernels import ops


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / iters * 1e6  # us


def main(rows: list | None = None) -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    out = []

    # template_eval: population scoring throughput
    exact = benchmark("mul_i8")
    in_tt = jnp.asarray(input_truth_tables(8))
    ev = jnp.asarray(exact.eval_words().astype(np.int32))
    P, T = 8192, 12
    lits = jnp.asarray(rng.integers(0, 3, size=(P, T, 8)), dtype=jnp.int32)
    sel = jnp.asarray((rng.random((P, 8, T)) < 0.4), dtype=jnp.int32)
    f = jax.jit(lambda l, s: ops.template_eval(l, s, in_tt, ev, backend="ref"))
    us = _time(f, lits, sel)
    out.append(("template_eval_8k_pop", us, f"{P/(us/1e6):.0f} cands/s"))

    # approx_matmul: LUT matmul vs float matmul
    M = K = N = 512
    a = jnp.asarray(rng.integers(0, 16, (M, K)), dtype=jnp.int32)
    b = jnp.asarray(rng.integers(0, 16, (K, N)), dtype=jnp.int32)
    lut = jnp.asarray(rng.integers(0, 226, (16, 16)), dtype=jnp.int32)
    f = jax.jit(lambda x, y: ops.approx_matmul(x, y, lut, backend="ref"))
    us = _time(f, a, b)
    gflops = 2 * M * K * N / (us / 1e6) / 1e9
    out.append((f"approx_matmul_{M}", us, f"{gflops:.2f} eq-GFLOP/s"))

    # width comparison: a decode-step-shaped LUT matmul (M tokens through
    # one K x N projection) at W4A4 vs composed W8A8 tables
    from repro.precision import compose, exact_table

    Mt, Kd, Nd = 64, 256, 256
    lut8 = jnp.asarray(
        compose.tile_to_width(exact_table("mul", 4)).astype(np.int32))
    for bits, table in ((4, lut), (8, lut8)):
        side = table.shape[-1]
        aw = jnp.asarray(rng.integers(0, side, (Mt, Kd)), dtype=jnp.int32)
        bw = jnp.asarray(rng.integers(0, side, (Kd, Nd)), dtype=jnp.int32)
        f = jax.jit(lambda x, y, t=table: ops.approx_matmul(
            x, y, t, backend="ref"))
        us = _time(f, aw, bw)
        out.append((f"lut_matmul_w{bits}_tok{Mt}", us,
                    f"{Mt / (us / 1e6):.0f} tok/s, {us / 1e3:.3f} ms/step"))

    # flash_attention reference path
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), dtype=jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, backend="ref"))
    us = _time(f, q, k, v)
    out.append(("attention_1k_gqa", us, "B1 H8 L1024 D64"))

    if rows is not None:
        rows.extend(out)
    return out


def rows_to_json(rows: list[tuple[str, float, str]]) -> dict:
    """Structured view of the bench rows: microseconds plus the derived
    per-step numbers for the LUT-matmul width rows."""
    doc: dict = {}
    for name, us, note in rows:
        entry: dict = {"us": round(us, 3), "note": note}
        if name.startswith("lut_matmul_w"):
            toks = int(name.rsplit("tok", 1)[1])
            entry["ms_per_step"] = round(us / 1e3, 4)
            entry["tokens_per_s"] = round(toks / (us / 1e6), 1)
            entry["width_bits"] = int(name.split("_w")[1].split("_")[0])
        doc[name] = entry
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write the rows as JSON, e.g. BENCH_kernels.json")
    args = ap.parse_args()
    rows = main()
    for r in rows:
        print(r)
    if args.json:
        path = Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(rows_to_json(rows), indent=1,
                                   sort_keys=True))
        print(f"bench rows -> {path}")
