"""Roofline report: aggregate the dry-run JSON records into the
EXPERIMENTS.md §Roofline table (one row per arch x shape x mesh)."""

from __future__ import annotations

import glob
import json
import os

COLUMNS = [
    "arch", "shape", "mesh", "status", "t_compute", "t_memory",
    "t_collective", "t_star", "bottleneck", "useful_flops_ratio",
    "roofline_fraction",
]


def load(dirname: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | t_comp(s) | t_mem(s) | t_coll(s) | t*(s) "
           "| bottleneck | useful | roofline |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                       f"| — | — | — | — | SKIP: {r['reason'][:40]} | — | — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                       f"| — | — | — | — | ERROR | — | — |")
            continue
        if r["mesh"] != "16x16":
            # multi-pod cells are compile-pass only (scan-body-once stats
            # are not corrected there; the roofline table is single-pod)
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                       f"| — | — | — | — | COMPILE-OK (pod axis shards) | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.3g} | {r['t_memory']:.3g} "
            f"| {r['t_collective']:.3g} | {r['t_star']:.3g} "
            f"| {r['bottleneck']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def main(rows_out: list | None = None, dirname: str = "experiments/dryrun"):
    rows = load(dirname)
    ok = [r for r in rows if r.get("status") == "ok"]
    ok = [r for r in ok if r.get("mesh") == "16x16"]
    if rows_out is not None:
        for r in ok:
            rows_out.append((
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                r["t_star"] * 1e6,
                f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f}",
            ))
    return rows


if __name__ == "__main__":
    print(table(load()))
