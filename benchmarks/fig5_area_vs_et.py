"""Fig. 5 reproduction: best synthesized area per method, varying ET.

Methods: SHARED (paper), XPAT (nonshared), MUSCAT-like, MECALS-like, plus
our beyond-paper HYBRID (loose-SMT seed -> tensorized minimization).  One
row per (benchmark, ET, method).

Every sound result every method finds is persisted into an operator
library (``--library`` / the ``store`` argument; a temp dir otherwise) and
the per-row "best" is a *frontier query* — the smallest-area operator
whose measured worst-case error fits the row's ET — instead of the old
per-report ``report.best`` pick.  A low-ET discovery that also satisfies a
looser row is therefore credited to it, exactly as a library-backed flow
would deploy it.
"""

from __future__ import annotations

import tempfile
import time

from repro.core.arith import benchmark, parse_benchmark_name
from repro.core.baselines import mecals_like, muscat_like
from repro.core.engine import SearchJob, get_engine
from repro.core.miter import HAVE_Z3, MiterZ3, worst_case_error
from repro.core.synth import area
from repro.core.templates import SharedTemplate
from repro.core.tensor_search import tensor_search
from repro.library import OperatorSignature, OperatorStore, ParetoFrontier


def run(bench: str, ets: list[int], budget_s: float = 90.0,
        store: OperatorStore | None = None) -> list[dict]:
    exact = benchmark(bench)
    exact_area = area(exact)
    if store is None:
        store = OperatorStore(tempfile.mkdtemp(prefix="fig5_lib_"))
    kind, bits = parse_benchmark_name(bench)

    def frontier(source: str) -> ParetoFrontier:
        return ParetoFrontier(store.query(kind, bits, source=source))

    rows = []
    for et in ets:
        sig = OperatorSignature(kind, bits, "wce", et)
        row = {"bench": bench, "et": et, "exact_area": exact_area}
        t0 = time.time()
        if HAVE_Z3:
            # the paper's two SMT methods through the unified engine layer;
            # every Candidate streams into the store as it is found
            for method in ("shared", "xpat"):
                eng = get_engine(method, timeout_ms=20_000,
                                 sink=store.sink(sig, method))
                outcome = eng.run(SearchJob(benchmark=kind, bits=bits, et=et,
                                            engine=method, budget_s=budget_s))
                # soundness re-verification of every winner
                if outcome.best is not None:
                    assert worst_case_error(exact, outcome.best.circuit) <= et
        # engine-registry source names ("muscat"/"mecals", same as the fleet
        # and search CLI write) so one shared library credits every producer
        rm = muscat_like(exact, et=et, restarts=3, wall_budget_s=budget_s / 3)
        store.put_circuit(rm.circuit, sig, area=rm.area, source="muscat")
        rc = mecals_like(exact, et=et, wall_budget_s=budget_s / 3)
        store.put_circuit(rc.circuit, sig, area=rc.area, source="mecals")

        # beyond-paper hybrid: loose-SMT seed -> tensor minimization
        if HAVE_Z3:
            n, m = exact.n_inputs, exact.n_outputs
            pool = min(2 * m + 2, 14)
            seed = MiterZ3(exact, SharedTemplate(n, m, pit=pool)).solve(
                et=et, its=pool, timeout_ms=30_000)
            if seed is not None:
                th = tensor_search(exact, et=et, pit=pool, population=4096,
                                   generations=80, seeds=[seed])
                for r in th.results:   # unified Candidates
                    store.put_circuit(r.circuit, sig, area=r.area,
                                      source="hybrid", params=r.params,
                                      proxies=r.proxies)

        # the row's "best" is now a frontier query over the library
        for name in ("shared", "xpat", "muscat", "mecals", "hybrid"):
            best = frontier(name).best_under_error(et)
            row[name] = best.area if best is not None else None
        row["wall_s"] = round(time.time() - t0, 1)
        rows.append(row)
    return rows


def main(budget_s: float = 60.0,
         store: OperatorStore | None = None) -> list[dict]:
    out = []
    out += run("adder_i4", [1, 2, 4], budget_s, store)
    out += run("mul_i4", [1, 2, 4], budget_s, store)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--library", default=None,
                    help="persist every sound operator into this store")
    ap.add_argument("--budget-s", type=float, default=60.0)
    args = ap.parse_args()
    lib = OperatorStore(args.library) if args.library else None
    for r in main(args.budget_s, lib):
        print(r)
