"""Fig. 5 reproduction: best synthesized area per method, varying ET.

Methods: SHARED (paper), XPAT (nonshared), MUSCAT-like, MECALS-like, plus
our beyond-paper HYBRID (loose-SMT seed -> tensorized minimization).  One
row per (benchmark, ET, method).
"""

from __future__ import annotations

import time

from repro.core.arith import benchmark
from repro.core.baselines import mecals_like, muscat_like
from repro.core.miter import MiterZ3, worst_case_error
from repro.core.search import progressive_search
from repro.core.synth import area
from repro.core.templates import SharedTemplate
from repro.core.tensor_search import tensor_search


def run(bench: str, ets: list[int], budget_s: float = 90.0) -> list[dict]:
    exact = benchmark(bench)
    exact_area = area(exact)
    rows = []
    for et in ets:
        row = {"bench": bench, "et": et, "exact_area": exact_area}
        t0 = time.time()
        rs = progressive_search(exact, et=et, method="shared",
                                wall_budget_s=budget_s, timeout_ms=20_000)
        row["shared"] = rs.best.area if rs.best else None
        rx = progressive_search(exact, et=et, method="xpat",
                                wall_budget_s=budget_s, timeout_ms=20_000)
        row["xpat"] = rx.best.area if rx.best else None
        rm = muscat_like(exact, et=et, restarts=3, wall_budget_s=budget_s / 3)
        row["muscat_like"] = rm.area
        rc = mecals_like(exact, et=et, wall_budget_s=budget_s / 3)
        row["mecals_like"] = rc.area

        # beyond-paper hybrid: loose-SMT seed -> tensor minimization
        n, m = exact.n_inputs, exact.n_outputs
        pool = min(2 * m + 2, 14)
        seed = MiterZ3(exact, SharedTemplate(n, m, pit=pool)).solve(
            et=et, its=pool, timeout_ms=30_000)
        if seed is not None:
            th = tensor_search(exact, et=et, pit=pool, population=4096,
                               generations=80, seeds=[seed])
            row["hybrid"] = th.best.area if th.best else None
        else:
            row["hybrid"] = None

        # soundness re-verification of every winner
        for name, rep in (("shared", rs), ("xpat", rx)):
            if rep.best is not None:
                assert worst_case_error(exact, rep.best.circuit) <= et
        row["wall_s"] = round(time.time() - t0, 1)
        rows.append(row)
    return rows


def main(budget_s: float = 60.0) -> list[dict]:
    out = []
    out += run("adder_i4", [1, 2, 4], budget_s)
    out += run("mul_i4", [1, 2, 4], budget_s)
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
