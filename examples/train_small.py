"""End-to-end training driver: train a ~100M-param qwen3-family model for a
few hundred steps on the synthetic pipeline, with checkpointing.

This is the assignment's end-to-end driver (deliverable b).  It uses the
REAL launcher (repro.launch.train) with a custom mid-size config — on a
cluster the identical code path runs the full configs on the production
mesh.

    PYTHONPATH=src python examples/train_small.py [--steps 300]
"""

import argparse
import dataclasses
import sys

import jax

from repro import parallel
from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_model
from repro.train import (
    DataState, OptimizerConfig, checkpoint, init_opt_state, make_train_step,
    next_batch,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # ~100M params: qwen3 family, narrow-deep, small vocab
    cfg = dataclasses.replace(
        get_config("qwen3-4b"),
        name="qwen3-100m",
        n_layers=8, d_model=640, n_heads=10, n_kv_heads=5, head_dim=64,
        d_ff=1792, vocab_size=32000,
    )
    key = jax.random.PRNGKey(0)
    mesh = make_smoke_mesh()
    with parallel.activate(mesh), mesh:
        params = init_model(cfg, key)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps "
              f"@ batch {args.batch}x{args.seq}")

        opt_cfg = OptimizerConfig(lr=6e-4, warmup_steps=30,
                                  total_steps=args.steps)
        opt_state = init_opt_state(params)
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat="none"))
        ds = DataState(seed=0, step=0)

        losses = []
        for step in range(args.steps):
            batch, ds = next_batch(cfg, args.batch, args.seq, ds)
            params, opt_state, m = step_fn(params, opt_state, batch)
            losses.append(float(m["loss"]))
            if (step + 1) % 20 == 0:
                print(f"  step {step+1:4d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(m['grad_norm']):.2f}", flush=True)
            if (step + 1) % 100 == 0 or step + 1 == args.steps:
                checkpoint.save(args.ckpt_dir, step + 1, params, opt_state,
                                data_state=ds.as_dict())

        first, last = losses[0], sum(losses[-20:]) / 20
        print(f"loss {first:.3f} -> {last:.3f}")
        if last >= first:
            print("WARNING: loss did not improve", file=sys.stderr)
            sys.exit(1)
        print(f"checkpoints in {args.ckpt_dir} "
              f"(latest step {checkpoint.latest_step(args.ckpt_dir)})")


if __name__ == "__main__":
    main()
