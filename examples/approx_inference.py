"""Approximate-arithmetic inference screening (Layer B of the framework).

Takes an approximate 4-bit multiplier produced by the ALS engine, builds
its LUT, and measures what routing a real model's MLP matmuls through it
does to the logits — exactly the screening a codesign team runs at fleet
scale before committing an operator to silicon.  Here: a reduced
architecture on CPU; on the production mesh the same forward runs as the
prefill_32k dry-run cell.

    PYTHONPATH=src python examples/approx_inference.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.arith import benchmark
from repro.core.baselines import muscat_like
from repro.core.synth import area
from repro.models import forward_fn, init_model
from repro.quant import build_lut, exact_mul_lut

# --- Layer A: synthesize approximate multipliers at several ETs -------------
# (operator source: the MUSCAT-like pruning engine — fast and sound at
#  mul_i8 scale; the SMT/SHARED path is demonstrated on quickstart.py's
#  adder, where 2-level SoP is competitive within quick budgets)
exact_mult = benchmark("mul_i8")
print(f"exact 4-bit multiplier area: {area(exact_mult)} µm²")
luts = {}
for et in (2, 8, 32):
    res = muscat_like(exact_mult, et=et, restarts=2, wall_budget_s=45)
    luts[et] = (build_lut(res.circuit), res.area)
    print(f"  ET={et:3d}: area {res.area} µm² "
          f"({100*(1-res.area/area(exact_mult)):.0f}% saving)")

# --- Layer B: route a model's MLP matmuls through each LUT ------------------
cfg = get_config("qwen3-4b", reduced=True).with_approx_mlp()
key = jax.random.PRNGKey(0)
params = init_model(cfg, key)
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
fwd = forward_fn(cfg)

logits_f, _ = fwd(cfg, params, batch, lut=None)                  # float
logits_q, _ = fwd(cfg, params, batch, lut=jnp.asarray(exact_mul_lut()))  # int4

print(f"\nmodel={cfg.name}  (MLP matmuls -> W4A4 with LUT multiplier)")
print(f"  int4 quantization alone: mean |Δlogit| = "
      f"{float(jnp.abs(logits_f - logits_q).mean()):.4f}")

base_top1 = jnp.argmax(logits_q, -1)
for et, (lut, a) in luts.items():
    logits_a, _ = fwd(cfg, params, batch, lut=jnp.asarray(lut))
    drift = float(jnp.abs(logits_q - logits_a).mean())
    agree = float((jnp.argmax(logits_a, -1) == base_top1).mean())
    print(f"  ET={et:3d}: extra drift {drift:.4f}, "
          f"top-1 agreement {100*agree:.1f}%, area saving "
          f"{100*(1 - a/area(exact_mult)):.0f}%")

print("\n-> the area/accuracy tradeoff the paper navigates, measured on a "
      "real architecture instead of operator error alone.")
