"""Approximate-arithmetic inference screening (Layer B of the framework).

Two modes:

* **ad-hoc** (default, the original demo): synthesize a few approximate
  4-bit multipliers in-process, build their LUTs, and measure logit drift
  when a real model's MLP matmuls route through them.

* **library + QoS** (``--library <dir> [--qos-budget B]``): load the
  Pareto frontier of operators a previous search persisted (``python -m
  repro.core.search --library <dir>``), compile each to the packed LUT the
  Pallas kernel consumes, *measure per-layer sensitivity* through
  ``repro.sensitivity.profile`` (the same measured code path the serve
  launcher's ``--profile`` consumes), and let the QoS selector assign each
  layer the smallest operator that keeps predicted drift within budget —
  then run the model on the resulting per-layer plan and report what each
  layer used (repro.launch.analysis.plan_report).

    PYTHONPATH=src python examples/approx_inference.py --reduced \
        --library runs/lib --qos-budget 0.02
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.arith import benchmark
from repro.core.baselines import muscat_like
from repro.core.synth import area
from repro.models import forward_fn, init_model
from repro.quant import build_lut, exact_mul_lut


def make_model(arch: str, reduced: bool, seed: int = 0):
    cfg = get_config(arch, reduced=reduced).with_approx_mlp()
    key = jax.random.PRNGKey(seed)
    params = init_model(cfg, key)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
    return cfg, params, batch, forward_fn(cfg)


def adhoc_main(args) -> None:
    """The original screening flow: one-shot in-process operators."""
    exact_mult = benchmark("mul_i8")
    print(f"exact 4-bit multiplier area: {area(exact_mult)} µm²")
    luts = {}
    for et in (2, 8, 32):
        res = muscat_like(exact_mult, et=et, restarts=2, wall_budget_s=45)
        luts[et] = (build_lut(res.circuit), res.area)
        print(f"  ET={et:3d}: area {res.area} µm² "
              f"({100*(1-res.area/area(exact_mult)):.0f}% saving)")

    cfg, params, batch, fwd = make_model(args.arch, args.reduced)
    logits_f, _ = fwd(cfg, params, batch, lut=None)
    logits_q, _ = fwd(cfg, params, batch, lut=jnp.asarray(exact_mul_lut()))

    print(f"\nmodel={cfg.name}  (MLP matmuls -> W4A4 with LUT multiplier)")
    print(f"  int4 quantization alone: mean |Δlogit| = "
          f"{float(jnp.abs(logits_f - logits_q).mean()):.4f}")

    base_top1 = jnp.argmax(logits_q, -1)
    for et, (lut, a) in luts.items():
        logits_a, _ = fwd(cfg, params, batch, lut=jnp.asarray(lut))
        drift = float(jnp.abs(logits_q - logits_a).mean())
        agree = float((jnp.argmax(logits_a, -1) == base_top1).mean())
        print(f"  ET={et:3d}: extra drift {drift:.4f}, "
              f"top-1 agreement {100*agree:.1f}%, area saving "
              f"{100*(1 - a/area(exact_mult)):.0f}%")

    print("\n-> the area/accuracy tradeoff the paper navigates, measured on "
          "a real architecture instead of operator error alone.")


def library_main(args) -> None:
    """Frontier-driven per-layer QoS selection from a persisted library."""
    from repro.launch.analysis import plan_report
    from repro.library import load_mul_frontier, select_plan, stack_luts
    from repro.library.compile import compile_cache_stats
    from repro.sensitivity.profile import measure_cost_matrix

    try:
        compiled, exact_area, bits = load_mul_frontier(args.library)
    except LookupError as e:
        raise SystemExit(str(e))
    print(f"library {args.library}: {len(compiled)} operator(s) on the "
          f"{bits}-bit multiplier frontier (exact area {exact_area} µm²):")
    for rec, comp in compiled:
        print(f"  {rec.key}  src={rec.source:<7s} area {rec.area:>7.3f} µm² "
              f"wce={rec.wce:<3d} -> compiled 16x16 LUT "
              f"wce16={comp.wce16} mae16={comp.mae16:.4f}")

    cfg, params, batch, fwd = make_model(args.arch, args.reduced)
    fwd_j = jax.jit(lambda p, b, lut: fwd(cfg, p, b, lut=lut)[0])
    base = fwd_j(params, batch, jnp.asarray(exact_mul_lut()))
    base_top1 = jnp.argmax(base, -1)
    L = cfg.n_layers

    # per-(layer, operator) drift, measured one probe at a time through the
    # shared sensitivity pipeline (biased LUT errors make drift non-linear
    # in mae16, so the QoS plan runs on measured costs rather than the
    # linear model); `python -m repro.sensitivity.profile --library ...`
    # persists the same measurement for the serve launcher's --profile
    print(f"\nmeasuring per-(layer, operator) drift on {cfg.name} "
          f"({L} layers x {len(compiled)} operators)...")
    costs = measure_cost_matrix(cfg, params, batch, compiled)
    print("  drift matrix (layers x operators):")
    print(np.array2string(costs, precision=4, suppress_small=True))

    plan = select_plan(compiled, costs, args.qos_budget, exact_area=exact_area)
    print(f"\nQoS plan under budget {args.qos_budget} "
          f"(mean |Δlogit| vs int4-exact):")
    print(plan_report(plan))

    logits_p = fwd_j(params, batch, jnp.asarray(stack_luts(plan, compiled)))
    drift = float(jnp.abs(logits_p - base).mean())
    agree = float((jnp.argmax(logits_p, -1) == base_top1).mean())
    cs = compile_cache_stats()
    print(f"\nmeasured drift {drift:.5f} (predicted {plan.predicted_total:.5f}), "
          f"top-1 agreement {100*agree:.1f}%")
    print(f"compile cache: {cs['hits']} hits / {cs['misses']} misses "
          f"({cs['size']} table(s))")
    print("-> per-layer operators selected from the persisted frontier, "
          "compiled to LUTs, routed through approx_matmul.")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    # reduced by default: the plain invocation stays CPU-runnable
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--library", default=None,
                    help="operator-store directory (enables the QoS flow)")
    ap.add_argument("--qos-budget", type=float, default=0.05,
                    help="allowed mean |Δlogit| vs the int4-exact baseline")
    args = ap.parse_args()
    if args.library:
        library_main(args)
    else:
        adhoc_main(args)


if __name__ == "__main__":
    main()
