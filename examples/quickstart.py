"""Quickstart: the paper's pipeline end to end on one benchmark.

1. Build the exact 4-bit multiplier (``mul_i8``).
2. Run the SHARED progressive search at ET=8.
3. Compare against XPAT, MUSCAT-like, MECALS-like and the hybrid
   tensorized search.
4. Turn the winner into a LUT and check its error profile.

Runs on CPU in a couple of minutes.

    PYTHONPATH=src python examples/quickstart.py

Adaptive serving
----------------
The searches above emit a whole *frontier* of operators, not one circuit
— and the serving runtime (:mod:`repro.serving`) exploits that at
deployment time.  Fill a library, then serve with the QoS controller
walking the frontier between batches:

    python -m repro.fleet --library runs/lib --sweep smoke
    python -m repro.launch.serve --reduced --adaptive --library runs/lib \
        --schedule ramp --ticks 8 --target-ms-per-step 20 \
        --drift-budget 0.05 --watch-library --bench-json BENCH_serve.json

The per-layer LUT stack is a plain jitted argument of the decode step, so
every plan swap (controller move, or a background ``repro.fleet`` sweep
landing new operators while ``--watch-library`` polls the store) reuses
the one traced executable — no recompilation mid-serve.  Telemetry
(tok/s split by prefill/decode, ms/step, swap log) lands in
``BENCH_serve.json`` / ``--telemetry``.

W8A8 serving
------------
The searches stop at 4-bit blocks, but serving does not: ``--width 8``
composes the same searched blocks into 256x256 product tables
(:mod:`repro.precision` — shift-add of 16x16 tiles over operand nibbles,
exactness identities checked at build time) and routes decode matmuls
through a ``(L, 256, 256)`` per-layer stack — the dominant edge
quantization regime, on searched operators:

    python -m repro.fleet --library runs/lib --sweep 8bit
    python -m repro.launch.serve --reduced --library runs/lib --width 8 \
        --qos-budget 1e9 --bench-json BENCH_w8a8.json

The ``8bit`` sweep densifies both block widths (2-bit via the template
engines, 4-bit via the rewrite baselines); the QoS planner prices each
block by its *composed* area and error, so the 8-bit frontier is a real
area/accuracy trade at serving width.  On TPU the 8-bit tables run
through a two-level Pallas kernel — four 16x16-tile LUT matmuls combined
by shift-add on the MXU — that bit-matches the gather oracle; everything
(adaptive controller, library watcher, hot-swap-without-retrace) works at
either width, one width per serve.

Measured sensitivities & QoS classes
------------------------------------
Uniform sensitivities price every layer the same; ``repro.sensitivity``
replaces them with *measurement*.  Profile the model once (one layer
perturbed at a time against the exact oracle, per serving width, plus the
full per-(layer, operator) drift matrix over the library's frontier),
then serve with per-request traffic tiers and a per-layer width map:

    python -m repro.sensitivity.profile --arch gemma3-1b --reduced \
        --library runs/lib --out runs/lib/_profiles/gemma3-1b.json
    python -m repro.launch.serve --reduced --library runs/lib \
        --profile runs/lib/_profiles/gemma3-1b.json --mixed-width \
        --qos-class "gold:0.02,std:0.05,batch:0.5" \
        --class-mix "gold:0.1,std:0.6,batch:0.3" \
        --bench-json BENCH_serve.json

``--qos-class`` declares named tiers with their own drift budgets: each
class gets its own request queue (drained in listed priority order) and
decodes on its own ladder level — ``gold`` rides a near-exact plan while
``batch`` rides the aggressive end, in the same process, against the same
single decode trace.  ``--mixed-width`` picks a per-layer width map by
one greedy descent over both frontiers at once: sensitive layers keep the
native 16x16 tiles, tolerant layers take composed 256x256 W8A8 tables
whose composed area undercuts the best uniform-width plan at the same
drift budget (the bench summary's ``mixed`` block reports the
comparison).  During the serve, shadow-step drift samples feed an online
per-layer EWMA estimator (``repro.sensitivity.online``) that keeps the
measured profile fresh.

Production serving
------------------
``--continuous`` upgrades any serve from batch-boundary admission to
continuous batching: a fixed pool of ``--max-slots`` decode slots that
requests join and leave *per step* (an active-mask over the same jitted
decode step — still exactly one trace), with KV in a paged pool
(``--page-size``/``--pages``, per-request page tables, free-list reuse)
so heterogeneous prompts (``--prompt-dist "bimodal:4-16"``) cost only the
pages they use.  A QoS class may attach a latency SLO to its drift
budget — ``gold:0.02@8ms`` means "p95 ms-per-step under 8 ms" — and SLO
classes *preempt*: when the pool is full, a gold arrival suspends the
worst lower-tier slot, which keeps its pages (no re-prefill) and resumes
from the head of its queue.  Admission drains the class queues
weighted-fair instead of strictly by priority, so ``batch`` never
starves.  Telemetry adds per-request TTFT histograms per class,
preemption counts, and slot occupancy:

    python -m repro.launch.serve --reduced --continuous --library runs/lib \
        --profile runs/lib/_profiles/gemma3-1b.json \
        --qos-class "gold:0.02@8ms,batch:0.5" --class-mix "gold:0.3,batch:0.7" \
        --max-slots 8 --prompt-dist "bimodal:4-16" --schedule spike \
        --compare-fixed --bench-json BENCH_slo.json

``--compare-fixed`` serves the identical profile on the fixed-batch
engine first and emits paired rows (``compare`` in the bench JSON):
steady-state decode tok/s and per-class p95 ms-per-step, fixed vs
continuous.  ``--replicas N`` fronts N continuous engines with a
class-affinity router — each replica keeps its own plan state (one can
hold gold on exact tiles while another soaks batch traffic on W8A8)
while a single watched :class:`~repro.library.store.OperatorStore` feeds
frontier refreshes to all of them.

Observability
-------------
Everything above can run under one trace.  ``--trace DIR`` (on both the
fleet and serve CLIs) turns on :mod:`repro.obs` — a stdlib-only metrics
registry (counters, gauges, histograms with exact p50/p95/p99) plus
crash-safe JSONL spans, file-per-process so fleet workers and the serve
process share a directory without locking:

    python -m repro.fleet --library runs/lib --sweep smoke --trace runs/trace
    python -m repro.launch.serve --reduced --library runs/lib \
        --profile runs/lib/_profiles/gemma3-1b.json \
        --qos-class "gold:0.02,batch:0.5" --class-mix "gold:0.4,batch:0.6" \
        --trace runs/trace --bench-json BENCH_qos.json
    python -m repro.obs summary --trace runs/trace

Fleet jobs run under ``fleet.job`` spans (engine search spans nested
inside, per-job ``engine_s``/``commit_s`` in the receipts) and the sweep
prints its five slowest jobs plus per-engine wall-time totals; the serve
emits ``serve.batch`` > ``serve.prefill``/``serve.decode``/``serve.shadow``
spans and per-class latency histograms, so the summary (and the bench
JSON's class rows) state p50/p95/p99 ms-per-step per traffic tier.  The
inspector gates CI: ``--require-span fleet.job --require-class-latency``
exits non-zero when the trace is missing either.  ``python -m repro.obs
summary --json`` emits the same report as machine-readable JSON.

Health & post-mortems
---------------------
``--health`` runs the SLO health plane (:mod:`repro.obs.health`) inside
the serve loop.  Every class that declares ``@ms`` on its spec gets a
multi-window burn-rate monitor over its live latency histogram (classes
with finite drift budgets get a drift monitor too): the short window
catches fast burns, the long window stops flapping, and the combined
state escalates ok -> warn -> page immediately but de-escalates only
after consecutive calm evaluations.  Alongside the monitors, streaming
anomaly detectors (EWMA smoothing scored by median/MAD robust z) watch
ms-per-step, shadow drift, preemption rate, and queue depth; a fired
anomaly is attributed to the nearest preceding control event — the
``serve.swap``/``serve.refresh``/``serve.control`` that most plausibly
caused it, by event id.  ``--postmortem-dir DIR`` (implies ``--health``)
adds the flight recorder: a bounded ring of recent steps, control
events, anomalies, and SLO transitions that dumps an atomic post-mortem
bundle on SLO breach, fired anomaly, or crash:

    python -m repro.launch.serve --reduced --continuous --library runs/lib \
        --profile runs/lib/_profiles/gemma3-1b.json \
        --qos-class "gold:0.02@8ms,batch:0.5" --health \
        --postmortem-dir runs/postmortems --bench-json BENCH_slo.json
    python -m repro.obs health --bench BENCH_slo.json   # exit 1 past warn
    python -m repro.obs postmortem --dir runs/postmortems

``repro.obs health`` gates CI on the bench JSON's ``health`` block
(``--max-state page`` to tolerate paging in a chaos drill); ``repro.obs
postmortem`` lists bundles (``--require N`` gates on their count, the
newest bundle prints its reason, cause, and last frames).  The bench
regression sentinel closes the loop against history: ``python -m
repro.obs diff --bench BENCH_*.json --baseline-dir benchmarks/baselines
--history-dir runs/bench-history`` compares every metric row against
the committed baseline with direction-aware tolerances
(``tolerances.json`` next to the baselines; throughput may only drop so
far, ms/step and drift may only rise so far, ``trace_count`` is exact)
and exits non-zero on regression, recording every run into the history
dir for trend plots.

Request timelines & provenance
------------------------------
Every serving-layer event with a request in scope carries its ``rid``
(and the replica name under a router), so a ``--trace`` continuous serve
leaves one causal chain per request in the span stream::

    req.queued -> req.admitted -> req.prefill -> req.decode
        [-> req.preempt -> req.resume]* -> req.done

``req.done`` carries the host-side breakdown — ``queue_ms``,
``prefill_ms``, ``decode_ms``, ``suspension_ms`` sum to ``total_ms`` —
and alongside the spans the engine writes an approximation-provenance
ledger (``prov-*.jsonl``): per request, which (plan, ladder level,
per-layer operator keys) decoded which generated-token ranges, plus the
shadow-drift samples measured in each window.  Ranges seal on plan
swap, preemption, and completion, so a finished request's ranges tile
``[0, gen_len)`` exactly — "token 7 of request 12 was decoded by plan
19a67fec54 at level 2, drift 0.03" is an auditable fact, not a guess:

    python -m repro.launch.serve --reduced --continuous --library runs/lib \
        --profile runs/lib/_profiles/gemma3-1b.json \
        --qos-class "gold:0.02@8ms,batch:0.5" --trace runs/trace \
        --health --bench-json BENCH_prov.json
    python -m repro.obs requests --trace runs/trace --require-complete
    python -m repro.obs provenance --trace runs/trace --json

``repro.obs requests`` prints the slowest-first timeline table with each
request's breakdown and critical path (where its latency actually went:
queueing, decode, or preemption suspensions); ``--require-complete``
exits 1 on any broken chain.  ``repro.obs provenance`` audits the
ledger and exits 1 when any completed request has a gap, overlap, or
dangling plan reference.  Per-class queueing-delay and suspension-time
histograms (``serve_queue_delay_ms``, ``serve_suspension_ms``) ride the
same trace dir into ``repro.obs prom``.

Cost accounting & live metrics
------------------------------
The cost plane turns the ledger into the paper's dividend, attributed
per request: the model config gives exact MLP MACs per token per layer,
each sealed token range names the plan that decoded it, and each plan
record prices its per-layer operators as an ``[area_lo, area_hi]``
bracket (composed W8 operators carry their glue adders in the upper
bound, so the guaranteed saving uses ``area_hi`` and the optimistic one
``area_lo``).  The attribution is reconciled, not sampled — a completed
request's attributed MACs must tile ``[0, gen_len)`` times the layer
dims exactly, and any gap is an audit failure::

    python -m repro.obs costs --trace runs/trace --require-reconciled
    python -m repro.obs costs --trace runs/trace --json   # machine form

The same numbers stream live while a serve runs: ``--metrics-port 0``
(or a fixed port) answers ``GET /metrics`` with the merged Prometheus
registries — ``approx_macs_total{class=...}`` and
``area_mac_saved_total{class=...,layer=...}`` tick per decode step —
plus ``/healthz`` (health-plane state as the HTTP status: ok=200,
warn=429, page=503) and ``/costs.json`` (the full reconciled report)::

    python -m repro.launch.serve --reduced --continuous --library runs/lib \
        --qos-class "gold:0.02@8ms,batch:0.5" --trace runs/trace \
        --health --metrics-port 0 --bench-json BENCH_costs.json &
    curl -s http://127.0.0.1:$PORT/metrics | grep area_mac_saved_total
    curl -s http://127.0.0.1:$PORT/healthz

For timeline debugging in a real viewer, export the span stream as
Chrome trace-event JSON — nesting and parentage preserved — and load it
at https://ui.perfetto.dev or ``chrome://tracing``::

    python -m repro.obs export --trace runs/trace --format chrome \
        --out runs/trace-chrome.json

Every trace-reading subcommand (``summary``, ``slowest``, ``requests``,
``provenance``, ``costs``, ``export``) answers a missing or empty trace
dir with one line (``no trace at <dir>``) and exit code 2.
"""

import numpy as np

from repro.core.arith import benchmark
from repro.core.baselines import mecals_like, muscat_like
from repro.core.miter import MiterZ3, worst_case_error
from repro.core.search import progressive_search
from repro.core.synth import area
from repro.core.templates import SharedTemplate
from repro.core.tensor_search import tensor_search
from repro.quant import build_lut, exact_mul_lut

ET = 2
exact = benchmark("adder_i6")
print(f"benchmark=adder_i6 (3-bit adder)  exact area={area(exact)} µm²  ET={ET}")

print("\n[1/4] SHARED progressive search (the paper)")
rs = progressive_search(exact, et=ET, method="shared",
                        wall_budget_s=150, timeout_ms=20_000)
best = rs.best
print(f"  -> {len(rs.results)} sound assignments, best area {best.area} µm² "
      f"(proxies {best.proxies}), wce={worst_case_error(exact, best.circuit)}")

print("\n[2/4] baselines")
rx = progressive_search(exact, et=ET, method="xpat",
                        wall_budget_s=120, timeout_ms=20_000)
print(f"  XPAT (nonshared): {rx.best.area if rx.best else 'none'} µm²")
rm = muscat_like(exact, et=ET, restarts=2, wall_budget_s=30)
print(f"  MUSCAT-like gate pruning: {rm.area} µm² (wce {rm.wce})")
rc = mecals_like(exact, et=ET, wall_budget_s=30)
print(f"  MECALS-like substitution: {rc.area} µm² (wce {rc.wce})")

print("\n[3/4] beyond-paper hybrid (loose-SMT seed -> tensorized minimization)")
n, m = exact.n_inputs, exact.n_outputs
pool = 10
seed = MiterZ3(exact, SharedTemplate(n, m, pit=pool)).solve(
    et=ET, its=pool, timeout_ms=60_000)
if seed is not None:
    th = tensor_search(exact, et=ET, pit=pool, population=8192,
                       generations=120, seeds=[seed])
    if th.best:
        print(f"  hybrid: {th.best.area} µm² (proxies {th.best.proxies}) "
              f"after {th.stats['evaluations']} tensorized evaluations")
        if th.best.area < best.area:
            best = th.best

print("\n[4/4] 4-bit multiplier LUT for deployment (repro.quant)")
mult = benchmark("mul_i8")
rm8 = muscat_like(mult, et=8, restarts=2, wall_budget_s=60)
lut = build_lut(rm8.circuit)
err = np.abs(lut - exact_mul_lut())
print(f"  multiplier ET=8: area {rm8.area} µm² vs exact {area(mult)} µm² "
      f"({100 * (1 - rm8.area / area(mult)):.1f}% saving)")
print(f"  LUT max error {err.max()}, mean error {err.mean():.2f}")
