"""Batched serving example: greedy-decode a reduced model with KV caches —
the serve-side counterpart of train_small.py (uses the real serve path
that the decode_32k / long_500k dry-run cells lower).

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-1b
"""

import argparse
import sys

from repro.launch import serve

if __name__ == "__main__":
    if "--reduced" not in sys.argv:
        sys.argv.append("--reduced")
    serve.main()
