"""§Perf hillclimb driver: baseline -> iterations for the 3 selected pairs.

Each iteration re-lowers the cell with one change enabled and records the
roofline record under a tagged filename in experiments/perf/.  Per-pair
results are reported through the unified
:class:`~repro.core.engine.SearchOutcome` — the same type the operator
searches emit — so winner selection is its generic pareto/min_by
machinery, not ad-hoc dict plumbing.

    PYTHONPATH=src python experiments/hillclimb.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell  # noqa: E402  (sets XLA_FLAGS first)
from repro.core.engine import SearchOutcome  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "perf")

# (arch, shape, tag, kwargs) — it0 is the re-measured baseline for exact
# comparability (identical harness, post-baseline-archive code).
STEPS = [
    # -------- pair 1: deepseek-v2-lite x train_4k (worst, collective-bound)
    ("deepseek-v2-lite-16b", "train_4k", "it1_blocked_noEP",
     dict(rules_override={"expert_fsdp": ()})),
    ("deepseek-v2-lite-16b", "train_4k", "it2_blocked_EP", dict()),
    ("deepseek-v2-lite-16b", "train_4k", "it3_EP_bf16attn",
     dict(attn_bf16=True)),
    # -------- pair 2: mixtral x train_4k (paper-representative, collective)
    ("mixtral-8x7b", "train_4k", "it1_blocked", dict()),
    ("mixtral-8x7b", "train_4k", "it2_blocked_bf16attn", dict(attn_bf16=True)),
    ("mixtral-8x7b", "train_4k", "it3_blocked_bf16_dots",
     dict(attn_bf16=True, remat="dots")),
    # -------- pair 3: command-r-plus x train_4k (memory-bound dense)
    ("command-r-plus-104b", "train_4k", "it1_bf16attn", dict(attn_bf16=True)),
    ("command-r-plus-104b", "train_4k", "it2_bf16_dots",
     dict(attn_bf16=True, remat="dots")),
    ("command-r-plus-104b", "train_4k", "it3_bf16_dots_mb4",
     dict(attn_bf16=True, remat="dots", microbatches=4)),
]


def _t_step(rec: dict) -> float:
    return max(rec["t_compute"], rec["t_memory"], rec["t_collective"])


def main() -> None:
    os.makedirs(OUT, exist_ok=True)
    by_pair: dict[tuple, SearchOutcome] = {}
    t_start = time.time()
    for arch, shape, tag, kw in STEPS:
        t0 = time.time()
        outcome = by_pair.setdefault(
            (arch, shape),
            SearchOutcome(engine="perf_hillclimb", benchmark=f"{arch}/{shape}",
                          stats={"iterations": 0, "errors": 0}),
        )
        rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT, tag=tag, **kw)
        outcome.stats["iterations"] += 1
        if rec["status"] == "ok":
            rec["tag"] = tag
            outcome.results.append(rec)
            print(f"{arch:24s} {shape:10s} {tag:22s} "
                  f"t_comp={rec['t_compute']:.3g}s t_mem={rec['t_memory']:.3g}s "
                  f"t_coll={rec['t_collective']:.3g}s "
                  f"roofline={rec['roofline_fraction']:.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
        else:
            outcome.stats["errors"] += 1
            outcome.error = f"{tag}: {rec.get('error', rec['status'])[:200]}"
            print(f"{arch} {shape} {tag} -> {rec['status']}: "
                  f"{rec.get('error','')[:200]}", flush=True)

    # pick winners by dominance over (modelled step time, HBM traffic),
    # not by eyeballing the log — same machinery as the operator library.
    for (arch, shape), outcome in by_pair.items():
        outcome.wall_s = time.time() - t_start
        if not outcome.results:
            print(f"{arch} {shape}: no successful iterations "
                  f"({outcome.error})", flush=True)
            continue
        front = outcome.pareto((_t_step, lambda r: r["hlo_bytes"]))
        tags = ", ".join(r["tag"] for r in front)
        best = outcome.min_by(_t_step)
        print(f"{arch} {shape}: pareto iterations [{tags}]; "
              f"fastest {best['tag']} at t_step={_t_step(best):.3g}s", flush=True)


if __name__ == "__main__":
    main()
