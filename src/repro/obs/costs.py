"""Cost-accounting plane: the area/energy dividend, attributed.

The provenance ledger (:mod:`repro.obs.provenance`) records which (plan,
ladder level, per-layer operator keys) decoded which generated-token
ranges; this module turns those facts into the number the paper is
actually about — how much area·work approximation saved, per request,
QoS class, layer, and plan.  Area is the standard energy proxy for
approximate DNN accelerators (Armeniakos et al., the survey the library
prices operators against), so the dividend is reported in two units:

* **approx MACs** — MLP multiply-accumulates that ran through an
  approximate operator instead of the exact array multiplier.
* **area·MACs saved** — those MACs weighted by the per-layer area gap
  ``exact_area - operator_area``, i.e. proxy energy.  Composed W8A8
  areas ignore partial-product glue adders (a documented lower bound
  since the precision tier landed), so the dividend is a **bracket**
  ``[lo, hi]``: the guaranteed saving uses each operator's glue-adder-
  inclusive upper-bound area (``CompiledLut.area_hi``), the optimistic
  end uses the composed lower bound.

MAC counts derive from the model config and mirror exactly what the
decode step routes through LUTs (``models/lm.py``): dense gated FFNs
route ``w1``/``w3``/``w2`` (3·D·F per token per layer), GELU FFNs route
``w1``/``w2`` (2·D·F), MoE layers route only their *shared* experts
(the ragged top-k expert dispatch and the router matmul are exact), and
RWKV channel mix never touches the LUT path at all.

The hard invariant: a completed request's attributed MACs must exactly
tile ``gen_len × Σ_layers macs_per_layer`` — the ledger ranges cover
``[0, gen_len)`` with zero gap and zero overlap.  Any mismatch is an
**audit failure** (``reconciled: false``, CI gates on it), never a
warning.  Everything here is stdlib-only and offline: the engine writes
``model`` and enriched ``plan`` records into the ledger, so
``python -m repro.obs costs --trace DIR`` needs nothing but the files.
"""

from __future__ import annotations

__all__ = [
    "mlp_macs_per_layer",
    "plan_cost_row",
    "cost_report",
    "render_report",
]


def mlp_macs_per_layer(cfg) -> list[int]:
    """Per-layer LUT-routable MLP MACs per generated token, derived from
    the model config.  Counts only matmuls the decode step actually
    routes through the approximate-operator path; everything else
    (attention, MoE router, ragged expert dispatch) is exact compute and
    never earns a dividend.

    Raises :class:`ValueError` for RWKV families — their channel mix
    bypasses the LUT path entirely, so there is nothing to account.
    """
    if getattr(cfg, "rwkv", None) is not None:
        raise ValueError(
            f"{cfg.name}: RWKV channel mix does not route through the LUT "
            "path; no approx MACs to account")
    d = int(cfg.d_model)
    if getattr(cfg, "moe", None) is not None:
        # only the always-on shared experts ride ffn(..., lut); the
        # sorted top-k dispatch runs exact ragged/batched matmuls
        per = int(cfg.moe.n_shared) * 3 * d * int(cfg.moe.d_ff_expert)
    elif getattr(cfg, "encoder", None) is not None:
        per = 2 * d * int(cfg.d_ff)        # GELU FFN: w1, w2
    else:
        per = 3 * d * int(cfg.d_ff)        # gated FFN: w1, w3, w2
    return [per] * int(cfg.n_layers)


def plan_cost_row(plan, macs_per_layer, *, layer_areas=None) -> dict:
    """Per-token cost increments for a live plan — the row the engine
    caches per ``plan_id`` and multiplies by decode-token counts each
    step.  ``layer_areas`` is the per-layer ``(area_lo, area_hi)`` list
    from :func:`repro.library.qos.plan_layer_areas`; without it the
    bracket collapses to the choices' own (lower-bound) areas.

    Returns ``{"macs", "approx_macs", "saved_lo", "saved_hi",
    "layers": {layer_idx: saved_lo}}`` — ``saved_lo`` is the guaranteed
    dividend (exact area minus the operator's *upper*-bound area).
    """
    total = int(sum(macs_per_layer))
    if plan is None:
        return {"macs": total, "approx_macs": 0,
                "saved_lo": 0.0, "saved_hi": 0.0, "layers": {}}
    ea = float(plan.exact_area)
    approx = 0
    saved_lo = saved_hi = 0.0
    layers: dict[str, float] = {}
    for li, c in enumerate(plan.choices):
        if c.key is None:
            continue
        m = int(macs_per_layer[li])
        if not m:
            continue
        if layer_areas is not None:
            a_lo, a_hi = layer_areas[li]
        else:
            a_lo = a_hi = float(c.area)
        approx += m
        lo = m * (ea - a_hi)
        saved_lo += lo
        saved_hi += m * (ea - a_lo)
        layers[str(li)] = lo
    return {"macs": total, "approx_macs": approx,
            "saved_lo": saved_lo, "saved_hi": saved_hi, "layers": layers}


# ---------------------------------------------------------------------------
# offline attribution over merged ledger records
# ---------------------------------------------------------------------------
def _agg(row: dict, macs: int, approx: int, lo: float, hi: float,
         tokens: int = 0) -> None:
    row["mlp_macs"] = row.get("mlp_macs", 0) + macs
    row["approx_macs"] = row.get("approx_macs", 0) + approx
    row["saved_lo"] = row.get("saved_lo", 0.0) + lo
    row["saved_hi"] = row.get("saved_hi", 0.0) + hi
    row["tokens"] = row.get("tokens", 0) + tokens


def _finish(row: dict) -> dict:
    out = {"tokens": row.get("tokens", 0),
           "mlp_macs": row.get("mlp_macs", 0),
           "approx_macs": row.get("approx_macs", 0),
           "area_mac_saved": [round(row.get("saved_lo", 0.0), 4),
                              round(row.get("saved_hi", 0.0), 4)]}
    if out["mlp_macs"]:
        out["approx_frac"] = round(out["approx_macs"] / out["mlp_macs"], 6)
    return out


def cost_report(records: list[dict]) -> dict:
    """Join the merged ledger against the model's MAC vector and the
    plans' per-layer areas into the attributed dividend (see module
    docstring).  ``reconciled`` is the hard invariant: every request
    with a ``done`` record tiles ``[0, gen_len)`` exactly *and* its
    attributed MACs equal ``gen_len × Σ macs_per_layer``.
    """
    from .provenance import audit

    aud = audit(records)
    model = None
    for r in records:
        if r.get("k") == "model":
            model = r
            break
    problems: list[str] = []
    out: dict = {
        "reconciled": False,
        "n_requests": aud["n_requests"],
        "n_done": aud["n_done"],
        "n_complete": aud["n_complete"],
    }
    if model is None:
        problems.append("no model record in ledger "
                        "(serve predates the cost plane?)")
        out["problems"] = problems
        return out
    macs = [int(m) for m in model["macs"]]
    out["model"] = {"name": model.get("name"), "n_layers": len(macs),
                    "macs_per_token": int(sum(macs))}
    mpt = sum(macs)

    plans = aud["plans"]
    plan_missing_areas: set[str] = set()
    totals: dict = {}
    classes: dict[str, dict] = {}
    layers: dict[str, dict] = {}
    plan_rows: dict[str, dict] = {}
    replicas: dict[str, dict] = {}
    requests: dict = {}
    mac_gap = 0
    reconciled = aud["n_done"] > 0

    for rkey, req in aud["requests"].items():
        tokens = sum(r["t1"] - r["t0"] for r in req["ranges"])
        attributed = tokens * mpt
        row = {"cls": req["cls"], "tokens": tokens, "mlp_macs": attributed,
               "approx_macs": 0, "saved_lo": 0.0, "saved_hi": 0.0}
        replica = req.get("replica")
        for r in req["ranges"]:
            n = r["t1"] - r["t0"]
            pid = r["plan"]
            prow = plan_rows.setdefault(pid, {})
            p = plans.get(pid)
            if pid == "exact" or p is None:
                _agg(prow, n * mpt, 0, 0.0, 0.0, tokens=n)
                continue
            areas = p.get("areas")
            areas_hi = p.get("areas_hi") or areas
            ea = p.get("exact_area")
            if areas is None or ea is None:
                if pid not in plan_missing_areas:
                    plan_missing_areas.add(pid)
                    problems.append(f"plan {pid} has no area record; its "
                                    "dividend is unpriced")
                areas = areas_hi = None
            r_approx = 0
            r_lo = r_hi = 0.0
            for li, key in enumerate(p["layers"]):
                if key == "exact" or not macs[li]:
                    continue
                m = n * macs[li]
                r_approx += m
                if areas is not None:
                    lo = m * (ea - areas_hi[li])
                    hi = m * (ea - areas[li])
                    r_lo += lo
                    r_hi += hi
                    lrow = layers.setdefault(str(li), {})
                    _agg(lrow, m, m, lo, hi)
            row["approx_macs"] += r_approx
            row["saved_lo"] += r_lo
            row["saved_hi"] += r_hi
            _agg(prow, n * mpt, r_approx, r_lo, r_hi, tokens=n)

        rrow = _finish(row)
        rrow["cls"] = req["cls"]
        if replica:
            rrow["replica"] = replica
        if "gen_len" in req:
            expected = req["gen_len"] * mpt
            rrow["expected_macs"] = expected
            rrow["reconciled"] = (attributed == expected
                                  and req["complete"])
            if not rrow["reconciled"]:
                reconciled = False
                gap = expected - attributed
                mac_gap += gap
                problems.append(
                    f"request {rkey}: attributed {attributed} MACs vs "
                    f"{expected} expected (gap {gap}); "
                    + "; ".join(req["problems"]))
        requests[rkey] = rrow
        _agg(totals, row["mlp_macs"], row["approx_macs"],
             row["saved_lo"], row["saved_hi"], tokens=tokens)
        _agg(classes.setdefault(req["cls"], {}), row["mlp_macs"],
             row["approx_macs"], row["saved_lo"], row["saved_hi"],
             tokens=tokens)
        if replica:
            _agg(replicas.setdefault(replica, {}), row["mlp_macs"],
                 row["approx_macs"], row["saved_lo"], row["saved_hi"],
                 tokens=tokens)

    if aud["n_done"] == 0:
        problems.append("no completed requests to reconcile")

    out["reconciled"] = reconciled and not plan_missing_areas
    out["mac_gap"] = mac_gap
    out["totals"] = _finish(totals)
    out["classes"] = {c: _finish(r) for c, r in sorted(classes.items())}
    out["layers"] = {k: _finish(v)
                     for k, v in sorted(layers.items(), key=lambda i: int(i[0]))}
    out["plans"] = {p: _finish(r) for p, r in sorted(plan_rows.items())}
    if replicas:
        out["replicas"] = {n: _finish(r)
                           for n, r in sorted(replicas.items())}
    out["requests"] = requests
    out["problems"] = problems
    return out


def render_report(rep: dict) -> str:
    """Human-readable costs table for the CLI."""
    lines: list[str] = []
    if "model" not in rep:
        lines.append("cost report: no model record")
        for p in rep.get("problems", ()):
            lines.append(f"  ! {p}")
        return "\n".join(lines)
    m = rep["model"]
    lines.append(f"model {m['name']}: {m['n_layers']} layers, "
                 f"{m['macs_per_token']} LUT-routable MACs/token")
    t = rep["totals"]
    lo, hi = t["area_mac_saved"]
    lines.append(
        f"requests {rep['n_requests']} (done {rep['n_done']}, complete "
        f"{rep['n_complete']})  reconciled={str(rep['reconciled']).lower()}")
    lines.append(f"tokens {t['tokens']}  mlp_macs {t['mlp_macs']}  "
                 f"approx_macs {t['approx_macs']} "
                 f"({100 * t.get('approx_frac', 0.0):.1f}%)")
    lines.append(f"area·MAC saved [{lo:.1f}, {hi:.1f}] µm²·MAC")
    hdr = f"  {'class':<10} {'tokens':>7} {'approx_macs':>12} " \
          f"{'saved_lo':>14} {'saved_hi':>14}"
    if rep["classes"]:
        lines.append(hdr)
        for cls, row in rep["classes"].items():
            clo, chi = row["area_mac_saved"]
            lines.append(f"  {cls:<10} {row['tokens']:>7} "
                         f"{row['approx_macs']:>12} {clo:>14.1f} {chi:>14.1f}")
    if rep.get("replicas"):
        lines.append(f"  {'replica':<10} {'tokens':>7} {'approx_macs':>12} "
                     f"{'saved_lo':>14} {'saved_hi':>14}")
        for name, row in rep["replicas"].items():
            clo, chi = row["area_mac_saved"]
            lines.append(f"  {name:<10} {row['tokens']:>7} "
                         f"{row['approx_macs']:>12} {clo:>14.1f} {chi:>14.1f}")
    for p in rep.get("problems", ()):
        lines.append(f"  ! {p}")
    return "\n".join(lines)
