"""Unified observability plane: metrics, spans, exporters, inspector.

One signal path for every pillar of the pipeline — fleet workers, search
engines, the serving runtime — replacing the per-subsystem ad-hoc
channels (event rings, end-of-run prints, hand-built bench dicts):

* :mod:`repro.obs.metrics` — process-wide :class:`MetricRegistry` of
  counters / gauges / histograms with exact p50/p95/p99, snapshot-able
  and mergeable across processes.
* :mod:`repro.obs.trace` — nestable :func:`span`\\ s written as crash-safe
  per-process JSONL, deterministic ids, injectable clock, merged at read
  time.
* :mod:`repro.obs.export` — Prometheus text + atomic bench-JSON views.
* ``python -m repro.obs`` — summarize/filter a trace dir (slowest spans,
  per-engine fleet wall-time, per-class latency tables).

Stdlib-only: importable before jax, numpy or z3 enter the process.
"""

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    get_registry,
    set_registry,
)
from .trace import (
    TRACE_DIR_ENV,
    Tracer,
    configure,
    current_tracer,
    event,
    read_trace,
    span,
    tracing_enabled,
)
from .export import (
    dump_metrics,
    prometheus_text,
    read_metrics,
    write_bench_json,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "get_registry",
    "set_registry",
    "TRACE_DIR_ENV",
    "Tracer",
    "configure",
    "current_tracer",
    "event",
    "read_trace",
    "span",
    "tracing_enabled",
    "dump_metrics",
    "prometheus_text",
    "read_metrics",
    "write_bench_json",
]
