"""Unified observability plane: metrics, spans, exporters, inspector.

One signal path for every pillar of the pipeline — fleet workers, search
engines, the serving runtime — replacing the per-subsystem ad-hoc
channels (event rings, end-of-run prints, hand-built bench dicts):

* :mod:`repro.obs.metrics` — process-wide :class:`MetricRegistry` of
  counters / gauges / histograms with exact p50/p95/p99, snapshot-able
  and mergeable across processes.
* :mod:`repro.obs.trace` — nestable :func:`span`\\ s written as crash-safe
  per-process JSONL, deterministic ids, injectable clock, merged at read
  time.
* :mod:`repro.obs.export` — Prometheus text + atomic bench-JSON views.
* :mod:`repro.obs.health` / :mod:`repro.obs.anomaly` /
  :mod:`repro.obs.flight` — the SLO health plane: multi-window burn-rate
  monitors with ok/warn/page hysteresis, streaming EWMA+MAD anomaly
  detectors attributed to control-plane events, and a flight recorder
  that dumps atomic post-mortem bundles on breach/anomaly/crash.
* :mod:`repro.obs.regress` — bench regression sentinel: BENCH_*.json vs
  committed baselines under direction-aware per-metric tolerances.
* :mod:`repro.obs.provenance` / :mod:`repro.obs.costs` — the
  approximation-provenance ledger and the cost-accounting plane over it:
  per-request/class/layer/plan approx-MAC and area·MAC dividend
  attribution with a hard tiling-reconciliation invariant.
* :mod:`repro.obs.httpd` — live ``/metrics`` (Prometheus), ``/healthz``
  and ``/costs.json`` endpoint a ``--metrics-port`` serve answers while
  running.
* :mod:`repro.obs.perfetto` — Chrome trace-event export of the span
  stream for Perfetto / ``chrome://tracing``.
* ``python -m repro.obs`` — summarize/filter a trace dir (slowest spans,
  per-engine fleet wall-time, per-class latency tables), gate on health
  (``health``), read post-mortems (``postmortem``), diff benches
  (``diff``), audit provenance (``provenance``), attribute the dividend
  (``costs``), export for external viewers (``export``).

Stdlib-only: importable before jax, numpy or z3 enter the process.
"""

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    get_registry,
    set_registry,
)
from .trace import (
    TRACE_DIR_ENV,
    Tracer,
    configure,
    current_tracer,
    event,
    read_trace,
    span,
    tracing_enabled,
)
from .export import (
    dump_metrics,
    prometheus_text,
    read_metrics,
    write_bench_json,
)
from .anomaly import (
    Anomaly,
    AnomalyPlane,
    ControlEvent,
    EventLog,
    RobustDetector,
    robust_zscores,
)
from .health import (
    BurnRate,
    HealthPlane,
    SLOMonitor,
    state_penalty,
    state_rank,
)
from .flight import FlightRecorder, read_postmortems
from .regress import Rule, compare_bench, flatten, load_rules
from .provenance import ProvenanceLedger, audit, ledger_for, read_ledger
from .costs import cost_report, mlp_macs_per_layer, plan_cost_row
from .httpd import MetricsServer
from .perfetto import chrome_trace, export_chrome

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "get_registry",
    "set_registry",
    "TRACE_DIR_ENV",
    "Tracer",
    "configure",
    "current_tracer",
    "event",
    "read_trace",
    "span",
    "tracing_enabled",
    "dump_metrics",
    "prometheus_text",
    "read_metrics",
    "write_bench_json",
    "Anomaly",
    "AnomalyPlane",
    "ControlEvent",
    "EventLog",
    "RobustDetector",
    "robust_zscores",
    "BurnRate",
    "HealthPlane",
    "SLOMonitor",
    "state_penalty",
    "state_rank",
    "FlightRecorder",
    "read_postmortems",
    "Rule",
    "compare_bench",
    "flatten",
    "load_rules",
    "ProvenanceLedger",
    "audit",
    "ledger_for",
    "read_ledger",
    "cost_report",
    "mlp_macs_per_layer",
    "plan_cost_row",
    "MetricsServer",
    "chrome_trace",
    "export_chrome",
]
