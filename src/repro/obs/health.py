"""SLO health plane: multi-window burn rates, states, and the bundle
that ties monitors + anomaly attribution + flight recorder together.

The serving tier declares per-class contracts (``gold:0.02@8ms`` — a
drift budget and a p95 per-step latency SLO); PR 6/7 *measure* against
them but nothing *acts*.  This module turns the measurements into
states:

* :class:`BurnRate` — one SLO's multi-window burn-rate monitor, SRE
  style but over **observation counts** instead of wall-clock (serves
  here are synthetic and step-driven; counts make the math exactly
  hand-computable in tests).  ``burn = bad_fraction / budget_fraction``:
  burn 1.0 spends the error budget exactly, burn 3.0 spends it 3× too
  fast.  Paging requires the *short and long* windows to both run hot —
  the short window gives fast detection, the long window refuses to page
  on a blip — and de-escalation needs ``clear_patience`` consecutive
  calm evaluations (hysteresis), so states never flap.
* :class:`SLOMonitor` — per-QoS-class monitors built straight from the
  declared :class:`~repro.sensitivity.classes.ClassBook`: a latency
  monitor per class with an ``slo_ms`` (budget fraction = the implied
  1 - 0.95, since ``slo_ms`` is declared as a p95) and a drift monitor
  per class with a finite drift budget.
* :class:`HealthPlane` — the engine-facing bundle: SLO monitors + the
  :class:`~repro.obs.anomaly.AnomalyPlane` + the
  :class:`~repro.obs.flight.FlightRecorder`, one ``observe_step`` call
  per decode step, ``note_event`` mirrors of the control-plane trace
  events, gauge exports (``health_state``, ``serve_slo_ok{class}``) into
  the metric registry so the Prometheus text carries them, and automatic
  post-mortem dumps on page transitions, fired anomalies, and crashes.

Everything is O(window) integer/float work per step — the serve smoke
gates the whole plane at ≤2% ms/step overhead.
"""

from __future__ import annotations

from collections import deque

from .anomaly import AnomalyPlane
from .flight import FlightRecorder
from .metrics import MetricRegistry, get_registry

__all__ = [
    "BurnRate",
    "SLOMonitor",
    "HealthPlane",
    "STATES",
    "state_rank",
    "state_penalty",
]

# severity order; rank comparisons everywhere use this
STATES = ("ok", "warn", "page")
_RANK = {s: i for i, s in enumerate(STATES)}

# routing penalty added to a replica's load score per health state: a
# warn replica looks one queued request busier, a paged replica four —
# enough that the router measurably sheds load without black-holing the
# replica entirely (it still drains what only it can serve)
_PENALTY = {"ok": 0.0, "warn": 1.0, "page": 4.0}


def state_rank(state: str) -> int:
    return _RANK[state]


def state_penalty(state: str) -> float:
    return _PENALTY[state]


def _worst(states) -> str:
    return max(states, key=state_rank, default="ok")


class BurnRate:
    """One SLO's multi-window burn-rate monitor over observation counts.

    Each ``observe(bad)`` folds one boolean into a short and a long
    sliding window.  ``burn = bad_fraction / budget`` per window, where
    ``budget`` is the allowed bad fraction (0.05 for a p95-declared SLO).
    With budget 0.1 and 3 violations in a 10-observation window the
    short burn is exactly 3.0 — tests hand-compute these.

    States: **page** when both windows burn at ``page_burn`` or hotter
    (fast *and* sustained), **warn** when both reach ``warn_burn``, else
    calm.  Escalation is immediate; de-escalation waits for
    ``clear_patience`` consecutive calm(er) evaluations.  Windows
    shorter than ``min_count`` observations never page (cold-start
    guard).
    """

    def __init__(self, *, budget: float, short_window: int = 32,
                 long_window: int = 128, warn_burn: float = 1.0,
                 page_burn: float = 2.0, clear_patience: int = 8,
                 min_count: int = 4) -> None:
        if not 0 < budget <= 1:
            raise ValueError(f"budget fraction {budget} outside (0, 1]")
        if short_window < 1 or long_window < short_window:
            raise ValueError(
                f"need long_window >= short_window >= 1 "
                f"(got {long_window}/{short_window})")
        if page_burn < warn_burn:
            raise ValueError(
                f"page_burn {page_burn} below warn_burn {warn_burn}")
        self.budget = float(budget)
        self.warn_burn = float(warn_burn)
        self.page_burn = float(page_burn)
        self.clear_patience = max(1, int(clear_patience))
        self.min_count = max(1, int(min_count))
        self._short: deque[int] = deque(maxlen=int(short_window))
        self._long: deque[int] = deque(maxlen=int(long_window))
        self.state = "ok"
        self._calm = 0
        self.observations = 0
        self.violations = 0

    def _burn(self, window: deque) -> float:
        if not window:
            return 0.0
        return (sum(window) / len(window)) / self.budget

    @property
    def burn_short(self) -> float:
        return self._burn(self._short)

    @property
    def burn_long(self) -> float:
        return self._burn(self._long)

    def observe(self, bad: bool) -> str:
        """Fold one observation; returns the (possibly new) state."""
        flag = 1 if bad else 0
        self._short.append(flag)
        self._long.append(flag)
        self.observations += 1
        self.violations += flag
        target = self._target()
        if state_rank(target) > state_rank(self.state):
            self.state = target          # escalate immediately
            self._calm = 0
        elif state_rank(target) < state_rank(self.state):
            self._calm += 1              # de-escalate under hysteresis
            if self._calm >= self.clear_patience:
                self.state = target
                self._calm = 0
        else:
            self._calm = 0
        return self.state

    def _target(self) -> str:
        if len(self._short) < self.min_count:
            return "ok"
        s, l = self.burn_short, self.burn_long
        if s >= self.page_burn and l >= self.page_burn:
            return "page"
        if s >= self.warn_burn and l >= self.warn_burn:
            return "warn"
        return "ok"

    def to_doc(self) -> dict:
        return {
            "state": self.state,
            "budget": self.budget,
            "burn_short": round(self.burn_short, 4),
            "burn_long": round(self.burn_long, 4),
            "observations": self.observations,
            "violations": self.violations,
        }


class SLOMonitor:
    """Per-class burn-rate monitors derived from the declared tiers.

    A class with an ``slo_ms`` gets a latency monitor (an observation is
    bad when that step's ms-per-step exceeded the SLO; the budget
    fraction is ``1 - quantile`` for the p95 the spec declares).  A class
    with a finite positive drift budget gets a drift monitor fed only on
    shadow-measured steps (bad = measured drift above budget; drift is a
    mean-style budget so the allowed-overrun fraction is configurable,
    default 20%).
    """

    def __init__(self, book=None, *, quantile: float = 0.95,
                 drift_bad_fraction: float = 0.2,
                 short_window: int = 32, long_window: int = 128,
                 warn_burn: float = 1.0, page_burn: float = 2.0,
                 clear_patience: int = 8, min_count: int = 4) -> None:
        if not 0 < quantile < 1:
            raise ValueError(f"quantile {quantile} outside (0, 1)")
        self._mk = dict(short_window=short_window, long_window=long_window,
                        warn_burn=warn_burn, page_burn=page_burn,
                        clear_patience=clear_patience, min_count=min_count)
        self.latency: dict[str, BurnRate] = {}
        self.drift: dict[str, BurnRate] = {}
        self.slo_ms: dict[str, float] = {}
        self.drift_budget: dict[str, float] = {}
        if book is not None:
            for c in book:
                if c.slo_ms is not None:
                    self.add_latency_slo(c.name, c.slo_ms,
                                         budget=1.0 - quantile)
                if 0 < c.drift_budget < float("inf"):
                    self.add_drift_slo(c.name, c.drift_budget,
                                       budget=drift_bad_fraction)

    def add_latency_slo(self, cls: str, slo_ms: float, *,
                        budget: float) -> None:
        self.slo_ms[cls] = float(slo_ms)
        self.latency[cls] = BurnRate(budget=budget, **self._mk)

    def add_drift_slo(self, cls: str, drift_budget: float, *,
                      budget: float) -> None:
        self.drift_budget[cls] = float(drift_budget)
        self.drift[cls] = BurnRate(budget=budget, **self._mk)

    def __bool__(self) -> bool:
        return bool(self.latency or self.drift)

    # ------------------------------------------------------------------ feed
    def observe_latency(self, cls: str, step_ms: float) -> str | None:
        mon = self.latency.get(cls)
        if mon is None:
            return None
        return mon.observe(float(step_ms) > self.slo_ms[cls])

    def observe_drift(self, cls: str, drift: float) -> str | None:
        mon = self.drift.get(cls)
        if mon is None:
            return None
        return mon.observe(float(drift) > self.drift_budget[cls])

    # ------------------------------------------------------------------ read
    def class_state(self, cls: str) -> str:
        states = []
        if cls in self.latency:
            states.append(self.latency[cls].state)
        if cls in self.drift:
            states.append(self.drift[cls].state)
        return _worst(states)

    @property
    def classes(self) -> list[str]:
        return sorted(set(self.latency) | set(self.drift))

    @property
    def worst_state(self) -> str:
        return _worst(self.class_state(c) for c in self.classes)

    def to_doc(self) -> dict:
        doc = {}
        for cls in self.classes:
            row: dict = {"state": self.class_state(cls)}
            if cls in self.latency:
                row["latency"] = {"slo_ms": self.slo_ms[cls],
                                  **self.latency[cls].to_doc()}
            if cls in self.drift:
                row["drift"] = {"drift_budget": self.drift_budget[cls],
                                **self.drift[cls].to_doc()}
            doc[cls] = row
        return doc


class HealthPlane:
    """One engine's health: SLO monitors + anomaly plane + flight
    recorder, fed once per decode step.

    ``observe_step`` fans one step's telemetry out to every monitor and
    detector, exports the resulting states as registry gauges, records
    the frame into the flight ring, and dumps a post-mortem bundle on a
    page transition or a fired anomaly (crashes dump via
    :meth:`record_crash`).  ``penalty`` is what the replica router adds
    to this engine's load score.
    """

    def __init__(self, book=None, *, registry: MetricRegistry | None = None,
                 postmortem_dir=None, tag: str | None = None,
                 slo: SLOMonitor | None = None,
                 anomaly: AnomalyPlane | None = None,
                 recorder: FlightRecorder | None = None,
                 monitor_config: dict | None = None,
                 anomaly_config: dict | None = None,
                 capacity: int = 512, max_bundles: int = 16) -> None:
        self.registry = registry if registry is not None else get_registry()
        self.slo = slo if slo is not None \
            else SLOMonitor(book, **(monitor_config or {}))
        self.anomaly = anomaly if anomaly is not None \
            else AnomalyPlane(**(anomaly_config or {}))
        self.recorder = recorder if recorder is not None \
            else FlightRecorder(capacity=capacity,
                                postmortem_dir=postmortem_dir, tag=tag,
                                max_bundles=max_bundles)
        self.anomalies_fired = 0
        self.pages = 0
        self._last_states: dict[str, str] = {}
        self._step = 0

    # ----------------------------------------------------------------- events
    def note_event(self, name: str, step: int | None = None,
                   event_id: str = "", **attrs) -> None:
        """Mirror of a control-plane trace event (``serve.swap``,
        ``serve.refresh``, ``serve.control``, ``serve.preempt``,
        ``serve.resume``): feeds anomaly attribution and the flight
        ring.  ``step`` defaults to the last observed step (events
        between steps belong to it)."""
        at = self._step if step is None else int(step)
        self.anomaly.note_event(name, at, event_id, **attrs)
        self.recorder.note("event", name=name, step=at,
                           event_id=event_id, **attrs)

    def set_context(self, **kv) -> None:
        self.recorder.set_context(**kv)

    # ------------------------------------------------------------------- step
    def observe_step(self, *, step: int, step_ms: float,
                     classes: dict | None = None,
                     drift: float | None = None, backlog: int = 0,
                     occupancy: float = 0.0, preemptions: int = 0,
                     plan_id: str | None = None, level: int | None = None,
                     pages: dict | None = None,
                     class_state: dict | None = None) -> dict:
        """Feed one decode step.  ``classes`` maps each *active* class to
        its row (any dict; only membership is used for latency
        attribution — every active class experienced ``step_ms``).
        ``preemptions`` is this step's count (a rate, not a cumulative).
        Returns ``{"state", "transitions", "anomalies", "dumps"}``.
        """
        self._step = int(step)
        transitions: list[dict] = []
        for cls in (classes or {}):
            self.slo.observe_latency(cls, step_ms)
        if drift is not None:
            for cls in (classes or {}):
                self.slo.observe_drift(cls, drift)
        for cls in self.slo.classes:
            now = self.slo.class_state(cls)
            before = self._last_states.get(cls, "ok")
            if now != before:
                transitions.append(
                    {"class": cls, "from": before, "to": now, "step": step})
                self._last_states[cls] = now

        anomalies = []
        for signal, value in (("ms_per_step", step_ms),
                              ("drift", drift),
                              ("preempt_rate", float(preemptions)),
                              ("queue_depth", float(backlog))):
            if value is None:
                continue
            fired = self.anomaly.observe(signal, float(value), step)
            if fired is not None:
                anomalies.append(fired)
        self.anomalies_fired += len(anomalies)

        # export: state gauges ride the registry so the Prometheus text
        # and metric snapshots carry them (satellite: SLO OK/MISS series)
        for cls in self.slo.classes:
            st = self.slo.class_state(cls)
            self.registry.gauge("serve_slo_ok",
                                **{"class": cls}).set(1.0 if st == "ok"
                                                      else 0.0)
            self.registry.gauge("health_state",
                                **{"class": cls}).set(state_rank(st))
        self.registry.gauge("health_anomalies").set(self.anomalies_fired)

        # flight ring: the step frame + current engine shape
        self.recorder.set_context(plan_id=plan_id, level=level,
                                  step=step, pages=pages,
                                  class_state=class_state)
        self.recorder.note("step", step=step, step_ms=round(step_ms, 4),
                           classes=sorted(classes or {}), drift=drift,
                           backlog=backlog,
                           occupancy=round(occupancy, 4),
                           preemptions=preemptions, plan_id=plan_id)
        for a in anomalies:
            self.recorder.note("anomaly", **a.to_doc())
        for t in transitions:
            self.recorder.note("slo", **t)

        dumps = []
        paged = [t for t in transitions if t["to"] == "page"]
        if paged:
            self.pages += len(paged)
            p = self.recorder.dump(
                "slo_breach",
                detail="; ".join(f"{t['class']}: {t['from']}->page"
                                 for t in paged),
                extra={"health": self.report()})
            if p is not None:
                dumps.append(str(p))
        if anomalies:
            p = self.recorder.dump(
                "anomaly",
                detail="; ".join(a.describe() for a in anomalies),
                extra={"health": self.report()})
            if p is not None:
                dumps.append(str(p))
        return {"state": self.worst_state, "transitions": transitions,
                "anomalies": anomalies, "dumps": dumps}

    def record_crash(self, exc: BaseException) -> str | None:
        """Dump the ring on an engine crash; re-raise at the call site."""
        p = self.recorder.dump(
            "crash", detail=f"{type(exc).__name__}: {exc}",
            extra={"health": self.report()})
        return None if p is None else str(p)

    # ------------------------------------------------------------------- read
    @property
    def worst_state(self) -> str:
        return self.slo.worst_state

    @property
    def penalty(self) -> float:
        """Load-score penalty the replica router adds for this engine."""
        return state_penalty(self.worst_state)

    def report(self) -> dict:
        return {
            "state": self.worst_state,
            "classes": self.slo.to_doc(),
            "anomalies_fired": self.anomalies_fired,
            "pages": self.pages,
            "dumps": self.recorder.dumps,
            "recent_anomalies": [a.to_doc()
                                 for a in list(self.anomaly.anomalies)[-8:]],
        }
