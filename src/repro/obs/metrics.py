"""Process-wide metric registry: counters, gauges, bucketed histograms.

The signal plane every subsystem (fleet, search engines, serving) records
into.  Stdlib-only by design — fleet workers import this before jax and a
bare image can always read a snapshot.  Three metric kinds:

* :class:`Counter` — monotone float; merged across processes by summing.
* :class:`Gauge`   — last-written value (queue depth, backoff level).
* :class:`Histogram` — fixed cumulative buckets *plus* a bounded sample
  reservoir.  While fewer than ``max_samples`` observations have been
  recorded the quantiles are **exact** (numpy-``percentile``-compatible
  linear interpolation over the raw samples); after the reservoir wraps
  they degrade gracefully to bucket interpolation.  Bucket counts are
  always exact, so merged snapshots never lie about distribution mass.

A :class:`MetricRegistry` keys metrics by ``(kind, name, labels)``;
``snapshot()`` emits a plain JSON-able document and ``merge()`` folds a
snapshot from another process back in — the fleet's file-per-process
trace layout carries one snapshot per worker and the reader merges them.
"""

from __future__ import annotations

import math
import threading
from collections import deque
from typing import Iterable, Mapping

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "DEFAULT_BUCKETS",
    "LATENCY_MS_BUCKETS",
    "get_registry",
    "set_registry",
]

# generic magnitude ladder (seconds-ish quantities)
DEFAULT_BUCKETS: tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0,
)
# decode-step latency in milliseconds (sub-ms reduced CPU models up to
# multi-second pathological steps)
LATENCY_MS_BUCKETS: tuple[float, ...] = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0,
)

QUANTILES = (0.5, 0.95, 0.99)   # the p50/p95/p99 every exporter reports


def _labels_key(labels: Mapping[str, object]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone accumulator (float so second-counters work too)."""

    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increments are non-negative, got {n}")
        self.value += float(n)

    def to_doc(self) -> dict:
        return {"value": self.value}

    def merge_doc(self, doc: dict) -> None:
        self.value += float(doc["value"])


class Gauge:
    """Last-written value."""

    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += float(n)

    def to_doc(self) -> dict:
        return {"value": self.value}

    def merge_doc(self, doc: dict) -> None:
        # cross-process merge has no write order; "most extreme" is the
        # useful aggregate for the gauges we keep (queue depth, backoff)
        self.value = max(self.value, float(doc["value"]))


class Histogram:
    """Fixed cumulative buckets + a bounded reservoir for exact quantiles.

    ``counts[i]`` is the number of observations ``<= buckets[i]``
    (non-cumulative storage; exporters cumulate), with one overflow slot.
    """

    kind = "histogram"

    def __init__(self, buckets: Iterable[float] = DEFAULT_BUCKETS,
                 max_samples: int = 4096) -> None:
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: deque[float] = deque(maxlen=int(max_samples))

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self._samples.append(v)
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def exact(self) -> bool:
        """Quantiles are exact while the reservoir holds every sample."""
        return self.count == len(self._samples)

    def quantile(self, q: float) -> float | None:
        """numpy-``percentile``-compatible (linear interpolation) while the
        reservoir is complete; bucket-interpolated once it has wrapped."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return None
        if self._samples and self.exact:
            xs = sorted(self._samples)
            rank = q * (len(xs) - 1)
            lo = int(math.floor(rank))
            hi = min(lo + 1, len(xs) - 1)
            return xs[lo] + (rank - lo) * (xs[hi] - xs[lo])
        # bucket interpolation: walk the cumulative counts to the target
        # rank, interpolate linearly inside the crossing bucket
        target = q * self.count
        cum = 0
        lo_bound = self.min
        for i, c in enumerate(self.counts):
            hi_bound = (self.buckets[i] if i < len(self.buckets) else self.max)
            if c and cum + c >= target:
                frac = (target - cum) / c
                return min(max(lo_bound + frac * (hi_bound - lo_bound),
                               self.min), self.max)
            cum += c
            if c:
                lo_bound = hi_bound
        return self.max

    def percentiles(self) -> dict:
        return {f"p{int(q * 100)}": self.quantile(q) for q in QUANTILES}

    def to_doc(self) -> dict:
        return {
            "buckets": list(self.buckets),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": None if self.count == 0 else self.min,
            "max": None if self.count == 0 else self.max,
            "samples": [float(s) for s in self._samples],
        }

    def merge_doc(self, doc: dict) -> None:
        if tuple(doc["buckets"]) != self.buckets:
            raise ValueError(
                f"cannot merge histograms with different buckets: "
                f"{tuple(doc['buckets'])} vs {self.buckets}")
        for i, c in enumerate(doc["counts"]):
            self.counts[i] += int(c)
        self.count += int(doc["count"])
        self.sum += float(doc["sum"])
        if doc.get("min") is not None:
            self.min = min(self.min, float(doc["min"]))
        if doc.get("max") is not None:
            self.max = max(self.max, float(doc["max"]))
        for s in doc.get("samples", ()):
            self._samples.append(float(s))


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricRegistry:
    """All of one process's metrics, keyed by ``(name, labels)``.

    Thread-safe for creation (the serving loop and a watcher thread may
    race a first ``counter()`` call); individual metric updates are plain
    float ops under the GIL.
    """

    def __init__(self) -> None:
        self._metrics: dict[tuple[str, tuple], object] = {}
        self._kinds: dict[str, str] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str, labels: Mapping[str, object],
             factory):
        key = (name, _labels_key(labels))
        with self._lock:
            known = self._kinds.get(name)
            if known is not None and known != kind:
                raise TypeError(
                    f"metric {name!r} is a {known}, not a {kind}")
            m = self._metrics.get(key)
            if m is None:
                m = factory()
                self._metrics[key] = m
                self._kinds[name] = kind
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, buckets: Iterable[float] | None = None,
                  **labels) -> Histogram:
        return self._get(
            "histogram", name, labels,
            lambda: Histogram(DEFAULT_BUCKETS if buckets is None else buckets))

    # ------------------------------------------------------------------ views
    def entries(self) -> list[tuple[str, dict, object]]:
        """``(name, labels-dict, metric)`` rows, deterministically ordered."""
        with self._lock:
            items = sorted(self._metrics.items())
        return [(name, dict(labels), m) for (name, labels), m in items]

    def find(self, name: str, **labels) -> object | None:
        """The metric at exactly ``(name, labels)``, or ``None``."""
        with self._lock:
            return self._metrics.get((name, _labels_key(labels)))

    def with_name(self, name: str) -> list[tuple[dict, object]]:
        """Every labeled instance of one metric family."""
        return [(labels, m) for n, labels, m in self.entries() if n == name]

    def snapshot(self) -> dict:
        """JSON-able document: the cross-process interchange format."""
        return {
            "metrics": [
                {"name": name, "kind": m.kind, "labels": labels,
                 **m.to_doc()}
                for name, labels, m in self.entries()
            ],
        }

    def merge(self, snapshot: dict) -> None:
        """Fold another process's :meth:`snapshot` into this registry."""
        for row in snapshot.get("metrics", ()):
            kind, name, labels = row["kind"], row["name"], row["labels"]
            if kind not in _KINDS:
                raise ValueError(f"unknown metric kind {kind!r} in snapshot")
            if kind == "histogram":
                m = self.histogram(name, buckets=row["buckets"], **labels)
            else:
                m = self._get(kind, name, labels, _KINDS[kind])
            m.merge_doc(row)

    @classmethod
    def from_snapshots(cls, snapshots: Iterable[dict]) -> "MetricRegistry":
        reg = cls()
        for snap in snapshots:
            reg.merge(snap)
        return reg


# the process-wide default registry subsystems record into unless handed
# an explicit one (Telemetry keeps its own so concurrent serves and tests
# never cross-contaminate counters)
_REGISTRY = MetricRegistry()


def get_registry() -> MetricRegistry:
    return _REGISTRY


def set_registry(registry: MetricRegistry) -> MetricRegistry:
    """Swap the process-wide registry (tests); returns the previous one."""
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, registry
    return prev
