"""Nestable spans written as crash-safe, per-process JSONL trace files.

Layout: one trace *directory* per run, one ``spans-<tag>.jsonl`` file per
writing process (tag = hostname + pid + an inherited worker discriminator)
— concurrent fleet workers never contend on a file, and the merge happens
at read time (:func:`read_trace` unions every file, drops torn trailing
lines, and dedups by span id, so re-reading / re-copying files is
idempotent).

Crash safety: every span is one self-contained JSON line, flushed on span
end.  A process dying mid-write can tear at most the final line, which
the reader detects and skips — no span that *was* fully written is ever
lost, and side files (metric snapshots) go through the same
``os.replace`` discipline as :func:`repro.library.store.atomic_write_json`
(see :func:`atomic_write_json` here; obs stays stdlib-only).

Span ids are **deterministic**: derived from ``(process tag, sequence
number, name, parent id)``, not the clock, so a test with an injected
clock and a fixed tag reproduces byte-identical traces.  Wall-clock never
leaks into ids — only into the ``t0``/``dur_s`` fields, via an injectable
``clock``.

Process-global use::

    configure("runs/trace")            # exports REPRO_TRACE_DIR for children
    with span("fleet.job", engine="muscat", bits=4):
        ...
    event("serve.swap", reason="qos-load")

``span()`` is a no-op (shared null context) when tracing was never
configured, so instrumented hot paths cost one attribute load when off.
Worker processes (fork *or* spawn) auto-configure from the inherited
``REPRO_TRACE_DIR`` environment variable on their first span.
"""

from __future__ import annotations

import contextlib
import json
import hashlib
import os
import socket
import tempfile
import threading
import time
from pathlib import Path
from typing import Callable, Iterator

__all__ = [
    "TRACE_DIR_ENV",
    "DEFAULT_SEGMENT_BYTES",
    "Tracer",
    "SpanHandle",
    "atomic_write_json",
    "configure",
    "current_tracer",
    "tracing_enabled",
    "span",
    "event",
    "read_trace",
]

TRACE_DIR_ENV = "REPRO_TRACE_DIR"

# rotate a process's span file once it crosses this many bytes: a
# long-running serve keeps a bounded active segment, and the rotated
# segments still match the ``spans-*.jsonl`` read glob so the merge is
# unchanged.  0 disables rotation.
DEFAULT_SEGMENT_BYTES = 8 * 1024 * 1024


def atomic_write_json(path: Path | str, doc: dict) -> None:
    """The store's temp-file + ``os.replace`` discipline, duplicated here
    so the observability core imports nothing heavier than the stdlib."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=f".{path.stem}.",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(json.dumps(doc, sort_keys=True, indent=1))
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class SpanHandle:
    """What ``with span(...) as sp`` yields: lets the body attach result
    attributes (status, counts) that are only known at span end."""

    __slots__ = ("name", "span_id", "parent_id", "attrs", "t0")

    def __init__(self, name: str, span_id: str, parent_id: str | None,
                 attrs: dict, t0: float) -> None:
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs = attrs
        self.t0 = t0

    def set(self, **attrs) -> "SpanHandle":
        self.attrs.update(attrs)
        return self


class Tracer:
    """One process's span writer.

    ``process_tag`` defaults to ``<hostname>-<pid>`` (file-per-process);
    tests pin it (plus ``clock``) for fully deterministic traces.  The
    tracer is fork-aware: a forked child detects the pid change on its
    first span and re-opens its own file with a fresh tag, so two
    processes never interleave writes into one JSONL file.
    """

    def __init__(self, root: str | os.PathLike, *,
                 clock: Callable[[], float] = time.time,
                 process_tag: str | None = None,
                 max_segment_bytes: int = DEFAULT_SEGMENT_BYTES) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._clock = clock
        self._fixed_tag = process_tag
        self._pid = os.getpid()
        self._tag = process_tag or self._default_tag()
        self._seq = 0
        self._fh = None
        self._local = threading.local()
        self._lock = threading.Lock()
        self.max_segment_bytes = int(max_segment_bytes)
        self._size = 0
        self._rot = 0

    def _default_tag(self) -> str:
        return f"{socket.gethostname()}-{os.getpid()}"

    @property
    def tag(self) -> str:
        """The process tag side files (e.g. the provenance ledger's
        ``prov-<tag>.jsonl``) share so one run's artifacts correlate."""
        return self._tag

    @property
    def path(self) -> Path:
        return self.root / f"spans-{self._tag}.jsonl"

    # ----------------------------------------------------------------- write
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _fork_check(self) -> None:
        pid = os.getpid()
        if pid != self._pid:   # forked child inherited the parent tracer
            self._pid = pid
            self._tag = (f"{self._fixed_tag}-f{pid}" if self._fixed_tag
                         else self._default_tag())
            self._seq = 0
            self._fh = None
            self._local = threading.local()
            self._size = 0
            self._rot = 0

    def _rotate_locked(self) -> None:
        """Seal the active segment under a numbered name (still matching
        the ``spans-*.jsonl`` read glob) and start a fresh one.  Rotation
        happens at line boundaries only, so a rotated segment is never
        torn — only a crashed writer's *active* tail can be."""
        self._fh.close()
        while True:
            rotated = self.root / f"spans-{self._tag}.{self._rot:04d}.jsonl"
            self._rot += 1
            if not rotated.exists():
                break
        os.replace(self.path, rotated)
        self._fh = open(self.path, "a")
        self._size = 0

    def _write(self, doc: dict) -> None:
        data = json.dumps(doc, sort_keys=True) + "\n"
        with self._lock:
            if self._fh is None:
                self._fh = open(self.path, "a")
                self._size = self.path.stat().st_size
            if (self.max_segment_bytes > 0 and self._size > 0
                    and self._size + len(data) > self.max_segment_bytes):
                self._rotate_locked()
            self._fh.write(data)
            self._fh.flush()
            self._size += len(data)

    def _next_id(self, name: str, parent_id: str | None) -> str:
        with self._lock:
            seq, self._seq = self._seq, self._seq + 1
        blob = f"{self._tag}|{seq}|{name}|{parent_id or ''}"
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    @contextlib.contextmanager
    def span(self, name: str, **attrs) -> Iterator[SpanHandle]:
        self._fork_check()
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        handle = SpanHandle(name, self._next_id(name, parent), parent,
                            dict(attrs), self._clock())
        stack.append(handle)
        try:
            yield handle
        finally:
            stack.pop()
            self._write({
                "name": handle.name,
                "id": handle.span_id,
                "parent": handle.parent_id,
                "t0": handle.t0,
                "dur_s": self._clock() - handle.t0,
                "attrs": handle.attrs,
            })

    def event(self, name: str, **attrs) -> str:
        """Zero-duration span: swap decisions, refreshes, cause markers.
        Returns the span id so callers (the health plane's anomaly
        attribution) can name the exact trace event later."""
        with self.span(name, **attrs) as handle:
            pass
        return handle.span_id

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


# ---------------------------------------------------------------------------
# process-global tracer
# ---------------------------------------------------------------------------
_tracer: Tracer | None = None
_checked_env = False


def configure(root: str | os.PathLike, *,
              clock: Callable[[], float] = time.time,
              process_tag: str | None = None,
              export_env: bool = True,
              max_segment_bytes: int = DEFAULT_SEGMENT_BYTES) -> Tracer:
    """Install the process-global tracer.  ``export_env`` publishes the
    trace dir to child processes (fleet pool workers, spawned or forked)
    through :data:`TRACE_DIR_ENV`."""
    global _tracer, _checked_env
    _tracer = Tracer(root, clock=clock, process_tag=process_tag,
                     max_segment_bytes=max_segment_bytes)
    _checked_env = True
    if export_env:
        os.environ[TRACE_DIR_ENV] = str(Path(root))
    return _tracer


def current_tracer() -> Tracer | None:
    """The global tracer; lazily adopts :data:`TRACE_DIR_ENV` so worker
    processes trace into the dir their parent configured."""
    global _tracer, _checked_env
    if _tracer is None and not _checked_env:
        _checked_env = True
        env_root = os.environ.get(TRACE_DIR_ENV)
        if env_root:
            _tracer = Tracer(env_root)
    return _tracer


def reset(*, clear_env: bool = True) -> None:
    """Drop the global tracer (tests)."""
    global _tracer, _checked_env
    if _tracer is not None:
        _tracer.close()
    _tracer = None
    _checked_env = False
    if clear_env:
        os.environ.pop(TRACE_DIR_ENV, None)


def tracing_enabled() -> bool:
    return current_tracer() is not None


@contextlib.contextmanager
def span(name: str, **attrs) -> Iterator[SpanHandle]:
    """Module-level span against the global tracer; cheap no-op when
    tracing is off (the yielded handle still accepts ``.set()``)."""
    t = current_tracer()
    if t is None:
        yield SpanHandle(name, "", None, dict(attrs), 0.0)
        return
    with t.span(name, **attrs) as handle:
        yield handle


def event(name: str, **attrs) -> str:
    """Emit a zero-duration span; returns its id ("" when tracing is
    off) so control-plane callers can hand the id to attribution."""
    t = current_tracer()
    if t is None:
        return ""
    return t.event(name, **attrs)


# ---------------------------------------------------------------------------
# read-time merge
# ---------------------------------------------------------------------------
def read_trace(root: str | os.PathLike) -> list[dict]:
    """Union every per-process span file under ``root`` — including
    rotated segments (``spans-<tag>.<n>.jsonl``), which the glob matches
    by construction.

    Skips torn (crash-truncated) lines, dedups by span id — so reading a
    dir whose files were re-copied or doubled is idempotent — and returns
    spans sorted by ``(t0, id)``."""
    root = Path(root)
    spans: dict[str, dict] = {}
    for path in sorted(root.glob("spans-*.jsonl")):
        for line in path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                doc = json.loads(line)
            except json.JSONDecodeError:
                continue   # torn tail of a crashed writer
            if isinstance(doc, dict) and "id" in doc:
                spans.setdefault(doc["id"], doc)
    return sorted(spans.values(), key=lambda s: (s.get("t0", 0.0), s["id"]))
