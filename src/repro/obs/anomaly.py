"""Streaming anomaly detection with control-event attribution.

The metric plane (PR 6) records what happened; this module notices when
what happened *changed* — and names the control action that changed it.
Two pieces:

* :class:`RobustDetector` — one signal's streaming detector: an EWMA of
  the raw samples is scored against a robust baseline (median + MAD over
  a bounded window of *prior* samples).  Robust statistics mean a single
  spike cannot drag the baseline toward itself the way a mean/stddev
  z-score would, so steps, spikes and ramps all register while seeded
  steady noise does not (``tests/test_health.py`` runs 10k noisy steps
  with zero false fires).  After a fire the detector **re-baselines**
  (window reseeded at the new regime) and holds a short refractory
  cooldown, so one step change is one anomaly, not one per step.
* :class:`EventLog` / :class:`AnomalyPlane` — attribution.  The serving
  engine notes every control action (``serve.swap``, ``serve.refresh``,
  ``serve.control``, ``serve.preempt``, ``serve.resume``) into a
  bounded event ring; when a
  detector fires, the anomaly is pinned to the nearest *prior* event
  within an attribution horizon — "ms/step stepped +4σ, 2 steps after
  swap 3f2a→91cc (event 8c11…)" instead of just "latency went up".

Everything is stdlib-only (``statistics.median`` over small windows) and
O(window) per observation, so the health plane stays inside the serve
smoke's ≤2% ms/step overhead gate.
"""

from __future__ import annotations

import dataclasses
import statistics
from collections import deque
from dataclasses import dataclass, field

__all__ = [
    "Anomaly",
    "ControlEvent",
    "EventLog",
    "RobustDetector",
    "AnomalyPlane",
    "robust_zscores",
]

# MAD -> sigma consistency constant for the normal distribution
MAD_SIGMA = 1.4826


def robust_zscores(values) -> list[float]:
    """Batch robust z-scores (median/MAD) for a list of samples — the
    fleet's job-wall-time outlier flagging uses this offline form.  A
    zero MAD (over half the samples identical) scores exact-median
    samples 0 and everything else ``inf``-like via a tiny floor."""
    xs = [float(v) for v in values]
    if len(xs) < 2:
        return [0.0 for _ in xs]
    med = statistics.median(xs)
    mad = statistics.median(abs(x - med) for x in xs)
    scale = max(MAD_SIGMA * mad, 1e-12)
    return [(x - med) / scale for x in xs]


@dataclass(frozen=True)
class ControlEvent:
    """One noted control-plane action (swap/refresh/control/preempt)."""

    step: int
    name: str
    event_id: str = ""        # trace span id when tracing is configured
    attrs: dict = field(default_factory=dict)

    def describe(self) -> str:
        inner = " ".join(f"{k}={self.attrs[k]}" for k in sorted(self.attrs))
        return (f"{self.name}@{self.step}"
                + (f" [{self.event_id}]" if self.event_id else "")
                + (f" ({inner})" if inner else ""))


class EventLog:
    """Bounded ring of recent control events, queried by anomaly step."""

    def __init__(self, capacity: int = 256) -> None:
        self._ring: deque[ControlEvent] = deque(maxlen=int(capacity))

    def note(self, name: str, step: int, event_id: str = "",
             **attrs) -> ControlEvent:
        ev = ControlEvent(step=int(step), name=name,
                          event_id=event_id or "", attrs=dict(attrs))
        self._ring.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self._ring)

    def events(self) -> list[ControlEvent]:
        return list(self._ring)

    def nearest(self, step: int, *, horizon: int = 64) -> ControlEvent | None:
        """The most recent event at or before ``step`` within ``horizon``
        observations — the action an anomaly at ``step`` is pinned to.
        Detection lags the cause (EWMA smoothing, consecutive-sample
        confirmation), so "nearest prior" is the right direction."""
        best = None
        for ev in self._ring:
            if ev.step <= step and step - ev.step <= horizon:
                if best is None or ev.step >= best.step:
                    best = ev
        return best


@dataclass(frozen=True)
class Anomaly:
    """One fired detection, with its attribution (or lack of one)."""

    signal: str
    step: int
    value: float            # EWMA-smoothed statistic that crossed
    zscore: float
    baseline: float         # window median at fire time
    direction: str          # "up" | "down"
    cause: ControlEvent | None = None

    def to_doc(self) -> dict:
        doc = {
            "signal": self.signal,
            "step": self.step,
            "value": round(self.value, 6),
            "zscore": round(self.zscore, 3),
            "baseline": round(self.baseline, 6),
            "direction": self.direction,
        }
        if self.cause is not None:
            doc["cause"] = {
                "event": self.cause.name,
                "step": self.cause.step,
                "event_id": self.cause.event_id,
                "attrs": self.cause.attrs,
                "distance": self.step - self.cause.step,
            }
        return doc

    def describe(self) -> str:
        return (f"{self.signal}@{self.step}: {self.direction} to "
                f"{self.value:.4g} (baseline {self.baseline:.4g}, "
                f"z={self.zscore:+.1f})"
                + (f" <- {self.cause.describe()}"
                   if self.cause is not None else " <- no recent event"))


class RobustDetector:
    """Streaming EWMA + median/MAD robust z-score detector for one signal.

    Per observation: the raw sample folds into an EWMA; the EWMA is
    scored as ``(ewma - median(window)) / (1.4826 * MAD(window))`` where
    the window holds the last ``window`` EWMA values from *before* the
    current observation — the statistic under test never contaminates
    its own baseline.  A fire needs ``|z| >= threshold`` (after
    ``warmup`` baseline samples); it then re-baselines the window at the
    current regime and holds ``cooldown`` refractory observations, so a
    sustained shift yields exactly one anomaly.

    ``min_scale`` floors the MAD so a perfectly constant baseline (MAD 0)
    still scores a departure as a finite, fire-able z.
    """

    def __init__(self, signal: str, *, window: int = 64, warmup: int = 12,
                 threshold: float = 6.0, alpha: float = 0.35,
                 cooldown: int | None = None,
                 min_scale: float = 1e-9) -> None:
        if not 0 < alpha <= 1:
            raise ValueError(f"alpha {alpha} outside (0, 1]")
        if warmup < 2 or window < warmup:
            raise ValueError(
                f"need window >= warmup >= 2 (got {window}/{warmup})")
        self.signal = signal
        self.window = int(window)
        self.warmup = int(warmup)
        self.threshold = float(threshold)
        self.alpha = float(alpha)
        self.cooldown = self.warmup if cooldown is None else int(cooldown)
        self.min_scale = float(min_scale)
        self._baseline: deque[float] = deque(maxlen=self.window)
        self._ewma: float | None = None
        self._refractory = 0
        self.fired = 0

    def _score(self, x: float) -> tuple[float, float]:
        med = statistics.median(self._baseline)
        mad = statistics.median(abs(b - med) for b in self._baseline)
        scale = max(MAD_SIGMA * mad, self.min_scale)
        return (x - med) / scale, med

    def observe(self, value: float, step: int) -> Anomaly | None:
        """Feed one sample; returns the :class:`Anomaly` on a fire."""
        v = float(value)
        self._ewma = (v if self._ewma is None
                      else self.alpha * v + (1 - self.alpha) * self._ewma)
        x = self._ewma
        if self._refractory > 0:
            self._refractory -= 1
            self._baseline.append(x)
            return None
        if len(self._baseline) < self.warmup:
            self._baseline.append(x)
            return None
        z, med = self._score(x)
        if abs(z) < self.threshold:
            self._baseline.append(x)
            return None
        # fire, then re-baseline at the new regime: the window restarts
        # from the post-change level so a sustained step is one anomaly
        # and the *next* change is judged against the new normal
        self.fired += 1
        self._baseline.clear()
        self._baseline.append(x)
        self._refractory = self.cooldown
        return Anomaly(signal=self.signal, step=int(step), value=x,
                       zscore=z, baseline=med,
                       direction="up" if z > 0 else "down")


class AnomalyPlane:
    """All of one engine's detectors plus the shared attribution log.

    ``observe(signal, value, step)`` lazily creates a detector per signal
    (overrides per signal via ``configs``), attributes any fire to the
    nearest prior control event, and keeps a bounded list of fired
    anomalies for post-mortems/reports.
    """

    DEFAULTS = dict(window=64, warmup=12, threshold=6.0, alpha=0.35)

    def __init__(self, *, configs: dict[str, dict] | None = None,
                 horizon: int = 64, capacity: int = 256,
                 event_capacity: int = 256) -> None:
        self._configs = dict(configs or {})
        self.horizon = int(horizon)
        self.events = EventLog(capacity=event_capacity)
        self.detectors: dict[str, RobustDetector] = {}
        self.anomalies: deque[Anomaly] = deque(maxlen=int(capacity))

    def note_event(self, name: str, step: int, event_id: str = "",
                   **attrs) -> ControlEvent:
        return self.events.note(name, step, event_id, **attrs)

    def detector(self, signal: str) -> RobustDetector:
        det = self.detectors.get(signal)
        if det is None:
            cfg = {**self.DEFAULTS, **self._configs.get(signal, {})}
            det = self.detectors[signal] = RobustDetector(signal, **cfg)
        return det

    def observe(self, signal: str, value: float, step: int) -> Anomaly | None:
        fired = self.detector(signal).observe(value, step)
        if fired is None:
            return None
        fired = dataclasses.replace(
            fired, cause=self.events.nearest(fired.step,
                                             horizon=self.horizon))
        self.anomalies.append(fired)
        return fired

    @property
    def fired_total(self) -> int:
        return sum(d.fired for d in self.detectors.values())

    def to_doc(self) -> dict:
        return {
            "fired_total": self.fired_total,
            "by_signal": {s: d.fired for s, d in self.detectors.items()},
            "anomalies": [a.to_doc() for a in self.anomalies],
        }
