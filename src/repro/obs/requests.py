"""Per-request lifecycle chains reconstructed from the JSONL trace.

The serving layer emits one zero-duration trace event per lifecycle
transition, every one carrying the request id (the repo convention:
*any* serving-layer event with a request in scope carries ``rid``):

    req.queued -> req.admitted -> req.prefill -> req.decode
        [-> req.preempt -> req.resume]* -> req.done

``req.done`` carries the full host-side time breakdown — ``queue_ms``
(submission to first admission), ``prefill_ms`` (first admission to
first generated token, suspensions excluded), ``decode_ms`` (first to
last generated token, suspensions excluded), ``suspension_ms`` (total
preempted-and-waiting time) — so a chain is self-describing even when
trace clocks are injected.  This module groups the merged span stream
(:func:`repro.obs.trace.read_trace`) by request id, validates each
chain's causal completeness, and extracts the critical path (the
dominant breakdown segment): the facts behind ``python -m repro.obs
requests`` and the ``provenance-smoke`` CI gate.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = [
    "LIFECYCLE_EVENTS",
    "BREAKDOWN_KEYS",
    "RequestTimeline",
    "request_events",
    "build_timelines",
    "critical_path",
]

LIFECYCLE_EVENTS = ("req.queued", "req.admitted", "req.prefill",
                    "req.decode", "req.preempt", "req.resume", "req.done")
BREAKDOWN_KEYS = ("queue_ms", "prefill_ms", "decode_ms", "suspension_ms")

# once per chain vs paired vs terminal — the completeness rules
_ONCE = ("req.queued", "req.admitted", "req.prefill", "req.done")


@dataclass
class RequestTimeline:
    """One request's reconstructed lifecycle chain."""

    rid: int
    cls: str = "?"
    replica: str = ""
    events: list = field(default_factory=list)   # trace docs, time order
    breakdown: dict = field(default_factory=dict)
    total_ms: float | None = None
    steps: int | None = None
    preempts: int = 0
    resumes: int = 0
    problems: list = field(default_factory=list)

    @property
    def complete(self) -> bool:
        return not self.problems

    def counts(self) -> dict[str, int]:
        by: dict[str, int] = {}
        for e in self.events:
            by[e["name"]] = by.get(e["name"], 0) + 1
        return by


def request_events(spans: list[dict]) -> list[dict]:
    """The lifecycle events in a merged span stream (``read_trace``
    order, i.e. sorted by ``(t0, id)``)."""
    return [s for s in spans if s.get("name") in LIFECYCLE_EVENTS
            and "rid" in s.get("attrs", {})]


def critical_path(breakdown: dict) -> str | None:
    """The dominant lifecycle segment — where this request's latency
    actually went (``queue_ms`` names an admission problem, ``decode_ms``
    a service-time one, ``suspension_ms`` a preemption-pressure one)."""
    present = {k: breakdown[k] for k in BREAKDOWN_KEYS if k in breakdown}
    if not present:
        return None
    return max(present, key=lambda k: (present[k], k))


def build_timelines(spans: list[dict]) -> dict[int, RequestTimeline]:
    """Group lifecycle events by request id and validate each chain.

    A chain is *complete* when every once-only transition appears
    exactly once, every ``req.preempt`` has a matching ``req.resume``
    (the request came back and finished), the terminal ``req.done``
    carries a non-negative breakdown, and the breakdown's segments sum
    to its ``total_ms`` (1% + 1ms tolerance for float rounding).
    Anything else — a lost event, a resume that never happened, a
    negative duration — lands in ``problems`` and fails the
    ``--require-complete`` CI gate.
    """
    timelines: dict[int, RequestTimeline] = {}
    for e in request_events(spans):
        attrs = e.get("attrs", {})
        rid = int(attrs["rid"])
        tl = timelines.setdefault(rid, RequestTimeline(rid=rid))
        tl.events.append(e)
        if "cls" in attrs:
            tl.cls = str(attrs["cls"])
        if attrs.get("replica"):
            tl.replica = str(attrs["replica"])

    for tl in timelines.values():
        by = tl.counts()
        tl.preempts = by.get("req.preempt", 0)
        tl.resumes = by.get("req.resume", 0)
        for name in _ONCE:
            n = by.get(name, 0)
            if n != 1:
                tl.problems.append(f"{n}x {name} (expected exactly 1)")
        if by.get("req.done") and not by.get("req.decode"):
            tl.problems.append("req.done without req.decode")
        if tl.resumes != tl.preempts:
            tl.problems.append(f"{tl.preempts} preempt(s) but "
                               f"{tl.resumes} resume(s)")
        done = next((e for e in tl.events if e["name"] == "req.done"), None)
        if done is not None:
            attrs = done.get("attrs", {})
            tl.total_ms = attrs.get("total_ms")
            tl.steps = attrs.get("steps")
            if attrs.get("preempts", tl.preempts) != tl.preempts:
                tl.problems.append(
                    f"req.done says {attrs['preempts']} preempt(s), chain "
                    f"has {tl.preempts}")
            for k in BREAKDOWN_KEYS:
                v = attrs.get(k)
                if v is None:
                    tl.problems.append(f"req.done missing {k}")
                elif v < 0:
                    tl.problems.append(f"negative {k} ({v})")
                else:
                    tl.breakdown[k] = float(v)
            if tl.total_ms is not None and len(tl.breakdown) == len(
                    BREAKDOWN_KEYS):
                total = sum(tl.breakdown.values())
                if abs(total - tl.total_ms) > 1.0 + 0.01 * tl.total_ms:
                    tl.problems.append(
                        f"breakdown sums to {total:.3f} ms but total_ms "
                        f"is {tl.total_ms:.3f}")
    return timelines
