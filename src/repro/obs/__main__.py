"""``python -m repro.obs [summary|slowest|prom] --trace <dir>`` — inspect
a merged trace directory.

``summary`` prints span totals by name, the slowest spans, per-engine
fleet job wall-time, and the per-class decode-latency table (p50/p95/p99
ms/step) from the merged metric snapshots.  ``--require-span`` /
``--require-class-latency`` turn the summary into a CI gate (non-zero
exit when the trace is missing the asserted signals).  ``prom`` dumps the
merged metrics in Prometheus text format.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .export import METRICS_GLOB, prometheus_text, read_metrics
from .metrics import Histogram, MetricRegistry
from .trace import read_trace

# the metric families the serving telemetry records (kept in one place so
# the inspector and repro.serving.telemetry cannot drift apart)
MS_PER_STEP_METRIC = "serve_ms_per_step"
DECODE_TOK_S_METRIC = "serve_decode_tok_s"
ALL_CLASSES = "_all"   # the label the whole-run aggregate rides under


def _fmt(v, width: int = 9, prec: int = 3) -> str:
    if v is None:
        return "-".rjust(width)
    return f"{v:{width}.{prec}f}"


def span_totals(spans: list[dict]) -> list[tuple[str, int, float]]:
    """``(name, count, total_s)`` rows, heaviest first."""
    agg: dict[str, list[float]] = {}
    for s in spans:
        agg.setdefault(s["name"], []).append(float(s.get("dur_s", 0.0)))
    return sorted(((name, len(ds), sum(ds)) for name, ds in agg.items()),
                  key=lambda r: -r[2])


def slowest_spans(spans: list[dict], n: int = 5) -> list[dict]:
    return sorted(spans, key=lambda s: -float(s.get("dur_s", 0.0)))[:n]


def engine_totals(spans: list[dict]) -> dict[str, dict]:
    """Per-engine wall-time over ``fleet.job`` spans."""
    agg: dict[str, dict] = {}
    for s in spans:
        if s["name"] != "fleet.job":
            continue
        eng = str(s.get("attrs", {}).get("engine", "?"))
        row = agg.setdefault(eng, {"jobs": 0, "wall_s": 0.0, "results": 0})
        row["jobs"] += 1
        row["wall_s"] += float(s.get("dur_s", 0.0))
        row["results"] += int(s.get("attrs", {}).get("n_results", 0) or 0)
    return agg


def class_latency_rows(metrics: MetricRegistry) -> dict[str, dict]:
    """Per-class decode latency percentiles from the merged snapshots."""
    rows: dict[str, dict] = {}
    for labels, hist in metrics.with_name(MS_PER_STEP_METRIC):
        if not isinstance(hist, Histogram) or hist.count == 0:
            continue
        cls = labels.get("class", ALL_CLASSES)
        rows[cls] = {
            "batches": hist.count,
            "mean": hist.mean,
            **hist.percentiles(),
        }
        tok = metrics.find(DECODE_TOK_S_METRIC, **labels)
        if isinstance(tok, Histogram) and tok.count:
            rows[cls]["tok_s_p50"] = tok.quantile(0.5)
    return rows


def _describe_span(s: dict) -> str:
    attrs = s.get("attrs", {})
    inner = " ".join(f"{k}={attrs[k]}" for k in sorted(attrs))
    return f"{s['name']}" + (f" [{inner}]" if inner else "")


def summarize(trace_dir: Path, *, limit: int = 5, out=print) -> dict:
    spans = read_trace(trace_dir)
    metrics = read_metrics(trace_dir)
    n_files = len(list(trace_dir.glob("spans-*.jsonl")))
    n_snaps = len(list(trace_dir.glob(METRICS_GLOB)))
    out(f"trace {trace_dir}: {len(spans)} span(s) from {n_files} file(s), "
        f"{n_snaps} metric snapshot(s)")

    totals = span_totals(spans)
    if totals:
        out("\nspan totals:")
        out(f"  {'name':24s} {'count':>6s} {'total_s':>9s} {'mean_s':>9s}")
        for name, count, total in totals:
            out(f"  {name:24s} {count:6d} {_fmt(total)} "
                f"{_fmt(total / count)}")

        out(f"\nslowest {limit} span(s):")
        for s in slowest_spans(spans, limit):
            out(f"  {_fmt(float(s.get('dur_s', 0.0)))}s  {_describe_span(s)}")

    engines = engine_totals(spans)
    if engines:
        out("\nfleet engines (job wall-time):")
        out(f"  {'engine':10s} {'jobs':>5s} {'wall_s':>9s} {'mean_s':>9s} "
            f"{'results':>8s}")
        for eng in sorted(engines, key=lambda e: -engines[e]["wall_s"]):
            row = engines[eng]
            out(f"  {eng:10s} {row['jobs']:5d} {_fmt(row['wall_s'])} "
                f"{_fmt(row['wall_s'] / row['jobs'])} {row['results']:8d}")

    classes = class_latency_rows(metrics)
    if classes:
        out("\nper-class decode latency (ms/step):")
        out(f"  {'class':10s} {'batches':>7s} {'p50':>9s} {'p95':>9s} "
            f"{'p99':>9s} {'mean':>9s}")
        for cls in sorted(classes):
            r = classes[cls]
            out(f"  {cls:10s} {r['batches']:7d} {_fmt(r['p50'])} "
                f"{_fmt(r['p95'])} {_fmt(r['p99'])} {_fmt(r['mean'])}")

    return {"spans": spans, "engines": engines, "classes": classes}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize/filter an observability trace directory.",
    )
    ap.add_argument("command", nargs="?", default="summary",
                    choices=("summary", "slowest", "prom"),
                    help="summary (default): totals + slowest + engines + "
                         "per-class latency; slowest: just the slowest "
                         "spans; prom: merged metrics as Prometheus text")
    ap.add_argument("--trace", required=True,
                    help="trace directory (spans-*.jsonl + metrics-*.json)")
    ap.add_argument("--limit", type=int, default=5,
                    help="how many slowest spans to show")
    ap.add_argument("--name", default=None,
                    help="filter spans to names containing this substring")
    ap.add_argument("--require-span", action="append", default=[],
                    metavar="NAME[=N]",
                    help="exit 1 unless >= N (default 1) spans named NAME "
                         "are present (CI gate; repeatable)")
    ap.add_argument("--require-class-latency", action="store_true",
                    help="exit 1 unless at least one per-class (non-"
                         f"{ALL_CLASSES!r}) latency histogram is present")
    args = ap.parse_args(argv)

    trace_dir = Path(args.trace)
    if not trace_dir.is_dir():
        print(f"no such trace dir: {trace_dir}", file=sys.stderr)
        return 2

    if args.command == "prom":
        sys.stdout.write(prometheus_text(read_metrics(trace_dir)))
        return 0

    if args.command == "slowest":
        spans = read_trace(trace_dir)
        if args.name:
            spans = [s for s in spans if args.name in s["name"]]
        for s in slowest_spans(spans, args.limit):
            print(f"{_fmt(float(s.get('dur_s', 0.0)))}s  {_describe_span(s)}")
        return 0

    report = summarize(trace_dir, limit=args.limit)

    rc = 0
    by_name: dict[str, int] = {}
    for s in report["spans"]:
        by_name[s["name"]] = by_name.get(s["name"], 0) + 1
    for req in args.require_span:
        name, _, n = req.partition("=")
        want = int(n) if n else 1
        got = by_name.get(name, 0)
        if got < want:
            print(f"FAIL: {got} span(s) named {name!r}, need >= {want}",
                  file=sys.stderr)
            rc = 1
    if args.require_class_latency:
        per_class = [c for c in report["classes"] if c != ALL_CLASSES]
        if not per_class:
            print("FAIL: no per-class latency histograms in trace metrics",
                  file=sys.stderr)
            rc = 1
        else:
            print(f"\nper-class latency present for: {sorted(per_class)}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
