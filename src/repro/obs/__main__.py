"""``python -m repro.obs <command>`` — inspect traces, gate health,
read post-mortems, and diff bench runs.

* ``summary --trace <dir>`` — span totals, slowest spans, per-engine
  fleet wall-time, per-class decode-latency table; ``--json`` emits the
  same facts machine-readably so CI gates parse fields instead of
  grepping formatted text.  ``--require-span`` /
  ``--require-class-latency`` turn it into a CI gate.
* ``slowest --trace <dir>`` / ``prom --trace <dir>`` — the slowest spans
  / merged metrics in Prometheus text format.
* ``health --bench BENCH.json [--max-state warn]`` — read the health
  section a ``--health`` serve wrote; exit 1 when the run's worst SLO
  state exceeds the allowed one (the CI health gate).
* ``postmortem --dir <dir>`` — list (or ``--json``-dump) the flight
  recorder's bundles; ``--require N`` gates on at least N bundles,
  ``--last`` prints the newest bundle whole.
* ``diff --bench BENCH.json ... --baseline-dir benchmarks/baselines`` —
  the bench regression sentinel: direction-aware per-metric comparison
  against committed baselines, optional ``--history-dir`` accumulation,
  exit 1 on any regression.
* ``requests --trace <dir>`` — per-request lifecycle timelines
  reconstructed from the ``req.*`` event chains: slowest-first table
  with the queue/prefill/decode/suspension breakdown and the critical
  path; ``--require-complete`` exits 1 on any broken chain (the
  provenance-smoke CI gate), ``--rid`` narrows to one request.
* ``provenance --trace <dir>`` — audit the approximation-provenance
  ledger (``prov-*.jsonl``): which plan decoded which token ranges,
  with drift stats; exits 1 when any completed request has a gap,
  overlap, or dangling plan reference.
* ``costs --trace <dir>`` — the cost-accounting report: per-request /
  per-class / per-layer approx-MAC and area·MAC dividend attribution
  joined from the ledger; ``--require-reconciled`` exits 1 unless every
  attributed MAC tiles its request exactly (the costs-smoke CI gate).
* ``export --trace <dir> --format chrome [--out f.json]`` — convert the
  merged span trace to Chrome trace-event JSON for Perfetto /
  ``chrome://tracing``.

Every trace-reading command exits 2 with ``no trace at <dir>`` when the
directory is absent or holds no trace artifacts at all.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .costs import cost_report, render_report
from .export import METRICS_GLOB, prometheus_text, read_metrics
from .flight import read_postmortems
from .health import STATES, state_rank
from .metrics import Histogram, MetricRegistry
from .perfetto import export_chrome
from .provenance import audit, read_ledger
from .regress import compare_bench, load_rules, record_history
from .requests import build_timelines, critical_path
from .trace import read_trace

# the metric families the serving telemetry records (kept in one place so
# the inspector and repro.serving.telemetry cannot drift apart)
MS_PER_STEP_METRIC = "serve_ms_per_step"
DECODE_TOK_S_METRIC = "serve_decode_tok_s"
ALL_CLASSES = "_all"   # the label the whole-run aggregate rides under

COMMANDS = ("summary", "slowest", "prom", "health", "postmortem", "diff",
            "requests", "provenance", "costs", "export")


def _trace_missing(trace_dir: Path) -> bool:
    """True when there is nothing to inspect: the dir is absent or holds
    none of the trace artifact families (spans, metric snapshots,
    provenance ledger)."""
    if not trace_dir.is_dir():
        return True
    return not any(
        any(trace_dir.glob(pattern))
        for pattern in ("spans-*.jsonl", METRICS_GLOB, "prov-*.jsonl"))


def _no_trace(trace_dir: Path) -> int:
    print(f"no trace at {trace_dir}", file=sys.stderr)
    return 2


def _fmt(v, width: int = 9, prec: int = 3) -> str:
    if v is None:
        return "-".rjust(width)
    return f"{v:{width}.{prec}f}"


def span_totals(spans: list[dict]) -> list[tuple[str, int, float]]:
    """``(name, count, total_s)`` rows, heaviest first."""
    agg: dict[str, list[float]] = {}
    for s in spans:
        agg.setdefault(s["name"], []).append(float(s.get("dur_s", 0.0)))
    return sorted(((name, len(ds), sum(ds)) for name, ds in agg.items()),
                  key=lambda r: -r[2])


def slowest_spans(spans: list[dict], n: int = 5) -> list[dict]:
    return sorted(spans, key=lambda s: -float(s.get("dur_s", 0.0)))[:n]


def engine_totals(spans: list[dict]) -> dict[str, dict]:
    """Per-engine wall-time over ``fleet.job`` spans."""
    agg: dict[str, dict] = {}
    for s in spans:
        if s["name"] != "fleet.job":
            continue
        eng = str(s.get("attrs", {}).get("engine", "?"))
        row = agg.setdefault(eng, {"jobs": 0, "wall_s": 0.0, "results": 0})
        row["jobs"] += 1
        row["wall_s"] += float(s.get("dur_s", 0.0))
        row["results"] += int(s.get("attrs", {}).get("n_results", 0) or 0)
    return agg


def class_latency_rows(metrics: MetricRegistry) -> dict[str, dict]:
    """Per-class decode latency percentiles from the merged snapshots."""
    rows: dict[str, dict] = {}
    for labels, hist in metrics.with_name(MS_PER_STEP_METRIC):
        if not isinstance(hist, Histogram) or hist.count == 0:
            continue
        cls = labels.get("class", ALL_CLASSES)
        rows[cls] = {
            "batches": hist.count,
            "mean": hist.mean,
            **hist.percentiles(),
        }
        tok = metrics.find(DECODE_TOK_S_METRIC, **labels)
        if isinstance(tok, Histogram) and tok.count:
            rows[cls]["tok_s_p50"] = tok.quantile(0.5)
    return rows


def _describe_span(s: dict) -> str:
    attrs = s.get("attrs", {})
    inner = " ".join(f"{k}={attrs[k]}" for k in sorted(attrs))
    return f"{s['name']}" + (f" [{inner}]" if inner else "")


def summarize(trace_dir: Path, *, limit: int = 5, out=print) -> dict:
    spans = read_trace(trace_dir)
    metrics = read_metrics(trace_dir)
    n_files = len(list(trace_dir.glob("spans-*.jsonl")))
    n_snaps = len(list(trace_dir.glob(METRICS_GLOB)))
    out(f"trace {trace_dir}: {len(spans)} span(s) from {n_files} file(s), "
        f"{n_snaps} metric snapshot(s)")

    totals = span_totals(spans)
    if totals:
        out("\nspan totals:")
        out(f"  {'name':24s} {'count':>6s} {'total_s':>9s} {'mean_s':>9s}")
        for name, count, total in totals:
            out(f"  {name:24s} {count:6d} {_fmt(total)} "
                f"{_fmt(total / count)}")

        out(f"\nslowest {limit} span(s):")
        for s in slowest_spans(spans, limit):
            out(f"  {_fmt(float(s.get('dur_s', 0.0)))}s  {_describe_span(s)}")

    engines = engine_totals(spans)
    if engines:
        out("\nfleet engines (job wall-time):")
        out(f"  {'engine':10s} {'jobs':>5s} {'wall_s':>9s} {'mean_s':>9s} "
            f"{'results':>8s}")
        for eng in sorted(engines, key=lambda e: -engines[e]["wall_s"]):
            row = engines[eng]
            out(f"  {eng:10s} {row['jobs']:5d} {_fmt(row['wall_s'])} "
                f"{_fmt(row['wall_s'] / row['jobs'])} {row['results']:8d}")

    classes = class_latency_rows(metrics)
    if classes:
        out("\nper-class decode latency (ms/step):")
        out(f"  {'class':10s} {'batches':>7s} {'p50':>9s} {'p95':>9s} "
            f"{'p99':>9s} {'mean':>9s}")
        for cls in sorted(classes):
            r = classes[cls]
            out(f"  {cls:10s} {r['batches']:7d} {_fmt(r['p50'])} "
                f"{_fmt(r['p95'])} {_fmt(r['p99'])} {_fmt(r['mean'])}")

    return {"spans": spans, "engines": engines, "classes": classes}


def summary_doc(trace_dir: Path, *, limit: int = 5) -> dict:
    """The ``summary --json`` document: the same facts the human summary
    prints, as structured fields CI can parse without grepping."""
    spans = read_trace(trace_dir)
    return {
        "trace_dir": str(trace_dir),
        "n_spans": len(spans),
        "n_span_files": len(list(trace_dir.glob("spans-*.jsonl"))),
        "n_metric_snapshots": len(list(trace_dir.glob(METRICS_GLOB))),
        "span_totals": {
            name: {"count": count, "total_s": round(total, 6)}
            for name, count, total in span_totals(spans)},
        "slowest": [
            {"name": s["name"], "dur_s": round(float(s.get("dur_s", 0)), 6),
             "id": s.get("id"), "attrs": s.get("attrs", {})}
            for s in slowest_spans(spans, limit)],
        "engines": engine_totals(spans),
        "classes": {
            cls: {k: (round(v, 6) if isinstance(v, float) else v)
                  for k, v in row.items()}
            for cls, row in class_latency_rows(
                read_metrics(trace_dir)).items()},
    }


# ---------------------------------------------------------------------------
# subcommands
# ---------------------------------------------------------------------------
def cmd_summary(args) -> int:
    trace_dir = Path(args.trace)
    if _trace_missing(trace_dir):
        return _no_trace(trace_dir)
    if args.json:
        doc = summary_doc(trace_dir, limit=args.limit)
        print(json.dumps(doc, indent=1, sort_keys=True))
        by_name = {n: r["count"] for n, r in doc["span_totals"].items()}
        classes = doc["classes"]
    else:
        report = summarize(trace_dir, limit=args.limit)
        by_name = {}
        for s in report["spans"]:
            by_name[s["name"]] = by_name.get(s["name"], 0) + 1
        classes = report["classes"]

    rc = 0
    for req in args.require_span:
        name, _, n = req.partition("=")
        want = int(n) if n else 1
        got = by_name.get(name, 0)
        if got < want:
            print(f"FAIL: {got} span(s) named {name!r}, need >= {want}",
                  file=sys.stderr)
            rc = 1
    if args.require_class_latency:
        per_class = [c for c in classes if c != ALL_CLASSES]
        if not per_class:
            print("FAIL: no per-class latency histograms in trace metrics",
                  file=sys.stderr)
            rc = 1
        elif not args.json:
            print(f"\nper-class latency present for: {sorted(per_class)}")
    return rc


def cmd_slowest(args) -> int:
    trace_dir = Path(args.trace)
    if _trace_missing(trace_dir):
        return _no_trace(trace_dir)
    spans = read_trace(trace_dir)
    if args.name:
        spans = [s for s in spans if args.name in s["name"]]
    for s in slowest_spans(spans, args.limit):
        print(f"{_fmt(float(s.get('dur_s', 0.0)))}s  {_describe_span(s)}")
    return 0


def cmd_prom(args) -> int:
    trace_dir = Path(args.trace)
    if not trace_dir.is_dir():
        print(f"no such trace dir: {trace_dir}", file=sys.stderr)
        return 2
    sys.stdout.write(prometheus_text(read_metrics(trace_dir)))
    return 0


def cmd_health(args) -> int:
    """Gate on the health section of a ``--health`` serve's bench JSON
    (or a bare health-report JSON): exit 1 when the worst observed SLO
    state exceeds ``--max-state``."""
    path = Path(args.bench)
    if not path.exists():
        print(f"no such bench json: {path}", file=sys.stderr)
        return 2
    doc = json.loads(path.read_text())
    health = doc.get("health", doc)
    state = health.get("state")
    if state not in STATES:
        print(f"{path} has no health section (serve without --health?)",
              file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps(health, indent=1, sort_keys=True))
    else:
        print(f"health: {state}  (anomalies={health.get('anomalies_fired', 0)}"
              f" pages={health.get('pages', 0)}"
              f" dumps={health.get('dumps', 0)})")
        for cls, row in sorted(health.get("classes", {}).items()):
            parts = [f"{cls}: {row.get('state')}"]
            for kind in ("latency", "drift"):
                if kind in row:
                    b = row[kind]
                    parts.append(
                        f"{kind} burn {b.get('burn_short', 0):.2f}/"
                        f"{b.get('burn_long', 0):.2f} "
                        f"({b.get('violations', 0)}/"
                        f"{b.get('observations', 0)} bad)")
            print("  " + "  ".join(parts))
        for a in health.get("recent_anomalies", []):
            cause = a.get("cause")
            print(f"  anomaly {a['signal']}@{a['step']} z={a['zscore']:+.1f}"
                  + (f" <- {cause['event']}@{cause['step']}"
                     f" [{cause.get('event_id', '')}]" if cause else ""))
    if state_rank(state) > state_rank(args.max_state):
        print(f"FAIL: health state {state!r} exceeds allowed "
              f"{args.max_state!r}", file=sys.stderr)
        return 1
    return 0


def cmd_postmortem(args) -> int:
    d = Path(args.dir)
    bundles = read_postmortems(d) if d.is_dir() else []
    if args.json:
        print(json.dumps([{"path": str(p), **doc} for p, doc in bundles],
                         indent=1, sort_keys=True))
    else:
        print(f"{len(bundles)} post-mortem bundle(s) in {d}")
        for p, doc in bundles:
            ctx = doc.get("context", {})
            print(f"  {p.name}: {doc.get('reason')} — "
                  f"{doc.get('detail', '')[:100]} "
                  f"[{len(doc.get('frames', []))} frame(s), "
                  f"plan={ctx.get('plan_id')}, step={ctx.get('step')}]")
        if args.last and bundles:
            print(json.dumps(bundles[-1][1], indent=1, sort_keys=True))
    if len(bundles) < args.require:
        print(f"FAIL: {len(bundles)} bundle(s), need >= {args.require}",
              file=sys.stderr)
        return 1
    return 0


def cmd_diff(args) -> int:
    """Bench regression sentinel: each BENCH json vs its committed
    baseline (same filename under ``--baseline-dir``)."""
    rules = load_rules(args.tolerances)
    rc = 0
    report = []
    for bench in args.bench:
        bench = Path(bench)
        if not bench.exists():
            print(f"SKIP {bench.name}: no such file", file=sys.stderr)
            if args.require_baseline:
                rc = 1
            continue
        current = json.loads(bench.read_text())
        if args.history_dir:
            record_history(args.history_dir, bench.name, current)
        base_path = Path(args.baseline_dir) / bench.name
        if not base_path.exists():
            print(f"SKIP {bench.name}: no baseline at {base_path}"
                  + ("" if args.require_baseline
                     else " (commit one to enable the gate)"))
            if args.require_baseline:
                rc = 1
            continue
        res = compare_bench(current, json.loads(base_path.read_text()),
                            rules)
        report.append({"bench": bench.name, **res})
        status = "FAIL" if res["regressions"] else "ok"
        if res["regressions"]:
            rc = 1
        if not args.json:
            print(f"{status} {bench.name}: {res['compared']} metric(s) "
                  f"compared, {len(res['regressions'])} regression(s), "
                  f"{len(res['improvements'])} improvement(s)")
            for f in res["regressions"]:
                print(f"  REGRESSION {f['metric']}: "
                      f"{f['baseline']} -> {f['current']} "
                      f"(rule {f['rule']}, {f['kind']})")
            for f in res["improvements"]:
                print(f"  improved   {f['metric']}: "
                      f"{f['baseline']} -> {f['current']}")
    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True))
    return rc


def cmd_requests(args) -> int:
    """Per-request lifecycle timelines from the ``req.*`` trace chains."""
    trace_dir = Path(args.trace)
    if _trace_missing(trace_dir):
        return _no_trace(trace_dir)
    timelines = build_timelines(read_trace(trace_dir))
    if args.rid is not None:
        timelines = {rid: tl for rid, tl in timelines.items()
                     if rid == args.rid}
    if not timelines:
        print("no req.* lifecycle events in trace (serve without --trace, "
              "or wrong --rid?)", file=sys.stderr)
        return 2

    # slowest first; in-flight requests (no total yet) sink to the end
    order = sorted(timelines.values(),
                   key=lambda t: -(t.total_ms if t.total_ms is not None
                                   else -1.0))
    broken = [t for t in order if not t.complete]
    if args.json:
        print(json.dumps({
            "trace_dir": str(trace_dir),
            "n_requests": len(order),
            "n_complete": len(order) - len(broken),
            "requests": [{
                "rid": t.rid, "cls": t.cls, "replica": t.replica or None,
                "total_ms": t.total_ms, "steps": t.steps,
                "preempts": t.preempts, "resumes": t.resumes,
                "breakdown": t.breakdown,
                "critical_path": critical_path(t.breakdown),
                "events": [e["name"] for e in t.events],
                "complete": t.complete, "problems": t.problems,
            } for t in order],
        }, indent=1, sort_keys=True))
    else:
        print(f"{len(order)} request(s) in {trace_dir}, "
              f"{len(order) - len(broken)} complete chain(s)")
        print(f"  {'rid':>5s} {'class':8s} {'total':>9s} {'queue':>9s} "
              f"{'prefill':>9s} {'decode':>9s} {'susp':>9s} {'pre':>3s} "
              f"{'critical':9s} chain")
        for t in order[:args.limit]:
            b = t.breakdown
            crit = critical_path(b) or "-"
            state = "ok" if t.complete else "BROKEN"
            print(f"  {t.rid:5d} {t.cls:8s} {_fmt(t.total_ms)} "
                  f"{_fmt(b.get('queue_ms'))} {_fmt(b.get('prefill_ms'))} "
                  f"{_fmt(b.get('decode_ms'))} "
                  f"{_fmt(b.get('suspension_ms'))} {t.preempts:3d} "
                  f"{crit.removesuffix('_ms') if crit != '-' else '-':9s} "
                  f"{state}"
                  + (f" ({t.replica})" if t.replica else ""))
        for t in broken:
            for prob in t.problems:
                print(f"  rid {t.rid}: {prob}", file=sys.stderr)
    if args.require_complete and broken:
        print(f"FAIL: {len(broken)} request(s) with broken lifecycle "
              f"chains", file=sys.stderr)
        return 1
    return 0


def cmd_provenance(args) -> int:
    """Audit the approximation-provenance ledger next to the trace."""
    trace_dir = Path(args.trace)
    if _trace_missing(trace_dir):
        return _no_trace(trace_dir)
    records = read_ledger(trace_dir)
    if not records:
        print(f"no prov-*.jsonl records in {trace_dir} (serve without "
              "--trace, or a non-continuous engine?)", file=sys.stderr)
        return 2
    report = audit(records)
    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True))
    else:
        print(f"{report['n_requests']} request(s), {report['n_done']} "
              f"done, {report['n_complete']} with gap-free provenance, "
              f"{report['n_failed']} failed; {len(report['plans'])} "
              f"plan(s) on record")
        for rid, rep in report["requests"].items():
            segs = " ".join(
                f"[{r['t0']},{r['t1']})@{r['plan'][:12]}"
                + (f"/L{r['level']}" if r.get("level") is not None else "")
                for r in rep["ranges"])
            drift = (f"  drift mean={rep['mean_drift']} "
                     f"max={rep['max_drift']}"
                     if "mean_drift" in rep else "")
            state = ("complete" if rep["complete"]
                     else "in-flight" if rep["problems"]
                     and rep["problems"][0].startswith("no done")
                     else "FAILED")
            print(f"  rid {rid} ({rep['cls']}): {rep['tokens_covered']} "
                  f"token(s) {state}  {segs}{drift}")
            for prob in rep["problems"]:
                if not prob.startswith("no done"):
                    print(f"    {prob}", file=sys.stderr)
    if report["n_failed"]:
        print(f"FAIL: {report['n_failed']} completed request(s) without "
              f"gap-free provenance", file=sys.stderr)
        return 1
    return 0


def cmd_costs(args) -> int:
    """Cost-accounting report/gate over the provenance ledger."""
    trace_dir = Path(args.trace)
    if _trace_missing(trace_dir):
        return _no_trace(trace_dir)
    records = read_ledger(trace_dir)
    if not records:
        print(f"no prov-*.jsonl records in {trace_dir} (serve without "
              "--trace, or a non-continuous engine?)", file=sys.stderr)
        return 2
    rep = cost_report(records)
    if args.json:
        print(json.dumps(rep, indent=1, sort_keys=True))
    else:
        print(render_report(rep))
    if args.require_reconciled and not rep["reconciled"]:
        print("FAIL: cost attribution did not reconcile — "
              + "; ".join(rep["problems"] or ["no completed requests"]),
              file=sys.stderr)
        return 1
    return 0


def cmd_export(args) -> int:
    """Convert the merged span trace to Chrome trace-event JSON."""
    trace_dir = Path(args.trace)
    if _trace_missing(trace_dir):
        return _no_trace(trace_dir)
    spans = read_trace(trace_dir)
    if not spans:
        print(f"no spans-*.jsonl span records in {trace_dir} (serve "
              "without --trace?)", file=sys.stderr)
        return 2
    doc = export_chrome(spans, args.out)
    if args.out:
        print(f"wrote {len(doc['traceEvents'])} trace event(s) "
              f"({doc['otherData']['spans']} span(s)) to {args.out}")
    else:
        print(json.dumps(doc))
    return 0


# ---------------------------------------------------------------------------
def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # historical form: `python -m repro.obs --trace d` (command omitted)
    if not argv or argv[0] not in COMMANDS and argv[0] not in ("-h",
                                                               "--help"):
        argv.insert(0, "summary")

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect traces, gate health, read post-mortems, "
                    "diff bench runs.",
    )
    sub = ap.add_subparsers(dest="command", required=True)

    def trace_args(p):
        p.add_argument("--trace", required=True,
                       help="trace directory (spans-*.jsonl + "
                            "metrics-*.json)")
        p.add_argument("--limit", type=int, default=5,
                       help="how many slowest spans to show")

    p = sub.add_parser("summary", help="totals + slowest + engines + "
                                       "per-class latency")
    trace_args(p)
    p.add_argument("--json", action="store_true",
                   help="machine-readable summary document")
    p.add_argument("--require-span", action="append", default=[],
                   metavar="NAME[=N]",
                   help="exit 1 unless >= N (default 1) spans named NAME "
                        "are present (CI gate; repeatable)")
    p.add_argument("--require-class-latency", action="store_true",
                   help="exit 1 unless at least one per-class (non-"
                        f"{ALL_CLASSES!r}) latency histogram is present")
    p.set_defaults(fn=cmd_summary)

    p = sub.add_parser("slowest", help="just the slowest spans")
    trace_args(p)
    p.add_argument("--name", default=None,
                   help="filter spans to names containing this substring")
    p.set_defaults(fn=cmd_slowest)

    p = sub.add_parser("prom", help="merged metrics as Prometheus text")
    trace_args(p)
    p.set_defaults(fn=cmd_prom)

    p = sub.add_parser("health", help="gate on a serve's health section")
    p.add_argument("--bench", required=True,
                   help="bench JSON from a --health serve (or a bare "
                        "health report JSON)")
    p.add_argument("--max-state", default="warn", choices=STATES,
                   help="worst state that still exits 0 (default: warn — "
                        "only a page fails the gate)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_health)

    p = sub.add_parser("postmortem", help="list flight-recorder bundles")
    p.add_argument("--dir", required=True,
                   help="post-mortem dir (postmortem-*.json)")
    p.add_argument("--require", type=int, default=0, metavar="N",
                   help="exit 1 unless >= N bundles are present (CI gate)")
    p.add_argument("--last", action="store_true",
                   help="also print the newest bundle in full")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_postmortem)

    p = sub.add_parser("requests", help="per-request lifecycle timelines")
    p.add_argument("--trace", required=True,
                   help="trace directory with req.* lifecycle events")
    p.add_argument("--rid", type=int, default=None,
                   help="narrow to a single request id")
    p.add_argument("--limit", type=int, default=20,
                   help="table rows to print (slowest first)")
    p.add_argument("--require-complete", action="store_true",
                   help="exit 1 on any broken lifecycle chain (CI gate)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_requests)

    p = sub.add_parser("provenance",
                       help="audit the approximation-provenance ledger")
    p.add_argument("--trace", required=True,
                   help="trace directory holding prov-*.jsonl")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_provenance)

    p = sub.add_parser("costs",
                       help="per-request area/energy dividend attribution")
    p.add_argument("--trace", required=True,
                   help="trace directory holding prov-*.jsonl")
    p.add_argument("--require-reconciled", action="store_true",
                   help="exit 1 unless every attributed MAC tiles its "
                        "request exactly and every plan is priced "
                        "(CI gate)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_costs)

    p = sub.add_parser("export",
                       help="export the span trace for external viewers")
    p.add_argument("--trace", required=True,
                   help="trace directory holding spans-*.jsonl")
    p.add_argument("--format", default="chrome", choices=("chrome",),
                   help="output format (chrome = Perfetto-loadable "
                        "trace-event JSON)")
    p.add_argument("--out", default=None,
                   help="output file (default: print to stdout)")
    p.set_defaults(fn=cmd_export)

    p = sub.add_parser("diff", help="bench regression sentinel")
    p.add_argument("--bench", nargs="+", required=True,
                   help="current BENCH_*.json file(s)")
    p.add_argument("--baseline-dir", required=True,
                   help="committed baselines (same filenames)")
    p.add_argument("--tolerances", default=None,
                   help="tolerances.json overriding the default rules "
                        "(default: <baseline-dir>/tolerances.json if "
                        "present)")
    p.add_argument("--history-dir", default=None,
                   help="append each compared run here (CI artifact)")
    p.add_argument("--require-baseline", action="store_true",
                   help="exit 1 when a bench has no committed baseline")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_diff)

    args = ap.parse_args(argv)
    if args.command == "diff" and args.tolerances is None:
        cand = Path(args.baseline_dir) / "tolerances.json"
        args.tolerances = str(cand) if cand.exists() else None
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
