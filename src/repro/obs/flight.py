"""Flight recorder: bounded ring of recent frames, dumped on trouble.

A long serve cannot keep (or re-read) everything it ever did, but the
moments that matter — an SLO page, a fired anomaly, a crash — are only
diagnosable from what happened *just before*.  The recorder keeps a
bounded ring of recent frames (per-step metric deltas, control events,
span notes) plus a merged "context" of the engine's current shape
(active plan, ladder level, per-class scheduler state, page-allocator
stats), and :meth:`FlightRecorder.dump` freezes all of it into one
post-mortem bundle, written atomically (temp file + ``os.replace``) so a
crash mid-dump never leaves a torn JSON.

Bundles are plain JSON under a post-mortem dir —
``postmortem-<tag>-<seq>.json`` — read back by
:func:`read_postmortems` and rendered by
``python -m repro.obs postmortem <dir>``.
"""

from __future__ import annotations

import os
import time
from collections import deque
from pathlib import Path

from .trace import atomic_write_json

__all__ = ["FlightRecorder", "read_postmortems", "POSTMORTEM_GLOB"]

POSTMORTEM_GLOB = "postmortem-*.json"


class FlightRecorder:
    """Bounded in-memory ring + atomic post-mortem dumps.

    ``note(kind, **doc)`` appends one frame (step telemetry, a control
    event, a span of interest); ``set_context(**kv)`` merges the current
    engine shape (kept whole, not ringed — it is small and the *latest*
    value is the useful one).  ``dump(reason, ...)`` writes everything.

    ``max_bundles`` caps how many bundles one recorder writes per run so
    a pathological serve (anomaly every step) cannot fill the disk; the
    cap is generous and the refusal is counted in ``dumps_suppressed``.
    """

    def __init__(self, *, capacity: int = 512,
                 postmortem_dir: str | os.PathLike | None = None,
                 tag: str | None = None, max_bundles: int = 16) -> None:
        self.capacity = int(capacity)
        self._ring: deque[dict] = deque(maxlen=self.capacity)
        self._context: dict = {}
        self.postmortem_dir = (Path(postmortem_dir)
                               if postmortem_dir is not None else None)
        if tag is None:
            import socket

            tag = f"{socket.gethostname()}-{os.getpid()}"
        self.tag = tag
        self.max_bundles = int(max_bundles)
        self._seq = 0
        self.dumps = 0
        self.dumps_suppressed = 0

    # ------------------------------------------------------------------ write
    def note(self, kind: str, **doc) -> None:
        """Append one frame to the ring.  ``kind`` names the frame type
        (``step``, ``event``, ``anomaly``, ``slo``...)."""
        self._ring.append({"kind": kind, **doc})

    def set_context(self, **kv) -> None:
        """Merge the engine's current shape; ``None`` values are kept
        (an explicit "no plan" is information too)."""
        self._context.update(kv)

    @property
    def frames(self) -> list[dict]:
        return list(self._ring)

    @property
    def context(self) -> dict:
        return dict(self._context)

    # ------------------------------------------------------------------- dump
    def bundle(self, reason: str, detail: str = "",
               extra: dict | None = None) -> dict:
        """The post-mortem document: why, the engine shape at dump time,
        and the last ``capacity`` frames in arrival order."""
        return {
            "reason": reason,
            "detail": detail,
            "tag": self.tag,
            "unix_time": round(time.time(), 3),
            "context": dict(self._context),
            "frames": list(self._ring),
            **(extra or {}),
        }

    def dump(self, reason: str, detail: str = "",
             extra: dict | None = None) -> Path | None:
        """Write one post-mortem bundle atomically; returns its path, or
        ``None`` when no dir is configured / the bundle cap is hit.  The
        ring is *not* cleared: a second trigger shortly after the first
        still sees the shared history, and the bundles' overlap makes the
        two triggers' ordering explicit."""
        if self.postmortem_dir is None:
            return None
        if self.dumps >= self.max_bundles:
            self.dumps_suppressed += 1
            return None
        self.postmortem_dir.mkdir(parents=True, exist_ok=True)
        # continue numbering past bundles already on disk (a serve that
        # restarts into the same dir must not overwrite its predecessor's
        # crash bundle)
        while True:
            path = (self.postmortem_dir
                    / f"postmortem-{self.tag}-{self._seq:04d}.json")
            if not path.exists():
                break
            self._seq += 1
        atomic_write_json(path, self.bundle(reason, detail, extra))
        self._seq += 1
        self.dumps += 1
        return path


def read_postmortems(
        postmortem_dir: str | os.PathLike) -> list[tuple[Path, dict]]:
    """Load every readable bundle under a dir, oldest first (bundles are
    atomic, so an unreadable file is foreign and skipped)."""
    import json

    out: list[tuple[Path, dict]] = []
    for path in sorted(Path(postmortem_dir).glob(POSTMORTEM_GLOB)):
        try:
            out.append((path, json.loads(path.read_text())))
        except (json.JSONDecodeError, OSError):
            continue
    return out
