"""Bench regression sentinel: BENCH_*.json vs committed baselines.

CI has produced bench JSONs since PR 3 and uploaded them as artifacts,
but nothing ever *compared* two runs — a 30% decode-tok/s regression
ships silently as long as the smoke asserts pass.  This module seeds the
bench trajectory:

* a **baseline dir** (``benchmarks/baselines/`` in the repo) holds one
  committed JSON per bench, plus an optional ``tolerances.json`` whose
  ordered rules override the defaults;
* :func:`compare_bench` flattens both documents to dotted paths
  (``classes.gold.p95_ms_per_step``) and judges each metric under the
  first matching rule — **direction-aware**, because a faster tok/s is
  not a regression and neither is a lower ms/step;
* a **history dir** accumulates every compared run (seq-numbered atomic
  copies) and is uploaded as a CI artifact, so the trajectory is
  reconstructable even though runners are shared and noisy;
* ``python -m repro.obs diff`` is the CLI/CI gate: exit 1 on any
  regression, with a ``--json`` report for machines.

Default tolerances are deliberately loose on timing (shared CI runners
jitter hugely) and exact on structure: ``trace_count`` drifting from 1
to 2 is a contract break at any speed.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from fnmatch import fnmatchcase
from pathlib import Path

from .trace import atomic_write_json

__all__ = [
    "Rule",
    "DEFAULT_RULES",
    "load_rules",
    "flatten",
    "compare_bench",
    "record_history",
]


@dataclass(frozen=True)
class Rule:
    """How one family of metrics (dotted-path glob) is judged.

    ``direction``: ``"higher"`` — higher is better, only a drop beyond
    tolerance regresses; ``"lower"`` — lower is better; ``"both"`` — any
    drift beyond tolerance regresses; ``"exact"`` — any change at all;
    ``"ignore"`` — never compared (run-local noise like wall time).
    Tolerance is ``max(abs_tol, rel_tol * |baseline|)``.
    """

    pattern: str
    direction: str = "both"
    rel_tol: float = 0.25
    abs_tol: float = 0.0

    def __post_init__(self) -> None:
        if self.direction not in ("higher", "lower", "both", "exact",
                                  "ignore"):
            raise ValueError(f"bad direction {self.direction!r} "
                             f"for pattern {self.pattern!r}")
        if self.rel_tol < 0 or self.abs_tol < 0:
            raise ValueError(f"negative tolerance on {self.pattern!r}")

    def matches(self, path: str) -> bool:
        return fnmatchcase(path, self.pattern)

    def judge(self, baseline, current) -> str | None:
        """``"regression"`` / ``"improvement"`` / ``None`` (within
        tolerance).  Non-numeric values only support exact rules."""
        if self.direction == "ignore":
            return None
        if self.direction == "exact" or not (
                isinstance(baseline, (int, float))
                and isinstance(current, (int, float))
                and not isinstance(baseline, bool)
                and not isinstance(current, bool)):
            return None if current == baseline else "regression"
        tol = max(self.abs_tol, self.rel_tol * abs(float(baseline)))
        delta = float(current) - float(baseline)
        if abs(delta) <= tol:
            return None
        if self.direction == "both":
            return "regression"
        worse = delta < 0 if self.direction == "higher" else delta > 0
        return "regression" if worse else "improvement"


# ordered: first match wins.  Structure exact, throughput/latency
# direction-aware and CI-noise tolerant, run-local identifiers ignored.
DEFAULT_RULES: tuple[Rule, ...] = (
    Rule("*trace_count*", "exact"),
    Rule("*wall_s*", "ignore"),
    Rule("*unix_time*", "ignore"),
    Rule("*plan*", "ignore"),          # plan ids are content hashes
    Rule("*tok_s*", "higher", rel_tol=0.5),
    Rule("*ms*", "lower", rel_tol=1.0),
    Rule("*drift*", "lower", rel_tol=1.0, abs_tol=1e-6),
    Rule("*area*", "lower", rel_tol=0.25),
    Rule("*", "ignore"),               # unmatched: counts, labels, noise
)


def load_rules(path: str | os.PathLike | None) -> tuple[Rule, ...]:
    """Rules from a committed ``tolerances.json`` (a list of rule docs
    under ``"rules"``), falling back to :data:`DEFAULT_RULES`; loaded
    rules take precedence but the defaults still backstop them."""
    if path is None or not Path(path).exists():
        return DEFAULT_RULES
    doc = json.loads(Path(path).read_text())
    rules = tuple(Rule(**r) for r in doc.get("rules", []))
    return rules + DEFAULT_RULES


def flatten(doc, prefix: str = "") -> dict:
    """Flatten nested dicts/lists to ``{"a.b.0.c": scalar}``."""
    out: dict = {}
    if isinstance(doc, dict):
        items = doc.items()
    elif isinstance(doc, (list, tuple)):
        items = ((str(i), v) for i, v in enumerate(doc))
    else:
        return {prefix: doc}
    for k, v in items:
        path = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, (dict, list, tuple)):
            out.update(flatten(v, path))
        else:
            out[path] = v
    return out


def _rule_for(path: str, rules: tuple[Rule, ...]) -> Rule | None:
    for rule in rules:
        if rule.matches(path):
            return rule
    return None


def compare_bench(current: dict, baseline: dict,
                  rules: tuple[Rule, ...] = DEFAULT_RULES) -> dict:
    """Judge one bench run against its baseline.

    Returns ``{"regressions": [...], "improvements": [...],
    "compared": n}`` where each finding carries the dotted metric path,
    both values, and the matching rule's pattern.  A metric present in
    the baseline but *missing* from the current run is a regression
    unless its rule is ``ignore`` (a renamed field must move its
    baseline, not silently vanish)."""
    cur = flatten(current)
    base = flatten(baseline)
    regressions: list[dict] = []
    improvements: list[dict] = []
    compared = 0
    for path in sorted(base):
        rule = _rule_for(path, rules)
        if rule is None or rule.direction == "ignore":
            continue
        if path not in cur:
            regressions.append({"metric": path, "baseline": base[path],
                                "current": None, "rule": rule.pattern,
                                "kind": "missing"})
            continue
        compared += 1
        verdict = rule.judge(base[path], cur[path])
        finding = {"metric": path, "baseline": base[path],
                   "current": cur[path], "rule": rule.pattern}
        if verdict == "regression":
            regressions.append({**finding, "kind": "regression"})
        elif verdict == "improvement":
            improvements.append({**finding, "kind": "improvement"})
    return {"regressions": regressions, "improvements": improvements,
            "compared": compared}


def record_history(history_dir: str | os.PathLike, name: str,
                   doc: dict) -> Path:
    """Append one run's bench doc to the history dir as
    ``<name>-<seq>.json`` (atomic, never overwrites an earlier run)."""
    d = Path(history_dir)
    d.mkdir(parents=True, exist_ok=True)
    stem = Path(name).stem
    seq = 0
    while (path := d / f"{stem}-{seq:04d}.json").exists():
        seq += 1
    atomic_write_json(path, doc)
    return path
