"""Approximation-provenance ledger: which circuits computed which tokens.

The trace answers *when* things happened; this ledger answers *what
produced each output*.  QoS-Nets-style adaptive serving reassigns
operators mid-request — a preempted-and-resumed ``gold`` request can
decode its first tokens on one plan and its last on another after a
mid-flight swap — so quality claims ("drift stayed under budget") are
only auditable if every generated token can be traced back to the
(plan, ladder level, width map, per-layer operator content keys) that
was live when it was sampled, together with the shadow-drift samples
measured in that window.

Three append-only record kinds, one JSON object per line:

* ``plan``  — a plan's identity, written once per writer: ``plan_id``
  -> per-layer operator content keys (``"exact"`` for exact layers),
  the width map when serving mixed width, and — for the cost plane —
  the per-layer operator area bracket (``areas``/``areas_hi``, exact
  layers carry the baseline) plus the per-layer ``exact_area``.  The
  analog of telemetry's plan table, but durable next to the trace.
* ``model`` — the serving model's LUT-routable MLP MAC vector
  (:func:`repro.obs.costs.mlp_macs_per_layer`), written once per
  writer so ``python -m repro.obs costs`` prices a ledger offline
  without reloading the model config.
* ``range`` — one request's contiguous run of generated-token indices
  ``[t0, t1)`` decoded under a single plan/ladder level, plus the
  shadow-drift samples the engine measured while the range was open.
  Ranges close on plan change, preemption, and completion, so the
  ledger of a completed request tiles ``[0, gen_len)`` exactly.
* ``done``  — the request completed: expected ``gen_len``, total decode
  steps, preemption count.  :func:`audit` treats a ``done`` without a
  gap-free range cover as a provenance failure.

File discipline mirrors :mod:`repro.obs.trace`: one ``prov-<tag>.jsonl``
per writing process in the same trace directory, one flushed line per
record (a crash tears at most the final line), read-time merge with
torn-line tolerance and dedup by ``(writer tag, sequence)`` so re-copied
files stay idempotent.  Provenance volume is a few records per request —
no rotation needed.  Everything is stdlib-only.
"""

from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Callable

__all__ = [
    "PROV_GLOB",
    "ProvenanceLedger",
    "ledger_for",
    "read_ledger",
    "audit",
]

PROV_GLOB = "prov-*.jsonl"


class ProvenanceLedger:
    """One process's provenance writer (see module docstring).

    ``tag`` defaults to ``<hostname>-<pid>`` like the tracer's; serving
    code shares one ledger per ``(root, tag)`` via :func:`ledger_for` so
    router replicas in one process never interleave conflicting sequence
    numbers into the same file.
    """

    def __init__(self, root: str | os.PathLike, *, tag: str | None = None,
                 clock: Callable[[], float] = time.time) -> None:
        import socket

        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.tag = tag or f"{socket.gethostname()}-{os.getpid()}"
        self._clock = clock
        self._seq = 0
        self._fh = None
        self._lock = threading.Lock()
        self._plans_written: set[str] = set()
        self._model_written = False

    @property
    def path(self) -> Path:
        return self.root / f"prov-{self.tag}.jsonl"

    def _write(self, doc: dict) -> None:
        with self._lock:
            doc = {**doc, "w": self.tag, "n": self._seq,
                   "t": self._clock()}
            self._seq += 1
            if self._fh is None:
                self._fh = open(self.path, "a")
            self._fh.write(json.dumps(doc, sort_keys=True) + "\n")
            self._fh.flush()

    # ----------------------------------------------------------------- write
    def note_plan(self, plan_id: str, layers: list[str],
                  width_map=None, *, areas=None, areas_hi=None,
                  exact_area=None) -> None:
        """Record a plan's identity once per writer (content-addressed
        ids make cross-writer duplicates harmless — ``audit`` keeps the
        first).  ``areas``/``areas_hi`` carry the per-layer operator
        area bracket and ``exact_area`` the per-layer exact baseline,
        so the cost plane can price the plan offline."""
        if plan_id in self._plans_written:
            return
        self._plans_written.add(plan_id)
        doc = {"k": "plan", "plan": plan_id, "layers": list(layers),
               "width_map": (list(int(b) for b in width_map)
                             if width_map is not None else None)}
        if areas is not None:
            doc["areas"] = [round(float(a), 6) for a in areas]
        if areas_hi is not None:
            doc["areas_hi"] = [round(float(a), 6) for a in areas_hi]
        if exact_area is not None:
            doc["exact_area"] = round(float(exact_area), 6)
        self._write(doc)

    def note_model(self, *, name: str, macs: list[int]) -> None:
        """Record the model's per-layer LUT-routable MAC vector once per
        writer — the denominator every cost attribution joins against."""
        if self._model_written:
            return
        self._model_written = True
        self._write({"k": "model", "name": name,
                     "n_layers": len(macs),
                     "macs": [int(m) for m in macs]})

    def record_range(self, *, rid: int, cls: str, t0: int, t1: int,
                     plan: str, level: int | None,
                     drift: list[float], replica: str | None = None) -> None:
        doc = {"k": "range", "rid": int(rid), "cls": cls,
               "t0": int(t0), "t1": int(t1), "plan": plan,
               "level": level, "drift": list(drift)}
        if replica:
            doc["replica"] = replica
        self._write(doc)

    def record_done(self, *, rid: int, cls: str, gen_len: int, steps: int,
                    preempts: int, replica: str | None = None) -> None:
        doc = {"k": "done", "rid": int(rid), "cls": cls,
               "gen_len": int(gen_len), "steps": int(steps),
               "preempts": int(preempts)}
        if replica:
            doc["replica"] = replica
        self._write(doc)

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


# one shared writer per (root, tag): two engines started in one traced
# process (router mode) must append through one sequence counter, or the
# read-time (w, n) dedup would silently drop records
_ledgers: dict[tuple[str, str], ProvenanceLedger] = {}
_ledgers_lock = threading.Lock()


def ledger_for(root: str | os.PathLike, tag: str | None = None, *,
               clock: Callable[[], float] = time.time) -> ProvenanceLedger:
    probe = ProvenanceLedger(root, tag=tag, clock=clock)
    key = (str(Path(root)), probe.tag)
    with _ledgers_lock:
        return _ledgers.setdefault(key, probe)


# ---------------------------------------------------------------------------
# read-time merge + audit
# ---------------------------------------------------------------------------
def read_ledger(root: str | os.PathLike) -> list[dict]:
    """Union every ``prov-*.jsonl`` under ``root``: skip torn lines,
    dedup by ``(writer, seq)``, return records sorted by write order."""
    root = Path(root)
    records: dict[tuple[str, int], dict] = {}
    for path in sorted(root.glob(PROV_GLOB)):
        for line in path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                doc = json.loads(line)
            except json.JSONDecodeError:
                continue   # torn tail of a crashed writer
            if isinstance(doc, dict) and "w" in doc and "n" in doc:
                records.setdefault((doc["w"], int(doc["n"])), doc)
    return sorted(records.values(),
                  key=lambda r: (r.get("t", 0.0), r["w"], r["n"]))


def audit(records: list[dict]) -> dict:
    """Per-request provenance report over merged ledger records.

    A request that recorded ``done`` is *complete* when its ranges tile
    ``[0, gen_len)`` with no gap and no overlap and every referenced
    plan id has a ``plan`` record (``"exact"`` — the planless serve — is
    implicitly known).  Requests without a ``done`` (still in flight, or
    a serve that crashed) are reported but never counted as failures.

    Records are grouped by ``(rid, replica)``: two replicas that served
    the same rid (separate routers sharing one trace dir) never blend
    ranges into a false overlap — the report keys stay plain rids when
    unique and become ``"<rid>@<replica>"`` only on collision.
    """
    plans: dict[str, dict] = {}
    reqs: dict[tuple, dict] = {}
    for r in records:
        if r["k"] == "plan":
            entry = {"layers": r.get("layers", []),
                     "width_map": r.get("width_map")}
            for extra in ("areas", "areas_hi", "exact_area"):
                if r.get(extra) is not None:
                    entry[extra] = r[extra]
            plans.setdefault(r["plan"], entry)
        elif r["k"] in ("range", "done"):
            gkey = (r["rid"], r.get("replica") or "")
            row = reqs.setdefault(gkey, {"ranges": [], "done": None})
            if r["k"] == "range":
                row["ranges"].append(r)
            else:
                row["done"] = r

    rid_groups: dict[int, int] = {}
    for rid, _ in reqs:
        rid_groups[rid] = rid_groups.get(rid, 0) + 1
    out_reqs: dict = {}
    n_done = n_complete = 0
    for gkey in sorted(reqs):
        rid, replica = gkey
        row = reqs[gkey]
        ranges = sorted(row["ranges"], key=lambda r: (r["t0"], r["t1"]))
        done = row["done"]
        drift = [d for r in ranges for d in r.get("drift", ())]
        problems: list[str] = []
        covered = 0
        for r in ranges:
            if r["t0"] < covered:
                problems.append(f"overlap at token {r['t0']}")
            elif r["t0"] > covered:
                problems.append(f"gap at tokens [{covered}, {r['t0']})")
            covered = max(covered, r["t1"])
            if r["plan"] != "exact" and r["plan"] not in plans:
                problems.append(f"plan {r['plan']} has no plan record")
        rep = {
            "cls": (ranges[0]["cls"] if ranges
                    else done["cls"] if done else "?"),
            "ranges": [{
                "t0": r["t0"], "t1": r["t1"], "plan": r["plan"],
                "level": r.get("level"),
                "drift_samples": len(r.get("drift", ())),
            } for r in ranges],
            "tokens_covered": covered,
            "drift_samples": len(drift),
        }
        if replica:
            rep["replica"] = replica
        if drift:
            rep["mean_drift"] = round(sum(drift) / len(drift), 6)
            rep["max_drift"] = round(max(drift), 6)
        if done is not None:
            n_done += 1
            rep["gen_len"] = done["gen_len"]
            rep["steps"] = done["steps"]
            rep["preempts"] = done["preempts"]
            if covered != done["gen_len"]:
                problems.append(
                    f"ranges cover {covered}/{done['gen_len']} tokens")
            if not problems:
                n_complete += 1
        else:
            problems.append("no done record (in flight or crashed)")
        rep["complete"] = done is not None and not [
            p for p in problems if not p.startswith("no done")]
        rep["problems"] = problems
        out_reqs[rid if rid_groups[rid] == 1
                 else f"{rid}@{replica or '?'}"] = rep

    return {
        "plans": plans,
        "requests": out_reqs,
        "n_requests": len(out_reqs),
        "n_done": n_done,
        "n_complete": n_complete,
        "n_failed": n_done - n_complete,
    }
