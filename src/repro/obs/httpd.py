"""Live scrape endpoint: ``/metrics``, ``/healthz`` and ``/costs.json``.

A serve with ``--metrics-port`` answers Prometheus scrapes *while it
runs* instead of only dumping snapshots at exit.  The server is the
stdlib :class:`~http.server.ThreadingHTTPServer` on a daemon thread —
no framework, no new dependency — and every handler reads the same
sources the offline CLI reads, so a live scrape and a post-hoc
``python -m repro.obs`` report can never disagree about schema:

* ``GET /metrics`` — :func:`~repro.obs.export.prometheus_text` over a
  merged :class:`~repro.obs.metrics.MetricRegistry`: every registered
  snapshot provider (the process registry, each replica's telemetry)
  plus any ``metrics-*.json`` snapshots already in the trace dir.
* ``GET /healthz`` — the health plane's worst state as an HTTP status
  (``ok``→200, ``warn``→429, ``page``→503) with the full report as the
  JSON body, so a load balancer and a human read the same probe.
* ``GET /costs.json`` — :func:`~repro.obs.costs.cost_report` over the
  provenance ledger in the trace dir (404 until the first record
  lands, or when the serve is untraced).

Handlers never raise into the serve loop: any exception becomes a 500
on that one response.  Binding port 0 picks a free port; ``start()``
returns the real one.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable

from .export import prometheus_text, read_metrics
from .metrics import MetricRegistry

__all__ = ["MetricsServer", "HEALTH_STATUS"]

# worst health state -> HTTP status. 429 for warn (still serving, shed
# load), 503 for page (take it out of rotation).
HEALTH_STATUS = {"ok": 200, "warn": 429, "page": 503}


class MetricsServer:
    """Threaded HTTP endpoint over live registries + an optional trace dir.

    ``snapshot_providers`` are zero-arg callables returning registry
    snapshot docs (:meth:`MetricRegistry.snapshot`) — called fresh on
    every scrape so counters are live, not start-of-serve copies.
    ``health_provider`` returns a health-plane report dict with a
    ``"state"`` key; ``None`` means no health plane (always 200 ok).
    """

    def __init__(self, *, port: int = 0, host: str = "127.0.0.1",
                 snapshot_providers: list[Callable[[], dict]] | None = None,
                 health_provider: Callable[[], dict] | None = None,
                 trace_dir: str | None = None) -> None:
        self.snapshot_providers = list(snapshot_providers or [])
        self.health_provider = health_provider
        self.trace_dir = trace_dir
        self._host, self._port = host, port
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- responses
    def metrics_text(self) -> str:
        reg = MetricRegistry.from_snapshots(
            p() for p in self.snapshot_providers)
        if self.trace_dir is not None:
            reg.merge(read_metrics(self.trace_dir).snapshot())
        return prometheus_text(reg)

    def health_doc(self) -> tuple[int, dict]:
        if self.health_provider is None:
            return 200, {"state": "ok"}
        doc = self.health_provider()
        return HEALTH_STATUS.get(doc.get("state"), 500), doc

    def costs_doc(self) -> dict | None:
        if self.trace_dir is None:
            return None
        from .costs import cost_report
        from .provenance import read_ledger

        records = read_ledger(self.trace_dir)
        if not records:
            return None
        return cost_report(records)

    # --------------------------------------------------------------- control
    def start(self) -> int:
        server = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *args) -> None:   # keep the serve log clean
                pass

            def _send(self, status: int, body: bytes,
                      ctype: str) -> None:
                self.send_response(status)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self) -> None:   # noqa: N802 (http.server API)
                try:
                    path = self.path.split("?", 1)[0]
                    if path == "/metrics":
                        self._send(200, server.metrics_text().encode(),
                                   "text/plain; version=0.0.4")
                    elif path == "/healthz":
                        status, doc = server.health_doc()
                        self._send(status, (json.dumps(doc) + "\n").encode(),
                                   "application/json")
                    elif path == "/costs.json":
                        doc = server.costs_doc()
                        if doc is None:
                            self._send(404, b'{"error": "no ledger"}\n',
                                       "application/json")
                        else:
                            self._send(200,
                                       (json.dumps(doc) + "\n").encode(),
                                       "application/json")
                    else:
                        self._send(404, b"not found\n", "text/plain")
                except Exception as e:   # a scrape bug must not kill a serve
                    try:
                        self._send(500, f"{type(e).__name__}: {e}\n".encode(),
                                   "text/plain")
                    except OSError:
                        pass             # client went away mid-response

        self._httpd = ThreadingHTTPServer((self._host, self._port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="repro-metrics-httpd",
                                        daemon=True)
        self._thread.start()
        self._port = self._httpd.server_address[1]
        return self._port

    @property
    def port(self) -> int:
        return self._port

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
