"""Exporters: Prometheus text format and atomic bench-JSON views.

The bench JSONs CI tracks (``BENCH_serve/kernels/qos.json``) are *views
over the metric registry*, not hand-assembled dicts: a subsystem records
into its :class:`~repro.obs.metrics.MetricRegistry` and the exporter
renders whatever is there.  Everything lands on disk through the same
``os.replace`` discipline as the operator store, so a crash mid-serve
never leaves a truncated artifact.
"""

from __future__ import annotations

import os
import re
from pathlib import Path

from .metrics import Counter, Gauge, Histogram, MetricRegistry
from .trace import atomic_write_json

__all__ = [
    "prometheus_text",
    "write_bench_json",
    "dump_metrics",
    "read_metrics",
    "METRICS_GLOB",
]

METRICS_GLOB = "metrics-*.json"   # per-process snapshots inside a trace dir

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _NAME_OK.sub("_", name)
    return name if not name[:1].isdigit() else "_" + name


def _prom_label_value(v: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote, newline."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _prom_labels(labels: dict, extra: dict | None = None) -> str:
    merged = {**labels, **(extra or {})}
    if not merged:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{_prom_label_value(v)}"'
                     for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def prometheus_text(registry: MetricRegistry) -> str:
    """Render a registry in the Prometheus exposition text format.

    Counters render as ``<name>_total``, histograms as the conventional
    cumulative ``_bucket{le=...}`` / ``_sum`` / ``_count`` triplet.
    """
    by_family: dict[str, list] = {}
    kinds: dict[str, str] = {}
    for name, labels, metric in registry.entries():
        by_family.setdefault(name, []).append((labels, metric))
        kinds[name] = metric.kind

    lines: list[str] = []
    for name in sorted(by_family):
        kind = kinds[name]
        pname = _prom_name(name)
        if kind == "counter":
            pname += "_total"
        lines.append(f"# TYPE {pname} {kind}")
        for labels, metric in by_family[name]:
            if isinstance(metric, (Counter, Gauge)):
                lines.append(f"{pname}{_prom_labels(labels)} "
                             f"{metric.value:g}")
            elif isinstance(metric, Histogram):
                cum = 0
                for i, bound in enumerate(metric.buckets):
                    cum += metric.counts[i]
                    lines.append(
                        f"{pname}_bucket"
                        f"{_prom_labels(labels, {'le': f'{bound:g}'})} {cum}")
                lines.append(
                    f"{pname}_bucket"
                    f"{_prom_labels(labels, {'le': '+Inf'})} {metric.count}")
                lines.append(f"{pname}_sum{_prom_labels(labels)} "
                             f"{metric.sum:g}")
                lines.append(f"{pname}_count{_prom_labels(labels)} "
                             f"{metric.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_bench_json(path: str | os.PathLike, doc: dict) -> None:
    """Write one bench/telemetry JSON document atomically, creating parent
    directories — the one way any CI-tracked JSON reaches disk."""
    atomic_write_json(Path(path), doc)


# ---------------------------------------------------------------------------
# metric snapshots inside a trace dir (file per process, merged at read)
# ---------------------------------------------------------------------------
def dump_metrics(trace_dir: str | os.PathLike, registry: MetricRegistry,
                 *, tag: str | None = None) -> Path:
    """Snapshot ``registry`` into ``<trace_dir>/metrics-<tag>.json``
    (atomic).  Same file-per-process layout as the span files; the obs
    CLI merges every snapshot it finds."""
    if tag is None:
        import socket

        tag = f"{socket.gethostname()}-{os.getpid()}"
    path = Path(trace_dir) / f"metrics-{tag}.json"
    atomic_write_json(path, registry.snapshot())
    return path


def read_metrics(trace_dir: str | os.PathLike) -> MetricRegistry:
    """Merge every per-process metric snapshot under a trace dir."""
    import json

    reg = MetricRegistry()
    for path in sorted(Path(trace_dir).glob(METRICS_GLOB)):
        try:
            reg.merge(json.loads(path.read_text()))
        except json.JSONDecodeError:
            continue   # torn writer; snapshots are atomic so only possible
            #            for files produced by foreign tools
    return reg
