"""Chrome trace-event export: open a serve's span stream as a flame graph.

``python -m repro.obs export --format chrome`` converts the merged span
trace (:func:`repro.obs.trace.read_trace` — every writer tag, rotated
segments included) into the Chrome trace-event JSON format that
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing`` load
directly, so ``serve.batch`` > ``serve.prefill``/``serve.decode``/
``serve.shadow`` nesting and the ``req.*`` request-lifecycle events
read as a flame graph instead of a JSONL scroll.

Mapping: every span becomes one complete (``"ph": "X"``) event with
microsecond ``ts``/``dur`` relative to the trace's first timestamp.
Chrome nests events on a track (``tid``) purely by time containment,
and our writer guarantees children close before their parents on the
same clock — so parentage is preserved by putting every span on its
*root's* track and packing roots onto tracks greedily (a new root takes
the first track that is idle at its start time).  Span ids and parent
ids ride in ``args`` next to the span's own attrs, so the explicit
parent chain survives the conversion verbatim.  Stdlib-only.
"""

from __future__ import annotations

import json
import os
from pathlib import Path

__all__ = ["chrome_trace", "export_chrome"]


def chrome_trace(spans: list[dict]) -> dict:
    """Convert merged span docs to a Chrome trace-event document."""
    by_id = {s["id"]: s for s in spans if s.get("id")}
    # a span is a root when it has no parent or the parent record is
    # missing (torn tail of a crashed writer)
    root_of: dict[str, str] = {}

    def find_root(sid: str) -> str:
        seen = []
        cur = sid
        while True:
            cached = root_of.get(cur)
            if cached is not None:
                break
            seen.append(cur)
            parent = by_id[cur].get("parent")
            if parent is None or parent not in by_id or parent == cur:
                cached = cur
                break
            cur = parent
        for s in seen:
            root_of[s] = cached
        return cached

    for sid in by_id:
        find_root(sid)

    # greedy track packing over the roots: overlapping roots (two
    # processes, two threads) land on separate tracks so their subtrees
    # nest without interleaving
    roots = sorted({r for r in root_of.values()},
                   key=lambda r: (by_id[r]["t0"], -by_id[r].get("dur_s", 0.0),
                                  r))
    track_end: list[float] = []
    track_of: dict[str, int] = {}
    for r in roots:
        t0 = by_id[r]["t0"]
        t1 = t0 + max(0.0, by_id[r].get("dur_s", 0.0))
        for i, end in enumerate(track_end):
            if end <= t0 + 1e-9:
                track_of[r] = i
                track_end[i] = t1
                break
        else:
            track_of[r] = len(track_end)
            track_end.append(t1)

    t_base = min((s["t0"] for s in by_id.values()), default=0.0)
    events: list[dict] = []
    for sid, s in sorted(by_id.items(), key=lambda i: (i[1]["t0"], i[0])):
        tid = track_of[root_of[sid]] + 1
        args = dict(s.get("attrs") or {})
        args["span_id"] = sid
        if s.get("parent"):
            args["parent_id"] = s["parent"]
        events.append({
            "name": s["name"],
            "cat": s["name"].split(".", 1)[0],
            "ph": "X",
            "pid": 1,
            "tid": tid,
            "ts": round((s["t0"] - t_base) * 1e6, 3),
            "dur": round(max(0.0, s.get("dur_s", 0.0)) * 1e6, 3),
            "args": args,
        })
    meta = [{"name": "process_name", "ph": "M", "pid": 1,
             "args": {"name": "repro trace"}}]
    meta += [{"name": "thread_name", "ph": "M", "pid": 1, "tid": i + 1,
              "args": {"name": f"track {i + 1}"}}
             for i in range(len(track_end))]
    return {
        "traceEvents": meta + events,
        "displayTimeUnit": "ms",
        "otherData": {"t0": t_base, "spans": len(events)},
    }


def export_chrome(spans: list[dict],
                  out: str | os.PathLike | None = None) -> dict:
    """Render :func:`chrome_trace` to ``out`` (or return it for stdout
    printing).  Parent dirs are created; the write is plain (the export
    is a one-shot CLI, not a crash-safe stream)."""
    doc = chrome_trace(spans)
    if out is not None:
        path = Path(out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc) + "\n")
    return doc
