"""Pareto-front management over stored operators.

Dominance is in the minimization sense over a tuple of objectives (for
operators: synthesized area and measured error).  :func:`pareto_front` is
generic — the perf hillclimb uses it over roofline terms — while
:class:`ParetoFrontier` wraps the operator-specific area-vs-error queries
that replace the per-script ``report.best`` idiom.
"""

from __future__ import annotations

from typing import Callable, Iterable, Sequence, TypeVar

from .store import OperatorRecord, OperatorStore

T = TypeVar("T")

__all__ = ["dominates", "pareto_front", "ParetoFrontier", "frontier_sizes"]


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """``a`` dominates ``b``: no objective worse, at least one strictly better."""
    assert len(a) == len(b)
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def pareto_front(
    items: Iterable[T],
    objectives: Sequence[Callable[[T], float]],
) -> list[T]:
    """Non-dominated subset of ``items``, minimizing every objective.

    Duplicated objective vectors keep their first representative.  The
    result is sorted by the first objective (ascending).
    """
    pts = [(tuple(f(it) for f in objectives), it) for it in items]
    pts.sort(key=lambda p: p[0])
    front: list[tuple[tuple, T]] = []
    seen: set[tuple] = set()
    for vec, it in pts:
        if vec in seen:
            continue
        if any(dominates(fvec, vec) for fvec, _ in front):
            continue
        front[:] = [(fvec, fit) for fvec, fit in front if not dominates(vec, fvec)]
        front.append((vec, it))
        seen.add(vec)
    front.sort(key=lambda p: p[0])
    return [it for _, it in front]


class ParetoFrontier:
    """Area-vs-error frontier over a set of :class:`OperatorRecord`s.

    Error is the *measured* worst-case error (``wce``), not the search
    threshold: a search run under ET=8 that happened to land at wce=3 sits
    at 3 on the frontier.
    """

    def __init__(self, records: Iterable[OperatorRecord]) -> None:
        self.records = list(records)
        self.front: list[OperatorRecord] = pareto_front(
            self.records, (lambda r: r.area, lambda r: float(r.wce))
        )

    @classmethod
    def from_store(
        cls,
        store: OperatorStore,
        op_kind: str | None = None,
        bits: int | None = None,
        **query_kw,
    ) -> "ParetoFrontier":
        return cls(store.query(op_kind, bits, **query_kw))

    def __len__(self) -> int:
        return len(self.front)

    def query(
        self, *, max_error: float | None = None, max_area: float | None = None
    ) -> list[OperatorRecord]:
        """Frontier operators satisfying the bounds, cheapest-area first."""
        out = self.front
        if max_error is not None:
            out = [r for r in out if r.wce <= max_error]
        if max_area is not None:
            out = [r for r in out if r.area <= max_area]
        return out

    def best_under_error(self, max_error: float) -> OperatorRecord | None:
        """Smallest-area operator whose measured wce fits the bound."""
        fits = self.query(max_error=max_error)
        return fits[0] if fits else None

    def most_accurate(self) -> OperatorRecord | None:
        return min(self.front, key=lambda r: (r.wce, r.area)) if self.front else None

    def cheapest(self) -> OperatorRecord | None:
        return self.front[0] if self.front else None


def frontier_sizes(store: OperatorStore) -> dict[str, tuple[int, int]]:
    """Per-signature ``{dirname: (record_count, frontier_size)}``.

    The fleet's densification report diffs two of these snapshots (before
    and after a sweep) to show what the run actually bought.
    """
    out: dict[str, tuple[int, int]] = {}
    for sig in store.signatures():
        recs = store.records(sig)
        out[sig.dirname] = (len(recs), len(ParetoFrontier(recs)))
    return out
