"""Lower stored operators to the packed LUT ``kernels/approx_matmul`` eats.

The Pallas kernel consumes a dense ``(16, 16) int32`` table over unsigned
4-bit codes.  :func:`repro.quant.lut.build_lut` only handled the 4x4-bit
multiplier; here any stored operator lowers to that format:

* **4-bit multiplier** — direct evaluation (identical to ``build_lut``).
* **sub-4-bit multiplier** — recursive tiling: split each 4-bit operand
  into ``ceil(4/b)`` b-bit chunks and sum the shifted chunk products
  ``M[a_i, b_j] << b(i+j)``, with ``M`` the operator's base table.  This
  is how small approximate building blocks scale up in hardware
  (Kulkarni-style 2x2 multipliers composing a 4x4).
* **adder** — carry-ripple chaining of b-bit blocks: each chunk sum goes
  through the approximate adder, the carry is folded in with a second
  application of the block, and chunk results concatenate.  The result is
  the operator's full 16x16 behaviour map (useful for accumulator
  emulation and error analysis; the matmul route consumes mul tables).

Compiled tables are cached in-memory, keyed by the record's content key —
re-planning a fleet of layers hits the cache, not the evaluator.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.circuits import Circuit
from ..quant.lut import build_lut
from .store import OperatorRecord

__all__ = [
    "CompiledLut",
    "base_table",
    "compile_circuit",
    "compile_record",
    "exact_lut16",
    "load_mul_frontier",
    "clear_compile_cache",
    "compile_cache_stats",
]


def base_table(circuit: Circuit, bits: int) -> np.ndarray:
    """The operator's ``(2**bits, 2**bits)`` behaviour map — a checked,
    widened view of :func:`repro.quant.lut.build_lut` (tiling shifts need
    int64 headroom)."""
    assert circuit.n_inputs == 2 * bits, (
        f"expected {2 * bits} inputs for a {bits}-bit operator, "
        f"got {circuit.n_inputs}"
    )
    return build_lut(circuit).astype(np.int64)


def _chunks(x: np.ndarray, bits: int) -> list[np.ndarray]:
    mask = (1 << bits) - 1
    n = -(-4 // bits)  # ceil(4 / bits)
    return [(x >> (bits * i)) & mask for i in range(n)]


def _tile_mul(base: np.ndarray, bits: int) -> np.ndarray:
    """Compose a 4x4 multiplier table from a b-bit multiplier block."""
    a = np.arange(16)
    ai, bj = _chunks(a, bits), _chunks(a, bits)
    out = np.zeros((16, 16), dtype=np.int64)
    for i, ac in enumerate(ai):
        for j, bc in enumerate(bj):
            out += base[ac[:, None], bc[None, :]] << (bits * (i + j))
    return out


def _chain_add(base: np.ndarray, bits: int) -> np.ndarray:
    """Compose a 4+4-bit adder table by carry-rippling b-bit blocks."""
    mask = (1 << bits) - 1
    a = np.arange(16)
    ai, bj = _chunks(a, bits), _chunks(a, bits)
    carry = np.zeros((16, 16), dtype=np.int64)
    out = np.zeros((16, 16), dtype=np.int64)
    for i, (ac, bc) in enumerate(zip(ai, bj)):
        t = base[ac[:, None], bc[None, :]]
        if i == 0:
            s, carry = t & mask, t >> bits
        else:
            # fold the incoming carry with a second block application
            t2 = base[t & mask, carry]
            s = t2 & mask
            carry = np.minimum(1, (t >> bits) + (t2 >> bits))
        out += s << (bits * i)
    # the final carry sits one chunk above the last block (bit 4 for 1/2/4-bit
    # blocks, bit 6 for 3-bit blocks whose top chunk spans bits 3..5)
    return out + (carry << (bits * len(ai)))


def exact_lut16(op_kind: str) -> np.ndarray:
    """Exact 16x16 reference semantics for a compiled table."""
    a = np.arange(16, dtype=np.int64)
    if op_kind == "mul":
        return a[:, None] * a[None, :]
    if op_kind == "adder":
        return a[:, None] + a[None, :]
    raise ValueError(f"unknown op_kind {op_kind!r}")


@dataclass(frozen=True)
class CompiledLut:
    """A (16, 16) table plus its error metrics *at the compiled level* —
    tiling amplifies block errors, so QoS prediction must use these, not
    the block-level wce."""

    lut: np.ndarray          # (16, 16) int32
    op_kind: str
    bits: int
    wce16: int               # worst |err| of the compiled table vs exact
    mae16: float             # mean |err| of the compiled table vs exact


def compile_circuit(circuit: Circuit, op_kind: str, bits: int) -> CompiledLut:
    base = base_table(circuit, bits)
    if op_kind == "mul":
        lut = base if bits == 4 else _tile_mul(base, bits)
    elif op_kind == "adder":
        lut = _chain_add(base, bits)
    else:
        raise ValueError(f"unknown op_kind {op_kind!r}")
    err = np.abs(lut - exact_lut16(op_kind))
    return CompiledLut(
        lut=lut.astype(np.int32),
        op_kind=op_kind,
        bits=bits,
        wce16=int(err.max()),
        mae16=float(err.mean()),
    )


def load_mul_frontier(library) -> tuple[list[tuple[OperatorRecord, "CompiledLut"]], float, int]:
    """One-stop loader for consumers (example, serve): open a store, take
    the widest-operand multiplier frontier, compile every frontier record,
    and return ``(compiled, exact_area, bits)``.

    Raises :class:`LookupError` when the store holds no multipliers.
    """
    from ..core.arith import benchmark
    from ..core.synth import area
    from .pareto import ParetoFrontier
    from .store import OperatorStore

    store = OperatorStore(library)
    sigs = [s for s in store.signatures() if s.op_kind == "mul"]
    if not sigs:
        raise LookupError(
            f"no multiplier operators in library {library}; fill it with: "
            f"python -m repro.core.search --benchmark mul_i4 --library {library}"
        )
    bits = max(s.bits for s in sigs)
    frontier = ParetoFrontier.from_store(store, "mul", bits)
    compiled = [(rec, compile_record(rec)) for rec in frontier.front]
    exact_area = area(benchmark(f"mul_i{2 * bits}"))
    return compiled, exact_area, bits


# ---------------------------------------------------------------------------
# in-memory compile cache
# ---------------------------------------------------------------------------
_CACHE: dict[tuple[str, str, int], CompiledLut] = {}
_STATS = {"hits": 0, "misses": 0}


def compile_record(record: OperatorRecord) -> CompiledLut:
    """Compile a stored operator, memoized by its content key."""
    key = (record.key or record.content_key(), record.signature.op_kind,
           record.signature.bits)
    hit = _CACHE.get(key)
    if hit is not None:
        _STATS["hits"] += 1
        return hit
    _STATS["misses"] += 1
    out = compile_circuit(record.circuit, record.signature.op_kind,
                          record.signature.bits)
    _CACHE[key] = out
    return out


def clear_compile_cache() -> None:
    _CACHE.clear()
    _STATS.update(hits=0, misses=0)


def compile_cache_stats() -> dict[str, int]:
    return dict(_STATS, size=len(_CACHE))
