"""Lower stored operators to the packed LUTs ``kernels/approx_matmul`` eats.

The Pallas kernels consume dense behaviour tables over unsigned codes —
``(16, 16)`` for the native 4-bit regime, ``(256, 256)`` for the composed
W8A8 regime.  :func:`repro.quant.lut.build_lut` only handled the 4x4-bit
multiplier; here any stored 1–4-bit operator lowers to any supported
*target width* through :mod:`repro.precision.compose`:

* **block == target** — direct evaluation (identical to ``build_lut``).
* **multiplier below target** — shift-add tiling of b-bit chunk products
  (Kulkarni-style 2x2 blocks composing a 4x4; the same recurrence carries
  the 16x16 tile up to 256x256 for W8A8, where the two-level form keeps
  the table kernel-consumable).
* **adder** — carry-ripple chaining of b-bit blocks at the target width.

Composition exactness identities are checked at build time inside the
composer (exact blocks must reproduce exact tables); compiled tables are
cached in-memory, keyed by ``(record content key, op_kind, bits,
target_bits)`` — re-planning a fleet of layers hits the cache, not the
evaluator.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from ..core.circuits import Circuit
from ..precision import compose
from ..precision.widths import NATIVE_BLOCK_BITS, exact_table, get_width
from ..quant.lut import build_lut
from .store import OperatorRecord

__all__ = [
    "CompiledLut",
    "base_table",
    "compile_circuit",
    "compile_record",
    "exact_lut16",
    "load_mul_frontier",
    "clear_compile_cache",
    "compile_cache_stats",
]


def base_table(circuit: Circuit, bits: int) -> np.ndarray:
    """The operator's ``(2**bits, 2**bits)`` behaviour map — a checked,
    widened view of :func:`repro.quant.lut.build_lut` (composition shifts
    need int64 headroom)."""
    assert circuit.n_inputs == 2 * bits, (
        f"expected {2 * bits} inputs for a {bits}-bit operator, "
        f"got {circuit.n_inputs}"
    )
    return build_lut(circuit).astype(np.int64)


def exact_lut16(op_kind: str) -> np.ndarray:
    """Exact 16x16 reference semantics (the 4-bit special case of
    :func:`repro.precision.widths.exact_table`)."""
    return exact_table(op_kind, NATIVE_BLOCK_BITS)


@dataclass(frozen=True)
class CompiledLut:
    """A behaviour table at its compiled *target width*, plus its error
    metrics at that level — composition amplifies block errors, so QoS
    prediction must use these, not the block-level wce.

    ``wce16`` / ``mae16`` keep their historical names but are measured
    against the exact table of ``target_bits`` (for an 8-bit target they
    span the full 256x256 composition); :attr:`wce` / :attr:`mae` are the
    width-neutral spellings.  ``tile`` holds the 16x16 generator tile of
    a wide multiplier table — the array the two-level Pallas kernel
    actually loads.

    ``area_lo``/``area_hi`` bracket the operator's area at the compiled
    width (:func:`load_mul_frontier` fills them): the lower bound is the
    block-count scaling of :func:`repro.precision.compose.compose_blocks`
    (partial-product glue adders ignored), the upper bound adds a
    ripple-carry ceiling on that glue
    (:func:`repro.precision.compose.compose_glue_bits`).  Native
    uncomposed tables carry a collapsed bracket (``lo == hi``).  The
    cost plane reports the area·MAC dividend as this bracket, never a
    point estimate.
    """

    lut: np.ndarray          # (side, side) int32 at the target width
    op_kind: str
    bits: int                # the *block* width the operator was searched at
    wce16: int               # worst |err| of the compiled table vs exact
    mae16: float             # mean |err| of the compiled table vs exact
    target_bits: int = NATIVE_BLOCK_BITS
    tile: np.ndarray | None = None   # 16x16 generator (wide mul targets only)
    area_lo: float | None = None     # composed-area lower bound (µm²)
    area_hi: float | None = None     # lower bound + glue-adder ceiling

    @property
    def wce(self) -> int:
        return self.wce16

    @property
    def mae(self) -> float:
        return self.mae16

    @property
    def side(self) -> int:
        return self.lut.shape[-1]


def compile_circuit(circuit: Circuit, op_kind: str, bits: int,
                    target_bits: int = NATIVE_BLOCK_BITS) -> CompiledLut:
    """Lower a b-bit block netlist to its ``target_bits`` behaviour table."""
    get_width(target_bits)   # reject unsupported targets early
    base = base_table(circuit, bits)
    tile = None
    if op_kind == "mul" and target_bits > NATIVE_BLOCK_BITS:
        tile = (base if bits == NATIVE_BLOCK_BITS
                else compose.compose_table(base, "mul", bits,
                                           NATIVE_BLOCK_BITS))
        tile = tile.astype(np.int32)
    lut = compose.compose_table(base, op_kind, bits, target_bits)
    err = np.abs(lut - exact_table(op_kind, target_bits))
    return CompiledLut(
        lut=lut.astype(np.int32),
        op_kind=op_kind,
        bits=bits,
        wce16=int(err.max()),
        mae16=float(err.mean()),
        target_bits=target_bits,
        tile=tile,
    )


def load_mul_frontier(
    library, target_bits: int | None = None
) -> tuple[list[tuple[OperatorRecord, "CompiledLut"]], float, int]:
    """One-stop loader for consumers (example, serve, watcher): open a
    store, build the multiplier frontier, compile every frontier record,
    and return ``(compiled, exact_area, bits)``.

    ``target_bits=None`` is the legacy native path: the widest-operand
    block frontier, compiled to the 16x16 LUT, with the store's own
    per-record areas (third element = the block width).

    With an explicit ``target_bits`` (the W8A8 path is ``8``), *every*
    stored multiplier block is composed up to the target, its area scaled
    by the block count the composition spends
    (:func:`repro.precision.compose.compose_blocks` — partial-product
    glue adders are ignored, so areas are a lower bound), and the
    frontier is re-taken over ``(composed area, composed wce)``: a tiny
    2-bit block that composes into a terrible 256x256 table loses to a
    4-bit block that composes cleanly.  ``exact_area`` is the exact
    ``target_bits`` array multiplier's.

    Raises :class:`LookupError` when the store holds no multipliers.
    """
    from ..core.arith import benchmark
    from ..core.synth import area
    from .pareto import ParetoFrontier, pareto_front
    from .store import OperatorStore

    store = OperatorStore(library)
    sigs = [s for s in store.signatures() if s.op_kind == "mul"]
    if not sigs:
        raise LookupError(
            f"no multiplier operators in library {library}; fill it with: "
            f"python -m repro.fleet --library {library} --sweep smoke"
        )
    if target_bits is None:
        bits = max(s.bits for s in sigs)
        frontier = ParetoFrontier.from_store(store, "mul", bits)
        compiled = [(rec, dataclasses.replace(compile_record(rec),
                                              area_lo=rec.area,
                                              area_hi=rec.area))
                    for rec in frontier.front]
        exact_area = area(benchmark(f"mul_i{2 * bits}"))
        return compiled, exact_area, bits

    width = get_width(target_bits)
    # glue-adder ceiling: ripple-carry cell area per bit position, taken
    # from the exact 4-bit benchmark adder (adder_i8 = two 4-bit operands)
    adder_bit_area = area(benchmark("adder_i8")) / 4.0
    pairs: list[tuple[OperatorRecord, CompiledLut]] = []
    for rec in store.query("mul"):
        comp = compile_record(rec, target_bits=width.bits)
        lo = rec.area * compose.compose_blocks(rec.signature.bits,
                                               width.bits)
        hi = lo + adder_bit_area * compose.compose_glue_bits(
            rec.signature.bits, width.bits)
        comp = dataclasses.replace(comp, area_lo=lo, area_hi=hi)
        scaled = dataclasses.replace(rec, area=lo)
        pairs.append((scaled, comp))
    front = pareto_front(pairs, (lambda p: p[0].area,
                                 lambda p: float(p[1].wce16)))
    exact_area = area(benchmark(width.benchmark_name))
    return front, exact_area, width.bits


# ---------------------------------------------------------------------------
# in-memory compile cache
# ---------------------------------------------------------------------------
_CACHE: dict[tuple[str, str, int, int], CompiledLut] = {}
_STATS = {"hits": 0, "misses": 0}


def compile_record(record: OperatorRecord,
                   target_bits: int = NATIVE_BLOCK_BITS) -> CompiledLut:
    """Compile a stored operator, memoized by (content key, target width)."""
    key = (record.key or record.content_key(), record.signature.op_kind,
           record.signature.bits, target_bits)
    hit = _CACHE.get(key)
    if hit is not None:
        _STATS["hits"] += 1
        return hit
    _STATS["misses"] += 1
    out = compile_circuit(record.circuit, record.signature.op_kind,
                          record.signature.bits, target_bits)
    _CACHE[key] = out
    return out


def clear_compile_cache() -> None:
    _CACHE.clear()
    _STATS.update(hits=0, misses=0)


def compile_cache_stats() -> dict[str, int]:
    return dict(_STATS, size=len(_CACHE))
