"""Versioned, content-addressed artifact store for approximate operators.

Layout (one directory per operator signature, one JSON file per operator)::

    <root>/
      mul2b_wce1/
        3f9a2c41d0b85e77.json     # content-addressed key
        ...
      adder2b_wce2/
        ...

Each record carries the full circuit netlist, the template parameters that
produced it (when the source was a template search), synthesized area, the
search proxies, and error metrics *measured exhaustively at store time*
against the exact reference operator — a record is never trusted on the
producer's say-so.  ``FORMAT_VERSION`` is embedded per record; readers
reject newer formats instead of misparsing them.

The content key is the SHA-256 of the canonical (sorted-keys) JSON of the
behaviour-defining payload, so re-running a search that finds the same
netlist is a no-op ``put`` and two stores can be merged with ``cp``.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable

import numpy as np

from ..core.arith import benchmark
from ..core.circuits import Circuit, Gate, Op
from ..core.miter import measure_error
from ..core.templates import TemplateParams

__all__ = [
    "FORMAT_VERSION",
    "OperatorSignature",
    "OperatorRecord",
    "OperatorStore",
    "atomic_write_json",
    "circuit_to_dict",
    "circuit_from_dict",
]

FORMAT_VERSION = 1


def atomic_write_json(path: Path, doc: dict) -> None:
    """Serialize ``doc`` to a uniquely named temp file next to ``path`` and
    ``os.replace`` it into place (atomic on POSIX): concurrent writers —
    fleet workers sharing one store — never expose torn JSON, and losing a
    same-destination race just publishes identical bytes twice."""
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=f".{path.stem}.",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(json.dumps(doc, sort_keys=True, indent=1))
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise

OP_KINDS = ("mul", "adder")


# ---------------------------------------------------------------------------
# circuit / params serialization
# ---------------------------------------------------------------------------
def circuit_to_dict(c: Circuit) -> dict:
    return {
        "n_inputs": c.n_inputs,
        "nodes": [[g.op.value, list(g.args)] for g in c.nodes],
        "outputs": list(c.outputs),
        "name": c.name,
    }


def circuit_from_dict(d: dict) -> Circuit:
    c = Circuit(n_inputs=int(d["n_inputs"]), name=d.get("name", "circuit"))
    c.nodes = [Gate(Op(op), tuple(args)) for op, args in d["nodes"]]
    c.outputs = [int(o) for o in d["outputs"]]
    return c


def _params_to_dict(p: TemplateParams | None) -> dict | None:
    if p is None:
        return None
    return {"lits": p.lits.tolist(), "sel": p.sel.tolist()}


def _params_from_dict(d: dict | None) -> TemplateParams | None:
    if d is None:
        return None
    return TemplateParams(
        np.asarray(d["lits"], dtype=np.int8), np.asarray(d["sel"], dtype=bool)
    )


# ---------------------------------------------------------------------------
# signature / record
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class OperatorSignature:
    """What the operator *is*: ``(op_kind, bits, error_metric, threshold)``."""

    op_kind: str        # "mul" | "adder"
    bits: int           # operand bit width (the paper: 2, 3, 4)
    error_metric: str   # "wce" | "mae" | "mse" (paper's miter: wce)
    threshold: int      # the ET the operator was searched under

    def __post_init__(self) -> None:
        # ValueError (not assert): signatures() must be able to skip foreign
        # directories (e.g. a future mul8b_* store merged in with cp)
        if self.op_kind not in OP_KINDS:
            raise ValueError(f"unknown op_kind {self.op_kind!r}")
        if not 1 <= self.bits <= 4:
            raise ValueError("LUT lowering supports 1..4-bit operands")
        # the threshold is part of the dirname; a fractional one (tempting
        # for mae/mse signatures) would not round-trip through
        # from_dirname — 'mae0.5' parses as metric 'mae0.' — so records
        # would be written but never correctly read back.  Refuse loudly.
        if self.threshold != int(self.threshold) or self.threshold < 1:
            raise ValueError(
                f"threshold must be a positive integer (got "
                f"{self.threshold!r}); signature dirnames cannot encode "
                f"fractional thresholds — scale the metric instead"
            )
        # normalize 2.0 -> 2 so the dirname never renders a float repr
        object.__setattr__(self, "threshold", int(self.threshold))
        if self.error_metric != self.error_metric.rstrip("0123456789."):
            raise ValueError(
                f"error_metric {self.error_metric!r} must not end in "
                f"digits (it would not round-trip through the dirname)"
            )

    @property
    def dirname(self) -> str:
        return f"{self.op_kind}{self.bits}b_{self.error_metric}{self.threshold}"

    @classmethod
    def from_dirname(cls, name: str) -> "OperatorSignature":
        kind_bits, metric_thr = name.split("_", 1)
        for kind in OP_KINDS:
            if kind_bits.startswith(kind):
                bits = int(kind_bits[len(kind):-1])
                break
        else:
            raise ValueError(f"unparseable signature dir {name!r}")
        metric = metric_thr.rstrip("0123456789")
        return cls(kind, bits, metric, int(metric_thr[len(metric):]))

    @property
    def benchmark_name(self) -> str:
        return f"{self.op_kind}_i{2 * self.bits}"

    def exact_values(self) -> np.ndarray:
        """Ground-truth outputs of the exact reference operator."""
        return benchmark(self.benchmark_name).eval_words()


@dataclass
class OperatorRecord:
    """One stored operator: netlist + provenance + measured error metrics."""

    signature: OperatorSignature
    circuit: Circuit
    area: float
    wce: int                      # measured exhaustively at store time
    mae: float                    # mean |err| over all assignments (QoS predictor)
    mse: float = -1.0             # mean squared err (-1 = pre-mse record)
    source: str = "unknown"       # shared | xpat | muscat | mecals | tensor | ...
    proxies: dict = field(default_factory=dict)
    params: TemplateParams | None = None
    meta: dict = field(default_factory=dict)   # grid_point, wall_s, ...
    key: str = ""                 # content hash; filled by the store

    def payload(self) -> dict:
        """The behaviour-defining payload the content key hashes over."""
        return {
            "format_version": FORMAT_VERSION,
            "signature": {
                "op_kind": self.signature.op_kind,
                "bits": self.signature.bits,
                "error_metric": self.signature.error_metric,
                "threshold": self.signature.threshold,
            },
            "circuit": circuit_to_dict(self.circuit),
            "params": _params_to_dict(self.params),
        }

    def content_key(self) -> str:
        blob = json.dumps(self.payload(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------
class OperatorStore:
    """Directory-backed operator library.

    ``put`` is idempotent (content-addressed); ``query`` returns records
    re-verified at read time only structurally (metrics were measured at
    write time and live in the record).
    """

    def __init__(self, root: str | os.PathLike) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ write
    def put(self, record: OperatorRecord) -> str:
        """Persist ``record``; idempotent and, via :func:`atomic_write_json`,
        safe under concurrent fleet writers sharing one store."""
        key = record.content_key()
        record.key = key
        path = self.root / record.signature.dirname / f"{key}.json"
        if path.exists():
            return key
        doc = record.payload()
        doc.update(
            area=record.area,
            wce=record.wce,
            mae=record.mae,
            mse=record.mse,
            source=record.source,
            proxies=record.proxies,
            meta=record.meta,
            key=key,
        )
        atomic_write_json(path, doc)
        return key

    def put_circuit(
        self,
        circuit: Circuit,
        signature: OperatorSignature,
        *,
        area: float,
        source: str = "unknown",
        proxies: dict | None = None,
        params: TemplateParams | None = None,
        meta: dict | None = None,
    ) -> OperatorRecord:
        """Measure a candidate against the exact reference and store it.

        Raises if the candidate violates the signature's error threshold
        *under the signature's own metric* (``wce`` / ``mae`` / ``mse``)
        — the store only ever holds sound operators, and an mae-signed
        record was really validated under mae, not a wce proxy.
        """
        stats = measure_error(circuit, signature.exact_values())
        val = stats.value(signature.error_metric)
        if val > signature.threshold:
            raise ValueError(
                f"unsound operator: measured {signature.error_metric} "
                f"{val:g} > threshold {signature.threshold} for "
                f"{signature.dirname}"
            )
        rec = OperatorRecord(
            signature=signature, circuit=circuit, area=float(area),
            wce=stats.wce, mae=stats.mae, mse=stats.mse, source=source,
            proxies=dict(proxies or {}), params=params, meta=dict(meta or {}),
        )
        self.put(rec)
        return rec

    def sink(self, signature: OperatorSignature, source: str) -> Callable:
        """A callback for :func:`repro.core.search.progressive_search`'s
        ``sink=`` parameter: persists every recorded
        :class:`~repro.core.engine.Candidate` as it is found."""

        def _sink(result) -> None:
            self.put_circuit(
                result.circuit,
                signature,
                area=result.area,
                source=source,
                proxies=getattr(result, "proxies", {}) or {},
                params=getattr(result, "params", None),
                meta={
                    **dict(getattr(result, "meta", {}) or {}),
                    "wall_s": getattr(result, "wall_s", None),
                },
            )

        return _sink

    # ------------------------------------------------------------------- read
    def _load(self, path: Path) -> OperatorRecord:
        doc = json.loads(path.read_text())
        ver = int(doc.get("format_version", -1))
        if ver > FORMAT_VERSION:
            raise ValueError(
                f"{path}: format_version {ver} is newer than supported "
                f"{FORMAT_VERSION}; upgrade the reader"
            )
        s = doc["signature"]
        sig = OperatorSignature(
            s["op_kind"], int(s["bits"]), s["error_metric"], int(s["threshold"])
        )
        return OperatorRecord(
            signature=sig,
            circuit=circuit_from_dict(doc["circuit"]),
            area=float(doc["area"]),
            wce=int(doc["wce"]),
            mae=float(doc["mae"]),
            mse=float(doc.get("mse", -1.0)),
            source=doc.get("source", "unknown"),
            proxies=doc.get("proxies", {}),
            params=_params_from_dict(doc.get("params")),
            meta=doc.get("meta", {}),
            key=doc.get("key", path.stem),
        )

    def signatures(self) -> list[OperatorSignature]:
        out = []
        for d in sorted(self.root.iterdir()):
            if d.is_dir():
                try:
                    out.append(OperatorSignature.from_dirname(d.name))
                except ValueError:
                    continue
        return out

    def get(self, signature: OperatorSignature, key: str) -> OperatorRecord:
        return self._load(self.root / signature.dirname / f"{key}.json")

    def records(self, signature: OperatorSignature) -> list[OperatorRecord]:
        """All records stored under one signature, sorted by (area, wce)."""
        d = self.root / signature.dirname
        recs = [self._load(p) for p in sorted(d.glob("*.json"))] if d.is_dir() else []
        recs.sort(key=lambda r: (r.area, r.wce))
        return recs

    def query(
        self,
        op_kind: str | None = None,
        bits: int | None = None,
        *,
        error_metric: str | None = None,
        max_threshold: int | None = None,
        source: str | None = None,
    ) -> list[OperatorRecord]:
        """All records matching the filters, sorted by (area, wce)."""
        recs: list[OperatorRecord] = []
        for sig in self.signatures():
            if op_kind is not None and sig.op_kind != op_kind:
                continue
            if bits is not None and sig.bits != bits:
                continue
            if error_metric is not None and sig.error_metric != error_metric:
                continue
            if max_threshold is not None and sig.threshold > max_threshold:
                continue
            for rec in self.records(sig):
                if source is None or rec.source == source:
                    recs.append(rec)
        recs.sort(key=lambda r: (r.area, r.wce))
        return recs

    def __len__(self) -> int:
        return sum(
            1
            for sig in self.signatures()
            for _ in (self.root / sig.dirname).glob("*.json")
        )

    def version_token(self) -> str:
        """Cheap fingerprint of the store's *readable* contents.

        Records are content-addressed, so the sorted set of relative
        record paths changes exactly when an operator is added, removed,
        or merged in — no file needs to be opened.  The serving library
        watcher polls this between batches to detect a background fleet
        sweep densifying the store mid-serve; foreign signature dirs the
        reader would skip anyway do not perturb the token.
        """
        h = hashlib.sha256()
        for sig in self.signatures():
            for p in sorted((self.root / sig.dirname).glob("*.json")):
                h.update(f"{sig.dirname}/{p.name}\n".encode())
        return h.hexdigest()[:16]
