"""Runtime QoS selection: per-layer operators under an accuracy budget.

QoS-Nets-style: each model layer may route its matmuls through a
*different* frontier operator.  Degradation is modelled linearly —
``predicted drift of layer l on operator o = sensitivity[l] * mae16(o)``
— with per-layer sensitivities *measured* by probing one layer at a time
(:func:`measure_sensitivities`).  Selection is greedy area-descent:

1. every layer starts on the exact operator (cost 0),
2. repeatedly take the single-layer downgrade with the best
   area-saved-per-predicted-drift ratio,
3. stop at the first step that would exceed the budget.

The stop-at-first-violation rule makes the accepted steps a prefix of a
budget-independent sequence, so a tighter budget can never produce a
*larger* total area (the monotonicity property the tests pin down).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Iterator, Sequence

import numpy as np

from .compile import CompiledLut
from .store import OperatorRecord

__all__ = [
    "LayerChoice",
    "LayerPlan",
    "select_plan",
    "refresh_plan",
    "plan_ladder",
    "plan_layer_areas",
    "validate_lut_stack",
    "measure_layer_costs",
    "measure_sensitivities",
    "stack_luts",
]


@dataclass
class LayerChoice:
    """The operator one layer runs on.  ``key is None`` = exact multiplier."""

    layer: int
    key: str | None
    area: float
    predicted_drift: float = 0.0


@dataclass
class LayerPlan:
    """A full per-layer assignment plus the budget accounting behind it."""

    choices: list[LayerChoice]
    budget: float
    predicted_total: float      # sum of per-layer predicted drifts
    exact_area: float           # area of the exact reference operator

    @property
    def n_layers(self) -> int:
        return len(self.choices)

    @property
    def total_area(self) -> float:
        return float(sum(c.area for c in self.choices))

    @property
    def exact_total_area(self) -> float:
        return self.exact_area * self.n_layers

    @property
    def area_saving(self) -> float:
        tot = self.exact_total_area
        return 1.0 - self.total_area / tot if tot else 0.0

    def operators_used(self) -> dict[str | None, int]:
        out: dict[str | None, int] = {}
        for c in self.choices:
            out[c.key] = out.get(c.key, 0) + 1
        return out

    @property
    def plan_id(self) -> str:
        """Stable short identity of the *assignment* (per-layer operator
        keys only) — two plans that route every layer identically share an
        id even if selected under different budgets.  The serving runtime
        uses it to suppress no-op swaps and label telemetry."""
        blob = ",".join(c.key or "exact" for c in self.choices)
        return hashlib.sha256(blob.encode()).hexdigest()[:10]


def plan_layer_areas(plan: LayerPlan,
                     area_hi_by_key: dict[str, float] | None = None
                     ) -> list[tuple[float, float]]:
    """Per-layer ``(area_lo, area_hi)`` bracket for a plan's choices —
    the pricing the cost plane records into provenance ``plan`` records.

    A choice's own ``area`` is the composed *lower* bound (glue adders
    ignored, see :func:`repro.precision.compose.compose_blocks`);
    ``area_hi_by_key`` maps operator keys to their glue-inclusive upper
    bounds (``CompiledLut.area_hi``).  Exact layers carry the exact
    baseline on both ends, so ``exact_area - area`` prices to a zero
    dividend without special-casing.  Keys missing from the map fall
    back to a collapsed bracket.
    """
    out: list[tuple[float, float]] = []
    for c in plan.choices:
        if c.key is None:
            out.append((plan.exact_area, plan.exact_area))
        else:
            hi = (area_hi_by_key or {}).get(c.key, c.area)
            out.append((float(c.area), float(max(c.area, hi))))
    return out


def _cost_matrix(
    operators: Sequence[tuple[OperatorRecord, CompiledLut]],
    sensitivities: Sequence[float] | np.ndarray,
) -> np.ndarray:
    """Normalize ``sensitivities`` into a per-(layer, operator) cost matrix:
    either a per-layer vector ``(L,)`` of drift per unit mae16 (the cheap
    linear model), or an already-measured ``(L, O)`` matrix."""
    sens = np.asarray(sensitivities, dtype=np.float64)
    assert (sens >= 0).all(), "drift costs must be non-negative"
    if sens.ndim == 1:
        maes = np.array([comp.mae16 for _, comp in operators])
        return sens[:, None] * maes[None, :]           # (L, O) linear model
    if sens.ndim != 2 or sens.shape[1] != len(operators):
        # ValueError (not assert) on purpose: a measured matrix priced
        # against a *stale* frontier reaches here through the serving
        # watcher's refresh path, which must skip the refresh and keep
        # serving rather than die on a background fleet sweep.  (The
        # layer dimension is whatever the caller measured; a wrong layer
        # count surfaces in validate_lut_stack.)
        raise ValueError(
            f"cost matrix is {sens.shape} but the frontier has "
            f"{len(operators)} operator(s); measured matrices must be "
            f"re-priced against a refreshed frontier"
        )
    return sens


def _downgrade_ladders(
    operators: Sequence[tuple[OperatorRecord, CompiledLut]],
    costs: np.ndarray,
    exact_area: float | Sequence[float] | np.ndarray,
    allowed: np.ndarray | None = None,
) -> list[list[tuple[str | None, float, float]]]:
    """Per-layer downgrade ladder: exact first, then cost-ascending operators
    that strictly save area over the previous rung (dominated rungs and
    rungs costlier than a cheaper-area option never help).

    ``exact_area`` may be per-layer: a mixed-width plan anchors each layer
    to the exact multiplier of *that layer's* serving width.  ``allowed``
    is an optional ``(L, O)`` boolean mask restricting which operators a
    layer may run (a frozen width map restricts each layer to operators of
    its own width — see :mod:`repro.precision.plans`)."""
    n_layers = costs.shape[0]
    ex = np.broadcast_to(
        np.asarray(exact_area, dtype=np.float64), (n_layers,))
    ladders: list[list[tuple[str | None, float, float]]] = []
    for l in range(n_layers):
        order = sorted((o for o in range(len(operators))
                        if allowed is None or allowed[l, o]),
                       key=lambda o: (costs[l, o], operators[o][0].area))
        ladder: list[tuple[str | None, float, float]] = [
            (None, float(ex[l]), 0.0)]
        for o in order:
            rec = operators[o][0]
            if rec.area < ladder[-1][1]:
                ladder.append((rec.key, rec.area, float(costs[l, o])))
        ladders.append(ladder)
    return ladders


def _greedy_steps(
    ladders: list[list[tuple[str | None, float, float]]],
) -> Iterator[tuple[int, float]]:
    """The budget-independent greedy descent: yields ``(layer, d_cost)`` for
    each single-layer downgrade in best-area-saved-per-drift order.  Every
    budget's plan is a prefix of this sequence — that shared prefix is both
    the monotonicity invariant and what lets :func:`plan_ladder` place its
    levels on actual descent breakpoints."""
    level = [0] * len(ladders)
    while True:
        best = None  # (ratio, layer) — deterministic tie-break on layer id
        for l, ladder in enumerate(ladders):
            if level[l] + 1 >= len(ladder):
                continue
            _, a_cur, e_cur = ladder[level[l]]
            _, a_nxt, e_nxt = ladder[level[l] + 1]
            d_area = a_cur - a_nxt
            d_cost = e_nxt - e_cur
            ratio = d_area / d_cost if d_cost > 0 else np.inf
            if best is None or ratio > best[0]:
                best = (ratio, l, d_cost)
        if best is None:
            return
        _, l, d_cost = best
        level[l] += 1
        yield l, max(0.0, d_cost)


def select_plan(
    operators: Sequence[tuple[OperatorRecord, CompiledLut]],
    sensitivities: Sequence[float] | np.ndarray,
    budget: float,
    *,
    exact_area: float | Sequence[float] | np.ndarray,
    allowed: np.ndarray | None = None,
) -> LayerPlan:
    """Greedy area-descent over the (layer, operator) lattice.

    ``operators``: frontier operators with their compiled tables (any
    order).  ``sensitivities``: either a per-layer vector ``(L,)`` of
    drift per unit mae16 (the cheap linear model), or a measured cost
    matrix ``(L, len(operators))`` of per-(layer, operator) drifts
    aligned with ``operators`` — LUT errors are biased, so measured
    per-operator costs predict far better than the linear model.
    ``budget``: total predicted drift allowed.  ``exact_area`` may be a
    per-layer vector and ``allowed`` an ``(L, O)`` operator mask (see
    :func:`_downgrade_ladders`).
    """
    costs = _cost_matrix(operators, sensitivities)
    n_layers = costs.shape[0]
    ladders = _downgrade_ladders(operators, costs, exact_area, allowed)

    level = [0] * n_layers
    spent = 0.0
    for l, d_cost in _greedy_steps(ladders):
        if spent + d_cost > budget:
            break  # first violation stops the pass (monotonicity invariant)
        level[l] += 1
        spent += d_cost

    choices = []
    for l in range(n_layers):
        key, a, e = ladders[l][level[l]]
        choices.append(LayerChoice(l, key, a, predicted_drift=e))
    # per-layer exact areas (mixed-width anchors) collapse to their mean so
    # exact_total_area still sums the true per-layer exact baseline
    return LayerPlan(
        choices=choices, budget=float(budget), predicted_total=float(spent),
        exact_area=float(np.mean(np.asarray(exact_area, dtype=np.float64))),
    )


def refresh_plan(
    plan: LayerPlan,
    operators: Sequence[tuple[OperatorRecord, CompiledLut]],
    sensitivities: Sequence[float] | np.ndarray,
    *,
    exact_area: float | Sequence[float] | np.ndarray,
    allowed: np.ndarray | None = None,
) -> LayerPlan:
    """Re-select under ``plan``'s original budget against a refreshed
    frontier — the incremental entry point the serving controller and
    library watcher call when a background fleet sweep densifies the
    store mid-serve.  The budget is carried over verbatim, so repeated
    refreshes keep the area-vs-budget monotonicity of :func:`select_plan`.
    """
    return select_plan(operators, sensitivities, plan.budget,
                       exact_area=exact_area, allowed=allowed)


def plan_ladder(
    operators: Sequence[tuple[OperatorRecord, CompiledLut]],
    sensitivities: Sequence[float] | np.ndarray,
    *,
    exact_area: float | Sequence[float] | np.ndarray,
    levels: int = 6,
    allowed: np.ndarray | None = None,
) -> list[LayerPlan]:
    """A monotone ladder of plans walking the area/accuracy frontier.

    Level 0 is the most accurate plan (budget 0 — only free downgrades),
    the last level is the full greedy descent (every layer on its cheapest
    rung).  Intermediate levels sit on *actual* breakpoints of the greedy
    sequence — cumulative-cost quantiles — so every rung change is a real
    plan change, not an empty budget increment.  Total area is strictly
    decreasing along the ladder; predicted drift is non-decreasing.
    """
    assert levels >= 2, "a ladder spans at least its two endpoints"
    costs = _cost_matrix(operators, sensitivities)
    ladders = _downgrade_ladders(operators, costs, exact_area, allowed)
    cum: list[float] = []
    spent = 0.0
    for _, d_cost in _greedy_steps(ladders):
        spent += d_cost
        cum.append(spent)

    budgets = [0.0]
    if cum:
        # descending linspace so the *last* breakpoint (full descent) is in
        # every ladder, even when levels only leaves one point for it
        idx = sorted({int(round(i))
                      for i in np.linspace(len(cum) - 1, 0,
                                           max(1, levels - 1))})
        for i in idx:
            if cum[i] > budgets[-1]:  # zero-cost runs collapse into one level
                budgets.append(cum[i])
    return [select_plan(operators, sensitivities, b, exact_area=exact_area,
                        allowed=allowed)
            for b in budgets]


def validate_lut_stack(prev, new) -> None:
    """Guard a between-batch hot-swap: the refreshed LUT stack must match
    the live one in shape and dtype, otherwise the jitted decode step would
    silently retrace (or worse, mis-broadcast) instead of reusing its
    compiled executable.  Raises :class:`ValueError` with both signatures.

    Mixed-width serving carries one stack per width group as a
    ``{bits: (n_group, side, side)}`` dict; the group structure is part of
    the traced shapes, so both sides must be dicts over identical widths
    and every group stack must match individually.
    """
    if isinstance(prev, dict) or isinstance(new, dict):
        pw = sorted(prev) if isinstance(prev, dict) else None
        nw = sorted(new) if isinstance(new, dict) else None
        if pw is None or nw is None or pw != nw:
            raise ValueError(
                f"mixed-width stack groups changed: widths {pw} -> {nw}; "
                f"the per-layer width map is frozen for the lifetime of a "
                f"serve (a width-map move needs a restart) — refusing."
            )
        for bits in pw:
            validate_lut_stack(prev[bits], new[bits])
        return
    ps, pd = tuple(prev.shape), prev.dtype
    ns, nd = tuple(new.shape), new.dtype
    if ps != ns or pd != nd:
        def _w(shape):   # best-effort width label for the error message
            side = shape[-1] if shape else 0
            b = max(side, 1).bit_length() - 1
            return f"{b}-bit" if side == 1 << b and side >= 2 else "?"

        raise ValueError(
            f"refreshed LUT stack is {ns}/{nd} ({_w(ns)}) but the serving "
            f"plan runs {ps}/{pd} ({_w(ps)}); a swap would retrace the "
            f"decode step — refusing.  (Did the refreshed frontier change "
            f"operator bit width or layer count?  A width move needs a "
            f"restart with --width, not a hot-swap.)"
        )


def measure_layer_costs(
    eval_drift: Callable[[list[np.ndarray | None]], float],
    n_layers: int,
    operators: Sequence[tuple[OperatorRecord, CompiledLut]],
) -> np.ndarray:
    """Measured ``(L, O)`` drift matrix: operator ``o`` probed at layer
    ``l`` alone.  L*O forwards — exact per-(layer, operator) costs for
    :func:`select_plan`, which matter because biased LUT errors break the
    linear-in-mae16 model badly."""
    costs = np.zeros((n_layers, len(operators)))
    for o, (_, comp) in enumerate(operators):
        for l in range(n_layers):
            luts: list[np.ndarray | None] = [None] * n_layers
            luts[l] = comp.lut
            costs[l, o] = max(0.0, eval_drift(luts))
    return costs


def measure_sensitivities(
    eval_drift: Callable[[list[np.ndarray | None]], float],
    n_layers: int,
    probe: CompiledLut,
) -> np.ndarray:
    """Per-layer drift per unit mae16, by probing one layer at a time.

    ``eval_drift(per_layer_luts)`` runs the model with layer ``l`` routed
    through ``per_layer_luts[l]`` (``None`` = exact) and returns a scalar
    drift against the all-exact baseline.  The probe should be a
    *coarse* operator so the signal is well above noise.
    """
    assert probe.mae16 > 0, "probe operator must be approximate"
    sens = np.zeros(n_layers)
    for l in range(n_layers):
        luts: list[np.ndarray | None] = [None] * n_layers
        luts[l] = probe.lut
        sens[l] = max(0.0, eval_drift(luts)) / probe.mae16
    return sens


def stack_luts(
    plan: LayerPlan,
    records: Sequence[tuple[OperatorRecord, CompiledLut]],
) -> np.ndarray:
    """Materialize a plan as the ``(L, side, side) int32`` array the model
    forward consumes; exact layers get the exact product table.

    The side follows the compiled frontier's target width — a 4-bit
    frontier stacks ``(L, 16, 16)``, an 8-bit (W8A8) one
    ``(L, 256, 256)`` — so a plan can never silently mix widths: every
    compiled table in ``records`` must share one side.
    """
    from ..precision.widths import exact_table

    sides = {comp.lut.shape[-1] for _, comp in records}
    if len(sides) > 1:
        raise ValueError(
            f"frontier mixes LUT sides {sorted(sides)}; a plan stack must "
            f"be single-width"
        )
    side = sides.pop() if sides else 16
    bits = side.bit_length() - 1
    by_key = {rec.key: comp for rec, comp in records}
    exact = exact_table("mul", bits).astype(np.int32)
    out = np.zeros((plan.n_layers, side, side), dtype=np.int32)
    for c in plan.choices:
        out[c.layer] = exact if c.key is None else by_key[c.key].lut
    return out
