"""Runtime QoS selection: per-layer operators under an accuracy budget.

QoS-Nets-style: each model layer may route its matmuls through a
*different* frontier operator.  Degradation is modelled linearly —
``predicted drift of layer l on operator o = sensitivity[l] * mae16(o)``
— with per-layer sensitivities *measured* by probing one layer at a time
(:func:`measure_sensitivities`).  Selection is greedy area-descent:

1. every layer starts on the exact operator (cost 0),
2. repeatedly take the single-layer downgrade with the best
   area-saved-per-predicted-drift ratio,
3. stop at the first step that would exceed the budget.

The stop-at-first-violation rule makes the accepted steps a prefix of a
budget-independent sequence, so a tighter budget can never produce a
*larger* total area (the monotonicity property the tests pin down).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from .compile import CompiledLut, exact_lut16
from .store import OperatorRecord

__all__ = [
    "LayerChoice",
    "LayerPlan",
    "select_plan",
    "measure_layer_costs",
    "measure_sensitivities",
    "stack_luts",
]


@dataclass
class LayerChoice:
    """The operator one layer runs on.  ``key is None`` = exact multiplier."""

    layer: int
    key: str | None
    area: float
    predicted_drift: float = 0.0


@dataclass
class LayerPlan:
    """A full per-layer assignment plus the budget accounting behind it."""

    choices: list[LayerChoice]
    budget: float
    predicted_total: float      # sum of per-layer predicted drifts
    exact_area: float           # area of the exact reference operator

    @property
    def n_layers(self) -> int:
        return len(self.choices)

    @property
    def total_area(self) -> float:
        return float(sum(c.area for c in self.choices))

    @property
    def exact_total_area(self) -> float:
        return self.exact_area * self.n_layers

    @property
    def area_saving(self) -> float:
        tot = self.exact_total_area
        return 1.0 - self.total_area / tot if tot else 0.0

    def operators_used(self) -> dict[str | None, int]:
        out: dict[str | None, int] = {}
        for c in self.choices:
            out[c.key] = out.get(c.key, 0) + 1
        return out


def select_plan(
    operators: Sequence[tuple[OperatorRecord, CompiledLut]],
    sensitivities: Sequence[float] | np.ndarray,
    budget: float,
    *,
    exact_area: float,
) -> LayerPlan:
    """Greedy area-descent over the (layer, operator) lattice.

    ``operators``: frontier operators with their compiled tables (any
    order).  ``sensitivities``: either a per-layer vector ``(L,)`` of
    drift per unit mae16 (the cheap linear model), or a measured cost
    matrix ``(L, len(operators))`` of per-(layer, operator) drifts
    aligned with ``operators`` — LUT errors are biased, so measured
    per-operator costs predict far better than the linear model.
    ``budget``: total predicted drift allowed.
    """
    sens = np.asarray(sensitivities, dtype=np.float64)
    assert (sens >= 0).all(), "drift costs must be non-negative"
    n_layers = sens.shape[0]
    if sens.ndim == 1:
        maes = np.array([comp.mae16 for _, comp in operators])
        costs = sens[:, None] * maes[None, :]          # (L, O) linear model
    else:
        assert sens.shape == (n_layers, len(operators))
        costs = sens

    # per-layer downgrade ladder: exact first, then cost-ascending operators
    # that strictly save area over the previous rung (dominated rungs and
    # rungs costlier than a cheaper-area option never help).
    ladders: list[list[tuple[str | None, float, float]]] = []
    for l in range(n_layers):
        order = sorted(range(len(operators)),
                       key=lambda o: (costs[l, o], operators[o][0].area))
        ladder: list[tuple[str | None, float, float]] = [(None, exact_area, 0.0)]
        for o in order:
            rec = operators[o][0]
            if rec.area < ladder[-1][1]:
                ladder.append((rec.key, rec.area, float(costs[l, o])))
        ladders.append(ladder)

    level = [0] * n_layers
    spent = 0.0
    while True:
        best = None  # (ratio, layer) — deterministic tie-break on layer id
        for l in range(n_layers):
            ladder = ladders[l]
            if level[l] + 1 >= len(ladder):
                continue
            _, a_cur, e_cur = ladder[level[l]]
            _, a_nxt, e_nxt = ladder[level[l] + 1]
            d_area = a_cur - a_nxt
            d_cost = e_nxt - e_cur
            ratio = d_area / d_cost if d_cost > 0 else np.inf
            if best is None or ratio > best[0]:
                best = (ratio, l, d_cost)
        if best is None:
            break
        _, l, d_cost = best
        if spent + d_cost > budget:
            break  # first violation stops the pass (monotonicity invariant)
        level[l] += 1
        spent += d_cost

    choices = []
    for l in range(n_layers):
        key, a, e = ladders[l][level[l]]
        choices.append(LayerChoice(l, key, a, predicted_drift=e))
    return LayerPlan(
        choices=choices, budget=float(budget), predicted_total=float(spent),
        exact_area=float(exact_area),
    )


def measure_layer_costs(
    eval_drift: Callable[[list[np.ndarray | None]], float],
    n_layers: int,
    operators: Sequence[tuple[OperatorRecord, CompiledLut]],
) -> np.ndarray:
    """Measured ``(L, O)`` drift matrix: operator ``o`` probed at layer
    ``l`` alone.  L*O forwards — exact per-(layer, operator) costs for
    :func:`select_plan`, which matter because biased LUT errors break the
    linear-in-mae16 model badly."""
    costs = np.zeros((n_layers, len(operators)))
    for o, (_, comp) in enumerate(operators):
        for l in range(n_layers):
            luts: list[np.ndarray | None] = [None] * n_layers
            luts[l] = comp.lut
            costs[l, o] = max(0.0, eval_drift(luts))
    return costs


def measure_sensitivities(
    eval_drift: Callable[[list[np.ndarray | None]], float],
    n_layers: int,
    probe: CompiledLut,
) -> np.ndarray:
    """Per-layer drift per unit mae16, by probing one layer at a time.

    ``eval_drift(per_layer_luts)`` runs the model with layer ``l`` routed
    through ``per_layer_luts[l]`` (``None`` = exact) and returns a scalar
    drift against the all-exact baseline.  The probe should be a
    *coarse* operator so the signal is well above noise.
    """
    assert probe.mae16 > 0, "probe operator must be approximate"
    sens = np.zeros(n_layers)
    for l in range(n_layers):
        luts: list[np.ndarray | None] = [None] * n_layers
        luts[l] = probe.lut
        sens[l] = max(0.0, eval_drift(luts)) / probe.mae16
    return sens


def stack_luts(
    plan: LayerPlan,
    records: Sequence[tuple[OperatorRecord, CompiledLut]],
) -> np.ndarray:
    """Materialize a plan as the ``(L, 16, 16) int32`` array the model
    forward consumes; exact layers get the exact product table."""
    by_key = {rec.key: comp for rec, comp in records}
    exact = exact_lut16("mul").astype(np.int32)
    out = np.zeros((plan.n_layers, 16, 16), dtype=np.int32)
    for c in plan.choices:
        out[c.layer] = exact if c.key is None else by_key[c.key].lut
    return out
