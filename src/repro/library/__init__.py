"""Approximate-operator library + runtime QoS selection.

The ALS searches (:mod:`repro.core.search`, :mod:`repro.core.baselines`,
:mod:`repro.core.tensor_search`) each emit *many* sound approximations per
run — a Pareto sweep of synthesized area against error threshold (paper
Fig. 4).  This package turns those one-shot, in-process results into a
persistent, serving-grade operator library (AxOSyn's "library of
Pareto-optimal operators" framing, with QoS-Nets-style runtime selection):

* :mod:`repro.library.store` — versioned on-disk artifact store.  Every
  operator (netlist + template params + area + measured error metrics) is
  serialized under a content-addressed key, grouped by operator signature
  ``(op_kind, bits, error_metric, threshold)``.  Searches write through a
  ``sink`` callback; nothing is thrown away between runs.
* :mod:`repro.library.pareto` — dominance filtering and area-vs-error
  frontier queries over stored operators.  This replaces the per-script
  ad-hoc ``report.best`` selection: consumers ask the frontier for "the
  cheapest operator whose error fits my bound".
* :mod:`repro.library.compile` — lowers any stored multiplier/adder to the
  packed ``(16, 16)`` LUT the Pallas ``approx_matmul`` kernel consumes.
  Generalizes :func:`repro.quant.lut.build_lut` beyond 4-bit multipliers:
  sub-4-bit multipliers are tiled recursively (Kulkarni-style 2x2 building
  blocks), adders are carry-ripple-chained, and compiled tables are cached
  in-memory by content key.
* :mod:`repro.library.qos` — per-layer runtime operator selection.  Given
  measured per-layer sensitivities and an accuracy budget, a greedy
  area-descent pass assigns each model layer the smallest operator that
  keeps the predicted degradation within budget, emitting a
  :class:`~repro.library.qos.LayerPlan` whose stacked LUTs route straight
  into the model forward / decode paths.

Wiring: ``repro.core.search`` gains a library sink + CLI (``python -m
repro.core.search --library <dir>``), ``examples/approx_inference.py`` and
``repro.launch.serve`` gain ``--library`` / ``--qos-budget`` flags, and
``repro.launch.analysis`` reports which operator each layer used.
"""

from .pareto import ParetoFrontier, frontier_sizes, pareto_front
from .store import OperatorRecord, OperatorSignature, OperatorStore

# compile/qos pull in the jax kernel stack; they are lazy (PEP 562) so
# CPU-only consumers — fleet fork-pool workers above all — can use the
# store and frontiers without ever importing jax.
_LAZY = {
    "CompiledLut": ".compile",
    "clear_compile_cache": ".compile",
    "compile_circuit": ".compile",
    "compile_record": ".compile",
    "load_mul_frontier": ".compile",
    "LayerPlan": ".qos",
    "measure_layer_costs": ".qos",
    "measure_sensitivities": ".qos",
    "plan_ladder": ".qos",
    "refresh_plan": ".qos",
    "select_plan": ".qos",
    "stack_luts": ".qos",
    "validate_lut_stack": ".qos",
}


def __getattr__(name: str):
    if name in _LAZY:
        from importlib import import_module

        value = getattr(import_module(_LAZY[name], __name__), name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "frontier_sizes",
    "OperatorStore",
    "OperatorRecord",
    "OperatorSignature",
    "ParetoFrontier",
    "pareto_front",
    "CompiledLut",
    "compile_record",
    "compile_circuit",
    "load_mul_frontier",
    "clear_compile_cache",
    "LayerPlan",
    "select_plan",
    "refresh_plan",
    "plan_ladder",
    "validate_lut_stack",
    "measure_layer_costs",
    "measure_sensitivities",
    "stack_luts",
]
