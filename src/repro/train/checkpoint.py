"""Fault-tolerant checkpointing: atomic, digest-verified, resumable.

Layout::

    <dir>/step_<N>/arrays.npz       # flattened param/opt pytree
    <dir>/step_<N>/meta.json        # step, data state, tree structure, crc
    <dir>/LATEST                    # atomically-updated pointer

Protocol (single-writer): write into ``step_<N>.tmp``, fsync, verify the
digest, then ``rename`` — a crashed writer never corrupts the previous
checkpoint, and a restarted job resumes from ``LATEST``.  Arrays are
stored with their *logical* pytree paths, not device layouts, so a restore
under a different mesh (elastic rescale) just re-shards on device_put.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz cannot round-trip ml_dtypes
            arr = arr.astype(np.float32)  # exact upcast; restore downcasts
        flat[key] = arr
    return flat


def _digest(arrays: dict[str, np.ndarray]) -> int:
    crc = 0
    for k in sorted(arrays):
        crc = zlib.crc32(arrays[k].tobytes(), zlib.crc32(k.encode(), crc))
    return crc


def save(directory: str, step: int, params: Any, opt_state: Any,
         data_state: dict | None = None, extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays = {f"params/{k}": v for k, v in _flatten(params).items()}
    arrays.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "data_state": data_state or {},
        "extra": extra or {},
        "crc": _digest(arrays),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> int | None:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    return int(name.split("_")[-1])


def restore(directory: str, params_like: Any, opt_like: Any,
            step: int | None = None) -> tuple[Any, Any, dict, int]:
    """Restore into the *structure* of ``params_like`` / ``opt_like``.

    Device placement / sharding is the caller's concern (device_put with
    the current mesh's shardings — elastic by construction).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    folder = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(folder, "meta.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(folder, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    if _digest(arrays) != meta["crc"]:
        raise IOError(f"checkpoint {folder} failed digest verification")

    def rebuild(prefix: str, like: Any) -> Any:
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat_like:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = arrays[f"{prefix}/{key}"]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            # jnp handles f32 -> bfloat16 (ml_dtypes) casts natively
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves
        )

    return rebuild("params", params_like), rebuild("opt", opt_like), meta, step
