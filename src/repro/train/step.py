"""Jit-compiled train / prefill / decode step builders.

``make_train_step`` is what both the launcher and the dry-run lower:
value_and_grad over the family loss, optional microbatch gradient
accumulation (a ``lax.scan`` over microbatches — decouples global batch
from per-device memory), then the AdamW update.  All functions are pure;
sharding comes from in/out shardings at jit time plus the logical
constraints inside the model code.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models import decode_fn, loss_fn
from ..models.config import ModelConfig
from .optim import OptimizerConfig, apply_updates


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    *,
    microbatches: int = 1,
    remat: str = "full",
    backend: str = "auto",
    scan_unroll: bool = False,
):
    loss = loss_fn(cfg)

    def compute_grads(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(
                lambda p: loss(cfg, p, batch, backend=backend, remat=remat,
                               scan_unroll=scan_unroll)
            )(params)

        def micro(carry, mb):
            acc_loss, acc_grads = carry
            l, g = jax.value_and_grad(
                lambda p: loss(cfg, p, mb, backend=backend, remat=remat,
                               scan_unroll=scan_unroll)
            )(params)
            return (acc_loss + l, jax.tree.map(jnp.add, acc_grads, g)), None

        split = jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
            batch,
        )
        zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        # scan_unroll: the roofline harness must see every microbatch's ops
        # (XLA cost_analysis counts a rolled scan body once)
        (total_loss, total_grads), _ = jax.lax.scan(
            micro, (jnp.float32(0.0), zero_grads), split,
            unroll=True if scan_unroll else 1,
        )
        inv = 1.0 / microbatches
        return total_loss * inv, jax.tree.map(lambda g: g * inv, total_grads)

    def train_step(params, opt_state, batch):
        l, grads = compute_grads(params, batch)
        params, opt_state, metrics = apply_updates(opt_cfg, params, grads, opt_state)
        metrics["loss"] = l
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, backend: str = "auto",
                      scan_unroll: bool = False):
    """Forward-only full-sequence step (inference prefill)."""
    from ..models import forward_fn

    fwd = forward_fn(cfg)

    def prefill(params, batch):
        logits, _ = fwd(cfg, params, batch, backend=backend, remat="none",
                        scan_unroll=scan_unroll)
        return logits[:, -1]  # next-token logits

    return prefill


def make_decode_step(cfg: ModelConfig):
    step = decode_fn(cfg)

    def serve_step(params, caches, tokens, pos):
        return step(cfg, params, caches, tokens, pos)

    return serve_step
