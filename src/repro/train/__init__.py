from .optim import OptimizerConfig, init_opt_state, apply_updates
from .step import make_train_step, make_prefill_step, make_decode_step
from .data import DataState, synth_batch, next_batch
from . import checkpoint

__all__ = [
    "OptimizerConfig",
    "init_opt_state",
    "apply_updates",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "DataState",
    "synth_batch",
    "next_batch",
    "checkpoint",
]
