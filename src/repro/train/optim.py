"""AdamW with decoupled weight decay, cosine schedule, global-norm clipping.

Implemented directly on pytrees (no optax dependency).  Optimizer moments
are f32 regardless of param dtype; under the sharding rules the moments
inherit the parameter specs, so optimizer state is fully sharded
(ZeRO-style) across ``data`` x ``model``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"mu": new_mu, "nu": new_nu, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
