"""Deterministic synthetic data pipeline with checkpointable state.

Batches are a pure function of ``(seed, step)`` — every host can generate
its own shard independently (no data service), restarts are exactly
reproducible, and the pipeline state that must be checkpointed is a single
integer.  Token streams are Zipf-distributed (more realistic softmax/
router statistics than uniform); frames/patches are unit Gaussians.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig


@dataclass
class DataState:
    seed: int
    step: int

    def as_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d: dict) -> "DataState":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


def synth_batch(cfg: ModelConfig, batch: int, seq: int, state: DataState) -> dict:
    """Generate the batch for ``state.step`` (host-side numpy; cheap)."""
    rng = np.random.default_rng((state.seed, state.step))
    # Zipf-ish token distribution, clipped into the vocab
    ranks = rng.zipf(1.2, size=(batch, seq)).astype(np.int64)
    tokens = np.minimum(ranks - 1, cfg.vocab_size - 1).astype(np.int32)
    out = {"tokens": jnp.asarray(tokens)}
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder.n_frames, cfg.d_model), dtype=np.float32)
        )
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.vision.n_patches, cfg.vision.d_vision), dtype=np.float32)
        )
    return out


def next_batch(cfg: ModelConfig, batch: int, seq: int, state: DataState):
    out = synth_batch(cfg, batch, seq, state)
    return out, DataState(state.seed, state.step + 1)
