"""Gate-level combinational circuit IR.

This is the substrate of the ALS engine (Layer A of the framework; see
DESIGN.md §1).  A :class:`Circuit` is a DAG of boolean gates over ``n``
primary inputs with ``m`` primary outputs.  Circuits are small (the paper
targets 2--4 bit arithmetic operators, n <= 8), so the *entire* input space
is enumerable and we evaluate nodes as **bit-packed truth tables**: one
``uint32`` lane holds 32 input assignments, a full truth table for ``n``
inputs is ``ceil(2**n / 32)`` lanes.  All boolean gate evaluation is then
word-wide bitwise arithmetic — the exact same representation the Pallas
``template_eval`` kernel uses on TPU.

The IR is deliberately tiny and explicit: it must round-trip through the
light synthesizer (:mod:`repro.core.synth`), the Z3 miter
(:mod:`repro.core.miter`), and the LUT builder (:mod:`repro.quant.lut`).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Op",
    "Gate",
    "Circuit",
    "input_truth_tables",
    "packed_words",
    "ALL_ONES",
]

ALL_ONES = np.uint32(0xFFFFFFFF)


class Op(enum.Enum):
    """Gate operators.  AND/OR are n-ary at IR level (binarized in synth)."""

    INPUT = "input"
    CONST0 = "const0"
    CONST1 = "const1"
    BUF = "buf"
    NOT = "not"
    AND = "and"
    OR = "or"
    XOR = "xor"
    NAND = "nand"
    NOR = "nor"
    XNOR = "xnor"


@dataclass(frozen=True)
class Gate:
    """A single node: an operator applied to previously-defined node ids."""

    op: Op
    args: tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if self.op is Op.INPUT or self.op in (Op.CONST0, Op.CONST1):
            assert not self.args, f"{self.op} takes no args"
        elif self.op in (Op.NOT, Op.BUF):
            assert len(self.args) == 1, f"{self.op} takes 1 arg"
        elif self.op in (Op.XOR, Op.XNOR):
            assert len(self.args) == 2, f"{self.op} takes 2 args"
        else:
            assert len(self.args) >= 1, f"{self.op} takes >=1 args"


def packed_words(n_inputs: int) -> int:
    """Number of uint32 lanes needed for a full truth table of n inputs."""
    return max(1, (1 << n_inputs) + 31 >> 5)


def input_truth_tables(n_inputs: int) -> np.ndarray:
    """Packed truth tables of the primary inputs, shape ``(n, W)`` uint32.

    Assignment index ``i``'s bit for input ``j`` is ``(i >> j) & 1`` —
    input 0 toggles fastest (LSB of the assignment index).
    """
    size = 1 << n_inputs
    idx = np.arange(size, dtype=np.uint64)
    bits = ((idx[None, :] >> np.arange(n_inputs, dtype=np.uint64)[:, None]) & 1).astype(bool)
    return pack_bits(bits)


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a boolean array (..., S) into uint32 lanes (..., ceil(S/32)).

    Bit ``k`` of lane ``w`` is assignment ``32*w + k``.
    """
    *lead, size = bits.shape
    w = (size + 31) // 32
    padded = np.zeros((*lead, w * 32), dtype=bool)
    padded[..., :size] = bits
    lanes = padded.reshape(*lead, w, 32)
    weights = (np.uint32(1) << np.arange(32, dtype=np.uint32))
    return (lanes.astype(np.uint32) * weights).sum(axis=-1, dtype=np.uint32)


def unpack_bits(words: np.ndarray, size: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`: (..., W) uint32 -> (..., size) bool."""
    *lead, w = words.shape
    shifts = np.arange(32, dtype=np.uint32)
    bits = ((words[..., :, None] >> shifts) & np.uint32(1)).astype(bool)
    return bits.reshape(*lead, w * 32)[..., :size]


@dataclass
class Circuit:
    """A combinational circuit: gates in topological order, outputs by id.

    ``nodes[0:n_inputs]`` are always the INPUT gates, in input order.
    """

    n_inputs: int
    nodes: list[Gate] = field(default_factory=list)
    outputs: list[int] = field(default_factory=list)
    name: str = "circuit"

    # ------------------------------------------------------------------ build
    @classmethod
    def empty(cls, n_inputs: int, name: str = "circuit") -> "Circuit":
        c = cls(n_inputs=n_inputs, name=name)
        c.nodes = [Gate(Op.INPUT) for _ in range(n_inputs)]
        return c

    def add(self, op: Op, *args: int) -> int:
        """Append a gate; returns its node id."""
        for a in args:
            assert 0 <= a < len(self.nodes), f"arg {a} out of range"
        self.nodes.append(Gate(op, tuple(args)))
        return len(self.nodes) - 1

    def const(self, value: bool) -> int:
        return self.add(Op.CONST1 if value else Op.CONST0)

    def mark_output(self, node_id: int) -> None:
        self.outputs.append(node_id)

    # ------------------------------------------------------------- properties
    @property
    def n_outputs(self) -> int:
        return len(self.outputs)

    def gate_count(self, *, logic_only: bool = True) -> int:
        """Number of gates (excluding inputs; optionally excluding const/buf)."""
        skip = {Op.INPUT}
        if logic_only:
            skip |= {Op.CONST0, Op.CONST1, Op.BUF}
        return sum(1 for g in self.nodes if g.op not in skip)

    # ------------------------------------------------------------- evaluation
    def node_tables(self, in_tables: np.ndarray | None = None) -> np.ndarray:
        """Packed truth tables for every node, shape ``(len(nodes), W)``.

        ``in_tables``: optional ``(n_inputs, W)`` packed input patterns
        (defaults to the full enumeration).  Evaluation is a single
        topological sweep of word-wide bitwise ops.
        """
        if in_tables is None:
            in_tables = input_truth_tables(self.n_inputs)
        n, w = in_tables.shape
        assert n == self.n_inputs
        out = np.zeros((len(self.nodes), w), dtype=np.uint32)
        n_seen = 0
        for i, g in enumerate(self.nodes):
            if g.op is Op.INPUT:
                out[i] = in_tables[n_seen]
                n_seen += 1
            elif g.op is Op.CONST0:
                out[i] = 0
            elif g.op is Op.CONST1:
                out[i] = ALL_ONES
            elif g.op is Op.BUF:
                out[i] = out[g.args[0]]
            elif g.op is Op.NOT:
                out[i] = ~out[g.args[0]]
            elif g.op is Op.AND:
                acc = out[g.args[0]].copy()
                for a in g.args[1:]:
                    acc &= out[a]
                out[i] = acc
            elif g.op is Op.OR:
                acc = out[g.args[0]].copy()
                for a in g.args[1:]:
                    acc |= out[a]
                out[i] = acc
            elif g.op is Op.NAND:
                acc = out[g.args[0]].copy()
                for a in g.args[1:]:
                    acc &= out[a]
                out[i] = ~acc
            elif g.op is Op.NOR:
                acc = out[g.args[0]].copy()
                for a in g.args[1:]:
                    acc |= out[a]
                out[i] = ~acc
            elif g.op is Op.XOR:
                out[i] = out[g.args[0]] ^ out[g.args[1]]
            elif g.op is Op.XNOR:
                out[i] = ~(out[g.args[0]] ^ out[g.args[1]])
            else:  # pragma: no cover - exhaustive
                raise ValueError(f"unknown op {g.op}")
        return out

    def output_tables(self, in_tables: np.ndarray | None = None) -> np.ndarray:
        """Packed truth tables of the outputs only, shape ``(m, W)``."""
        tables = self.node_tables(in_tables)
        return tables[np.asarray(self.outputs, dtype=np.int64)]

    def eval_words(self) -> np.ndarray:
        """Output *values* per assignment: ``(2**n,)`` uint64.

        ``map`` of the paper's miter: outputs interpreted as an unsigned
        integer, output 0 = LSB.
        """
        bits = unpack_bits(self.output_tables(), 1 << self.n_inputs)  # (m, S)
        weights = np.uint64(1) << np.arange(self.n_outputs, dtype=np.uint64)
        return (bits.astype(np.uint64) * weights[:, None]).sum(axis=0)

    def eval_assignment(self, values: Sequence[int]) -> int:
        """Evaluate a single input assignment (list of 0/1) -> unsigned int."""
        assert len(values) == self.n_inputs
        idx = sum(int(v) << j for j, v in enumerate(values))
        return int(self.eval_words()[idx])

    # ------------------------------------------------------------------ utils
    def fanout_counts(self) -> np.ndarray:
        counts = np.zeros(len(self.nodes), dtype=np.int64)
        for g in self.nodes:
            for a in g.args:
                counts[a] += 1
        for o in self.outputs:
            counts[o] += 1
        return counts

    def live_nodes(self) -> np.ndarray:
        """Boolean mask of nodes reachable from the outputs (or inputs)."""
        live = np.zeros(len(self.nodes), dtype=bool)
        stack = list(self.outputs)
        while stack:
            i = stack.pop()
            if live[i]:
                continue
            live[i] = True
            stack.extend(self.nodes[i].args)
        live[: self.n_inputs] = True  # inputs are part of the interface
        return live

    def to_pretty(self) -> str:
        """Human-readable netlist dump (Verilog-ish), for docs/debugging."""
        lines = [f"// circuit {self.name}: {self.n_inputs} in, {self.n_outputs} out"]
        for i, g in enumerate(self.nodes):
            if g.op is Op.INPUT:
                lines.append(f"n{i} = input[{i}]")
            elif g.op in (Op.CONST0, Op.CONST1):
                lines.append(f"n{i} = {0 if g.op is Op.CONST0 else 1}")
            else:
                args = ", ".join(f"n{a}" for a in g.args)
                lines.append(f"n{i} = {g.op.value}({args})")
        for k, o in enumerate(self.outputs):
            lines.append(f"out[{k}] = n{o}")
        return "\n".join(lines)


def check_topological(circuit: Circuit) -> bool:
    """All gate args refer to earlier nodes (the IR invariant)."""
    return all(
        all(a < i for a in g.args) for i, g in enumerate(circuit.nodes)
    )
