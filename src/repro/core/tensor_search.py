"""Beyond-paper: tensorized population search for the SHARED template.

The paper drives a *sequential* SMT solver through a proxy-ordered grid.
This module re-expresses the same exploration as a data-parallel tensor
program (DESIGN.md §4): a population of candidate parameter assignments is
scored against the *entire* input space in one fused evaluation
(:func:`repro.kernels.ops.template_eval` — VPU boolean algebra over
bit-packed truth tables), then evolved with elitist mutation.  On a TPU
mesh the population axis shards over ``data`` — the search scales to
thousands of chips with zero coordination beyond one all-gather of elites
per generation.

Fitness mirrors the paper's proxy logic: sound candidates are ranked by an
(area-proxy) score built from PIT / ITS / literal counts; unsound ones by
their ET violation.  Final winners are *re-verified exhaustively* and
synthesized for true area.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .circuits import Circuit, input_truth_tables
from .engine import SearchOutcome, harvest
from .templates import IGNORE, SharedTemplate, TemplateParams

__all__ = ["tensor_search"]


def _proxy_score(lits: jax.Array, sel: jax.Array) -> jax.Array:
    """Differentiable-in-spirit area proxy per candidate.

    ``PIT``-weighted + literal count + sum fan-in: the quantities the paper
    shows correlate with synthesized area (§III / Fig. 4).
    """
    used_prod = (sel > 0).any(axis=1)                      # (P, T)
    lit_cnt = ((lits != IGNORE) & used_prod[:, :, None]).sum((1, 2))
    pit = used_prod.sum(axis=1)
    its = (sel > 0).sum(axis=2).max(axis=1)
    return 10.0 * pit + 2.0 * lit_cnt + 3.0 * its


def tensor_search(
    exact: Circuit,
    et: int,
    *,
    pit: int | None = None,
    population: int = 4096,
    generations: int = 60,
    elites: int = 64,
    seed: int = 0,
    keep: int = 16,
    seeds: list[TemplateParams] | None = None,
    wall_budget_s: float | None = None,
    mesh: jax.sharding.Mesh | None = None,
) -> SearchOutcome:
    """Evolve shared-template parameters toward minimal-area sound circuits.

    ``seeds``: optional known-good parameter assignments (e.g. from a loose
    SMT query) injected into the initial population — the hybrid
    SMT-feasible / tensor-minimize mode (DESIGN.md §4).

    ``mesh``: optional jax mesh with a ``data`` axis (e.g.
    :func:`repro.launch.mesh.make_fleet_mesh`).  The population axis is
    sharded over it, so one fleet worker drives every local device; the
    per-generation elite argsort is the only cross-shard collective.
    """
    n, m = exact.n_inputs, exact.n_outputs
    T = pit if pit is not None else 2 * m
    tpl = SharedTemplate(n, m, pit=T)
    pop_sharding = None
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec

        n_shards = mesh.shape["data"]
        # population must tile evenly over the data axis; round up
        population += (-population) % n_shards
        pop_sharding = NamedSharding(mesh, PartitionSpec("data"))
    in_tt = jnp.asarray(input_truth_tables(n))
    exact_vals = jnp.asarray(exact.eval_words().astype(np.int32))
    key = jax.random.PRNGKey(seed)
    t0 = time.time()

    BIG = jnp.float32(1e6)

    @jax.jit
    def fitness(lits, sel):
        wce, esum = ops.template_eval(lits, sel, in_tt, exact_vals)
        sound = wce <= et
        score = _proxy_score(lits, sel)
        # unsound candidates are ranked by violation magnitude: the total
        # error gives a smooth descent direction the worst-case alone lacks
        violation = BIG + 100.0 * wce.astype(jnp.float32) + esum.astype(jnp.float32)
        return jnp.where(sound, score, violation), wce

    @jax.jit
    def step(key, lits, sel):
        fit, _ = fitness(lits, sel)
        order = jnp.argsort(fit)
        elite_lits = lits[order[:elites]]
        elite_sel = sel[order[:elites]]
        # children: mutate random elites
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        parent = jax.random.randint(k1, (population - elites,), 0, elites)
        c_lits = elite_lits[parent]
        c_sel = elite_sel[parent]
        mut_l = jax.random.bernoulli(k2, 0.04, c_lits.shape)
        new_l = jax.random.randint(k3, c_lits.shape, 0, 3)
        c_lits = jnp.where(mut_l, new_l, c_lits)
        mut_s = jax.random.bernoulli(k4, 0.04, c_sel.shape)
        c_sel = jnp.where(mut_s, 1 - c_sel, c_sel)
        lits = jnp.concatenate([elite_lits, c_lits])
        sel = jnp.concatenate([elite_sel, c_sel])
        if pop_sharding is not None:  # keep the population sharded over data
            lits = jax.lax.with_sharding_constraint(lits, pop_sharding)
            sel = jax.lax.with_sharding_constraint(sel, pop_sharding)
        return k5, lits, sel

    # init population: IGNORE-biased literals (small products are the useful
    # building blocks) and sparse selection (low starting proxies)
    k0, k1, key = jax.random.split(key, 3)
    u = jax.random.uniform(k0, (population, T, n))
    lits = jnp.where(u < 0.25, 0, jnp.where(u < 0.5, 1, 2))  # USE/NEG/IGNORE
    sel = (jax.random.uniform(k1, (population, m, T)) < 0.3).astype(jnp.int32)
    if seeds:
        # tile each seed over a slab of the population (mutation diversifies)
        slab = max(1, population // (4 * len(seeds)))
        row = 0
        for sp in seeds:
            s_lits = np.full((T, n), IGNORE, dtype=np.int32)
            s_sel = np.zeros((m, T), dtype=np.int32)
            t_src = min(sp.lits.shape[0], T)
            s_lits[:t_src] = sp.lits[:t_src]
            s_sel[:, :t_src] = sp.sel[:, :t_src]
            end = min(population, row + slab)
            lits = lits.at[row:end].set(jnp.asarray(s_lits)[None])
            sel = sel.at[row:end].set(jnp.asarray(s_sel)[None])
            row = end
    if pop_sharding is not None:
        lits = jax.device_put(lits, pop_sharding)
        sel = jax.device_put(sel, pop_sharding)

    outcome = SearchOutcome(engine="tensor", benchmark=exact.name, et=et,
                            stats={"generations": 0, "evaluations": 0})
    for g in range(generations):
        if wall_budget_s is not None and time.time() - t0 > wall_budget_s:
            break
        key, lits, sel = step(key, lits, sel)
        outcome.stats["generations"] += 1
        outcome.stats["evaluations"] += population

    # harvest: exhaustively re-verify + synthesize the distinct elites.
    # harvest() raises a descriptive UnsoundResultError if the synthesized
    # netlist disagrees with the template-eval fitness (a kernel bug) —
    # fleet workers report the failing job instead of dying on an assert.
    fit, wce = fitness(lits, sel)
    order = np.asarray(jnp.argsort(fit))
    exact_np = exact.eval_words()
    seen: set[bytes] = set()
    for idx in order:
        if len(outcome.results) >= keep or float(fit[idx]) >= float(BIG):
            break
        p = TemplateParams(
            np.asarray(lits[idx], dtype=np.int8), np.asarray(sel[idx]).astype(bool)
        )
        fingerprint = p.lits.tobytes() + p.sel.tobytes()
        if fingerprint in seen:
            continue
        seen.add(fingerprint)
        outcome.results.append(
            harvest(tpl, p, exact_np, et, engine="tensor",
                    name=f"{exact.name}_tensor", wall_s=time.time() - t0,
                    meta={"fitness": float(fit[idx])})
        )
    outcome.wall_s = time.time() - t0
    return outcome
