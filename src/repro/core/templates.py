"""Parametrisable sum-of-products templates — the paper's contribution.

Two templates (paper §II):

* :class:`NonsharedTemplate` — the original XPAT template (Eq. 1).  Every
  output ``i`` owns a *private* bank of ``K`` products; a literal selector
  ``p_k^j ∈ {USE, NEG, IGNORE}`` per (product, input) decides whether input
  ``j`` enters product ``k`` as-is, negated, or not at all (constant 1), and
  an include bit per (output, product) decides whether the product feeds the
  sum (an all-excluded sum is constant 0).

* :class:`SharedTemplate` — the paper's template (Eq. 2).  A single *global*
  pool of ``T`` products; per-(output, product) selection bits ``s_i^t``
  decide which pooled products feed each output sum, so product logic is
  **shared** across outputs exactly as a synthesized multi-output netlist
  shares subexpressions.

Parameter encoding (identical for JAX / numpy / Z3 backends):

* ``lits``: int8 array, ``USE=0 / NEG=1 / IGNORE=2``.
  - nonshared shape ``(m, K, n)``; shared shape ``(T, n)``.
* ``sel``: bool array of sum membership.
  - nonshared shape ``(m, K)``; shared shape ``(m, T)``.

The *proxies* (paper §III):

* nonshared: ``LPP``  = max literals in any product,
             ``PPO``  = max products included in any output sum.
* shared:    ``PIT``  = products used by >= 1 output (products in total),
             ``ITS``  = max products feeding any single sum (inputs to sums).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .circuits import ALL_ONES, Circuit, Op, input_truth_tables

USE, NEG, IGNORE = 0, 1, 2

__all__ = [
    "USE",
    "NEG",
    "IGNORE",
    "TemplateParams",
    "NonsharedTemplate",
    "SharedTemplate",
]


@dataclass
class TemplateParams:
    """A concrete parameter assignment for either template."""

    lits: np.ndarray  # int8, {USE, NEG, IGNORE}
    sel: np.ndarray   # bool

    def copy(self) -> "TemplateParams":
        return TemplateParams(self.lits.copy(), self.sel.copy())


class _TemplateBase:
    n_inputs: int
    n_outputs: int

    # -- API ---------------------------------------------------------------
    def eval_outputs(self, params: TemplateParams) -> np.ndarray:
        """Packed output truth tables ``(m, W)`` for a parameter assignment."""
        raise NotImplementedError

    def instantiate(self, params: TemplateParams, name: str = "approx") -> Circuit:
        """Materialize the parameter assignment as a gate netlist."""
        raise NotImplementedError

    def proxies(self, params: TemplateParams) -> dict[str, int]:
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------
    def _product_tables(self, lits: np.ndarray) -> np.ndarray:
        """Truth tables of products.  ``lits``: (..., n) -> tables (..., W)."""
        tt = input_truth_tables(self.n_inputs)  # (n, W)
        use = np.where(lits[..., None] == USE, tt, ALL_ONES)
        neg = np.where(lits[..., None] == NEG, ~tt, ALL_ONES)
        # AND over inputs of (use-term & neg-term); IGNORE contributes all-ones
        comb = use & neg  # broadcasting: (..., n, W)
        out = comb[..., 0, :].copy()
        for j in range(1, self.n_inputs):
            out &= comb[..., j, :]
        return out

    def _emit_product(self, c: Circuit, lit_row: np.ndarray) -> int | None:
        """Emit AND-of-literals for one product; None => constant-1 product."""
        terms: list[int] = []
        for j in range(self.n_inputs):
            if lit_row[j] == USE:
                terms.append(j)
            elif lit_row[j] == NEG:
                terms.append(c.add(Op.NOT, j))
        if not terms:
            return None
        if len(terms) == 1:
            return terms[0]
        return c.add(Op.AND, *terms)

    @staticmethod
    def _emit_sum(c: Circuit, terms: list[int | None]) -> int:
        """OR of product nodes; None (const-1 product) saturates the sum."""
        if any(t is None for t in terms):
            return c.const(True)
        ids = [t for t in terms if t is not None]
        if not ids:
            return c.const(False)
        if len(ids) == 1:
            return ids[0]
        return c.add(Op.OR, *ids)


class NonsharedTemplate(_TemplateBase):
    """XPAT's original template: per-output private product banks (Eq. 1)."""

    def __init__(self, n_inputs: int, n_outputs: int, ppo: int):
        self.n_inputs = n_inputs
        self.n_outputs = n_outputs
        self.ppo = ppo  # K: structural products per output

    # parameters: lits (m, K, n), sel (m, K)
    def random_params(self, rng: np.random.Generator) -> TemplateParams:
        lits = rng.integers(0, 3, size=(self.n_outputs, self.ppo, self.n_inputs), dtype=np.int8)
        sel = rng.random((self.n_outputs, self.ppo)) < 0.5
        return TemplateParams(lits, sel)

    def eval_outputs(self, params: TemplateParams) -> np.ndarray:
        prods = self._product_tables(params.lits)  # (m, K, W)
        masked = np.where(params.sel[..., None], prods, np.uint32(0))
        out = masked[:, 0, :].copy()
        for k in range(1, self.ppo):
            out |= masked[:, k, :]
        return out

    def instantiate(self, params: TemplateParams, name: str = "approx") -> Circuit:
        c = Circuit.empty(self.n_inputs, name=name)
        for i in range(self.n_outputs):
            terms = [
                self._emit_product(c, params.lits[i, k])
                for k in range(self.ppo)
                if params.sel[i, k]
            ]
            c.mark_output(self._emit_sum(c, terms))
        return c

    def proxies(self, params: TemplateParams) -> dict[str, int]:
        used_lits = (params.lits != IGNORE) & params.sel[..., None]
        lpp = int(used_lits.sum(axis=-1).max(initial=0))
        ppo = int(params.sel.sum(axis=-1).max(initial=0))
        return {"LPP": lpp, "PPO": ppo}


class SharedTemplate(_TemplateBase):
    """The paper's shared template: one global product pool (Eq. 2)."""

    def __init__(self, n_inputs: int, n_outputs: int, pit: int):
        self.n_inputs = n_inputs
        self.n_outputs = n_outputs
        self.pit = pit  # T: structural size of the product pool

    # parameters: lits (T, n), sel (m, T)
    def random_params(self, rng: np.random.Generator) -> TemplateParams:
        lits = rng.integers(0, 3, size=(self.pit, self.n_inputs), dtype=np.int8)
        sel = rng.random((self.n_outputs, self.pit)) < 0.5
        return TemplateParams(lits, sel)

    def eval_outputs(self, params: TemplateParams) -> np.ndarray:
        prods = self._product_tables(params.lits)  # (T, W)
        masked = np.where(params.sel[..., None], prods[None, :, :], np.uint32(0))
        out = masked[:, 0, :].copy()
        for t in range(1, self.pit):
            out |= masked[:, t, :]
        return out

    def instantiate(self, params: TemplateParams, name: str = "approx") -> Circuit:
        c = Circuit.empty(self.n_inputs, name=name)
        used = params.sel.any(axis=0)  # (T,) — only materialize used products
        prod_nodes: dict[int, int | None] = {}
        for t in range(self.pit):
            if used[t]:
                prod_nodes[t] = self._emit_product(c, params.lits[t])
        for i in range(self.n_outputs):
            terms = [prod_nodes[t] for t in range(self.pit) if params.sel[i, t]]
            c.mark_output(self._emit_sum(c, terms))
        return c

    def proxies(self, params: TemplateParams) -> dict[str, int]:
        used = params.sel.any(axis=0)
        pit = int(used.sum())
        its = int(params.sel.sum(axis=-1).max(initial=0))
        return {"PIT": pit, "ITS": its}
