"""Comparison baselines (paper §IV).

The paper compares SHARED against XPAT (nonshared template — implemented
natively in :mod:`repro.core.search`), MUSCAT, MECALS and a cloud of random
sound approximations.  MUSCAT and MECALS are separate toolchains; per
DESIGN.md §3 we re-implement their *mechanisms* against our own exhaustive
miter, so the comparison is apples-to-apples on soundness:

* :func:`muscat_like` — MUSCAT prunes circuit structure under an error
  bound (MUS-guided gate removal).  We implement greedy iterative gate
  *constant-substitution* (each gate tried at 0 and at 1) with multiple
  randomized orders, accepting any substitution that keeps the circuit
  sound and lowers synthesized area.
* :func:`mecals_like` — MECALS uses an error miter + SAT to verify local
  rewrites.  We implement *wire-substitution* (SASIMI-style): replace a
  gate's output with another existing signal or its negation when sound.
* :func:`random_sound` — the red-dot cloud: uniformly random template
  instantiations filtered for soundness.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from .circuits import Circuit, Gate, Op
from .miter import values_from_tables, worst_case_error
from .synth import area, synthesize
from .templates import SharedTemplate, TemplateParams

__all__ = ["muscat_like", "mecals_like", "random_sound", "BaselineResult"]


@dataclass
class BaselineResult:
    circuit: Circuit
    area: float
    wce: int
    wall_s: float


def _with_const(circuit: Circuit, node: int, value: bool) -> Circuit:
    c = Circuit(
        n_inputs=circuit.n_inputs,
        nodes=list(circuit.nodes),
        outputs=list(circuit.outputs),
        name=circuit.name,
    )
    c.nodes[node] = Gate(Op.CONST1 if value else Op.CONST0)
    return c


def _wce(circuit: Circuit, exact_values: np.ndarray) -> int:
    vals = circuit.eval_words().astype(np.int64)
    return int(np.abs(vals - exact_values.astype(np.int64)).max())


def muscat_like(
    exact: Circuit,
    et: int,
    *,
    restarts: int = 4,
    seed: int = 0,
    wall_budget_s: float = 120.0,
) -> BaselineResult:
    """Greedy sound gate-to-constant pruning with randomized restarts."""
    exact_values = exact.eval_words()
    t0 = time.time()
    rng = np.random.default_rng(seed)
    best = synthesize(exact)
    best_area = area(best, presynthesized=True)

    for _ in range(restarts):
        cur = Circuit(
            n_inputs=exact.n_inputs,
            nodes=list(exact.nodes),
            outputs=list(exact.outputs),
            name=f"{exact.name}_muscat",
        )
        improved = True
        while improved and time.time() - t0 < wall_budget_s:
            improved = False
            order = rng.permutation(np.arange(exact.n_inputs, len(cur.nodes)))
            for node in order:
                if cur.nodes[node].op in (Op.CONST0, Op.CONST1, Op.INPUT):
                    continue
                for value in (False, True):
                    cand = _with_const(cur, int(node), value)
                    if _wce(cand, exact_values) <= et:
                        cur = cand
                        improved = True
                        break
        syn = synthesize(cur)
        a = area(syn, presynthesized=True)
        if a < best_area:
            best, best_area = syn, a

    return BaselineResult(best, best_area, _wce(best, exact_values), time.time() - t0)


def mecals_like(
    exact: Circuit,
    et: int,
    *,
    seed: int = 0,
    wall_budget_s: float = 120.0,
) -> BaselineResult:
    """Sound wire-substitution (replace gate output by existing signal /
    its negation / a constant), greedy on synthesized area."""
    exact_values = exact.eval_words()
    t0 = time.time()
    cur = Circuit(
        n_inputs=exact.n_inputs,
        nodes=list(exact.nodes),
        outputs=list(exact.outputs),
        name=f"{exact.name}_mecals",
    )
    rng = np.random.default_rng(seed)

    def try_substitutions() -> bool:
        tables = cur.node_tables()
        n_nodes = len(cur.nodes)
        # candidate pairs ranked by truth-table Hamming similarity
        order = rng.permutation(np.arange(cur.n_inputs, n_nodes))
        for node in order:
            if cur.nodes[node].op in (Op.CONST0, Op.CONST1, Op.INPUT):
                continue
            tt = tables[node]
            # try constants first (cheapest), then similar earlier signals
            for value in (False, True):
                cand = _with_const(cur, int(node), value)
                if _wce(cand, exact_values) <= et:
                    _commit(cand)
                    return True
            for other in range(int(node)):
                if other == node:
                    continue
                same = tt == tables[other]
                if bool(same.all()):
                    continue  # identical — structural hashing handles it
                for negate in (False, True):
                    cand = Circuit(
                        n_inputs=cur.n_inputs,
                        nodes=list(cur.nodes),
                        outputs=list(cur.outputs),
                        name=cur.name,
                    )
                    if negate:
                        cand.nodes[int(node)] = Gate(Op.NOT, (other,))
                    else:
                        cand.nodes[int(node)] = Gate(Op.BUF, (other,))
                    if _wce(cand, exact_values) <= et:
                        before = area(cur)
                        if area(cand) < before:
                            _commit(cand)
                            return True
            if time.time() - t0 > wall_budget_s:
                return False
        return False

    committed = {"c": cur}

    def _commit(cand: Circuit) -> None:
        committed["c"] = cand

    while time.time() - t0 < wall_budget_s:
        cur = committed["c"]
        if not try_substitutions():
            break
    cur = synthesize(committed["c"])
    return BaselineResult(
        cur, area(cur, presynthesized=True), _wce(cur, exact_values), time.time() - t0
    )


def random_sound(
    exact: Circuit,
    et: int,
    *,
    count: int = 1000,
    pit: int | None = None,
    batch: int = 4096,
    max_batches: int = 200,
    seed: int = 0,
) -> list[tuple[float, dict[str, int]]]:
    """Sample random shared-template instantiations, keep the sound ones.

    Returns ``[(synthesized_area, proxies), ...]`` — the paper's red-dot
    cloud.  Vectorized over the whole batch via the template's bit-packed
    evaluation, so filtering is cheap even at low hit rates.
    """
    n, m = exact.n_inputs, exact.n_outputs
    tpl = SharedTemplate(n, m, pit=pit if pit is not None else 2 * m)
    exact_values = exact.eval_words().astype(np.int64)
    rng = np.random.default_rng(seed)
    kept: list[tuple[float, dict[str, int]]] = []

    for _ in range(max_batches):
        if len(kept) >= count:
            break
        lits = rng.integers(0, 3, size=(batch, tpl.pit, n), dtype=np.int8)
        sel = rng.random((batch, m, tpl.pit)) < rng.uniform(0.2, 0.6)
        # vectorized eval: products (batch, T, W) -> outputs (batch, m, W)
        prods = tpl._product_tables(lits)
        masked = np.where(sel[..., None], prods[:, None, :, :], np.uint32(0))
        outs = masked[:, :, 0, :].copy()
        for t in range(1, tpl.pit):
            outs |= masked[:, :, t, :]
        # values per assignment
        from .circuits import unpack_bits

        bits = unpack_bits(outs, 1 << n)  # (batch, m, S)
        weights = (np.int64(1) << np.arange(m, dtype=np.int64))[None, :, None]
        vals = (bits.astype(np.int64) * weights).sum(axis=1)  # (batch, S)
        wce = np.abs(vals - exact_values[None, :]).max(axis=1)
        for idx in np.nonzero(wce <= et)[0]:
            if len(kept) >= count:
                break
            p = TemplateParams(lits[idx], sel[idx])
            circ = tpl.instantiate(p)
            kept.append((area(circ), tpl.proxies(p)))
    return kept
