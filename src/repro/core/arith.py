"""Exact arithmetic circuit generators — the paper's benchmark set.

The paper evaluates on Verilog specs of small adders and multipliers with
operand bitwidths 2, 3 and 4, named by *total input count*: ``i4`` (2-bit),
``i6`` (3-bit), ``i8`` (4-bit).  We generate the canonical structures:

* ripple-carry adder (half adder + chain of full adders),
* array multiplier (AND partial products + ripple reduction rows),

both as :class:`~repro.core.circuits.Circuit` DAGs.  Input layout is
``[a_0..a_{b-1}, b_0..b_{b-1}]`` LSB-first; outputs LSB-first
(``b+1`` sum bits for adders, ``2b`` product bits for multipliers).
"""

from __future__ import annotations

import numpy as np

from .circuits import Circuit, Op

__all__ = [
    "ripple_carry_adder",
    "array_multiplier",
    "benchmark",
    "parse_benchmark_name",
    "BENCHMARKS",
]


def _half_adder(c: Circuit, a: int, b: int) -> tuple[int, int]:
    """Returns (sum, carry)."""
    s = c.add(Op.XOR, a, b)
    cy = c.add(Op.AND, a, b)
    return s, cy


def _full_adder(c: Circuit, a: int, b: int, cin: int) -> tuple[int, int]:
    """Returns (sum, carry) — the standard 2-XOR 2-AND 1-OR decomposition."""
    axb = c.add(Op.XOR, a, b)
    s = c.add(Op.XOR, axb, cin)
    t1 = c.add(Op.AND, axb, cin)
    t2 = c.add(Op.AND, a, b)
    cy = c.add(Op.OR, t1, t2)
    return s, cy


def ripple_carry_adder(bits: int) -> Circuit:
    """``bits``-bit + ``bits``-bit -> ``bits+1``-bit ripple-carry adder."""
    c = Circuit.empty(2 * bits, name=f"adder_i{2 * bits}")
    a = list(range(bits))
    b = list(range(bits, 2 * bits))
    s, carry = _half_adder(c, a[0], b[0])
    c.mark_output(s)
    for k in range(1, bits):
        s, carry = _full_adder(c, a[k], b[k], carry)
        c.mark_output(s)
    c.mark_output(carry)
    return c


def array_multiplier(bits: int) -> Circuit:
    """``bits``x``bits`` -> ``2*bits``-bit array multiplier.

    Row-by-row carry-save style reduction: partial-product row ``r`` is
    added into the running sum with a ripple of half/full adders — the
    classic array multiplier a synthesis flow would start from.
    """
    c = Circuit.empty(2 * bits, name=f"mul_i{2 * bits}")
    a = list(range(bits))
    b = list(range(bits, 2 * bits))

    # partial products pp[r][j] = a_j AND b_r
    pp = [[c.add(Op.AND, a[j], b[r]) for j in range(bits)] for r in range(bits)]

    # running sum starts as row 0 (weight offset 0)
    acc: list[int] = list(pp[0])  # acc[k] has weight 2**k
    c.mark_output(acc[0])  # out bit 0 is final
    acc = acc[1:]  # weights 2**1 .. 2**(bits-1)

    for r in range(1, bits):
        row = pp[r]  # weights 2**r .. 2**(r+bits-1); acc holds 2**r ..
        new_acc: list[int] = []
        carry: int | None = None
        for j in range(bits):
            have_acc = j < len(acc)
            terms = [row[j]]
            if have_acc:
                terms.append(acc[j])
            if carry is not None:
                terms.append(carry)
            if len(terms) == 1:
                s, carry = terms[0], None
            elif len(terms) == 2:
                s, carry = _half_adder(c, terms[0], terms[1])
            else:
                s, carry = _full_adder(c, terms[0], terms[1], terms[2])
            new_acc.append(s)
        if carry is not None:
            new_acc.append(carry)
        # lowest bit of new_acc has weight 2**r -> it is final output bit r
        c.mark_output(new_acc[0])
        acc = new_acc[1:]

    for s in acc:  # remaining high bits
        c.mark_output(s)
    assert c.n_outputs == 2 * bits, (c.n_outputs, bits)
    return c


def parse_benchmark_name(name: str) -> tuple[str, int]:
    """``"mul_i8" -> ("mul", 4)``: benchmark name to (kind, operand bits).

    The single parser for every consumer (benchmark(), the search CLI's
    store signatures, fig5) — the naming scheme must not diverge between
    the circuit searched and the signature it is stored under.
    """
    try:
        kind, size = name.split("_i")
        bits = int(size) // 2
    except ValueError:
        raise KeyError(name) from None
    if kind not in ("adder", "mul") or bits < 1:
        raise KeyError(name)
    return kind, bits


def benchmark(name: str) -> Circuit:
    """Fetch a paper benchmark by name, e.g. ``adder_i4`` or ``mul_i8``."""
    kind, bits = parse_benchmark_name(name)
    if kind == "adder":
        return ripple_carry_adder(bits)
    return array_multiplier(bits)


BENCHMARKS = ["adder_i4", "adder_i6", "adder_i8", "mul_i4", "mul_i6", "mul_i8"]


def reference_values(name: str) -> np.ndarray:
    """Ground-truth integer outputs for every assignment (for tests)."""
    kind, size = name.split("_i")
    bits = int(size) // 2
    idx = np.arange(1 << (2 * bits), dtype=np.uint64)
    a = idx & np.uint64((1 << bits) - 1)
    b = idx >> np.uint64(bits)
    return a + b if kind == "adder" else a * b
