"""The error miter (paper Fig. 1): ``∃p ∀i : dist(i, p) <= ET``.

``map`` interprets a circuit's output bits as an unsigned integer (LSB =
output 0); ``dist`` is the absolute difference between the mapped outputs of
the exact and approximate circuits.  Soundness = the worst-case error over
*all* input assignments is at most the error threshold (ET).

Two backends:

* **Exhaustive** (numpy / bit-packed): for the paper's operator sizes
  (n <= 8, 256 assignments) the full input space is enumerable; this backend
  is the ground truth every search result is re-validated against.
* **Z3**: a quantifier-free expansion of the miter — one arithmetic
  constraint per input assignment with only the *template parameters*
  symbolic.  This mirrors what XPAT's solver sees and is the faithful
  reproduction path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

try:
    import z3
except ImportError:  # pragma: no cover - exercised on z3-less images
    z3 = None  # the exhaustive backend stays fully usable without the SMT one

from .circuits import Circuit, unpack_bits
from .templates import NonsharedTemplate, SharedTemplate, TemplateParams

HAVE_Z3 = z3 is not None

__all__ = [
    "ErrorStats",
    "ERROR_METRICS",
    "measure_error",
    "worst_case_error",
    "values_from_tables",
    "MiterZ3",
    "HAVE_Z3",
]

ERROR_METRICS = ("wce", "mae", "mse")


class ErrorStats(NamedTuple):
    """Exhaustive error statistics of a candidate vs the exact outputs.

    One measurement, three bound-able metrics: the paper's worst-case
    error plus the MECALS-style mean metrics (ROADMAP "richer error
    metrics").  A NamedTuple so the historical ``wce, mae = ...`` readers
    become explicit attribute reads instead of silent mis-unpacks.
    """

    wce: int     # worst |err| over all assignments
    mae: float   # mean |err|
    mse: float   # mean squared err

    def value(self, metric: str) -> float:
        """The statistic a named error metric bounds."""
        if metric not in ERROR_METRICS:
            raise KeyError(
                f"unknown error metric {metric!r}; known: {ERROR_METRICS}")
        return getattr(self, metric)


def values_from_tables(tables: np.ndarray, n_inputs: int) -> np.ndarray:
    """Packed output tables ``(m, W)`` -> per-assignment values ``(2**n,)``."""
    bits = unpack_bits(tables, 1 << n_inputs)  # (m, S)
    weights = np.uint64(1) << np.arange(tables.shape[0], dtype=np.uint64)
    return (bits.astype(np.uint64) * weights[:, None]).sum(axis=0)


def measure_error(circuit: Circuit, exact_values: np.ndarray) -> ErrorStats:
    """Exhaustive :class:`ErrorStats` of a candidate against the exact
    outputs.

    The one measurement every consumer shares — engine harvests
    (:func:`repro.core.engine.verify_circuit`) and store writes
    (:meth:`repro.library.OperatorStore.put_circuit`) — so every error
    metric (``wce`` / ``mae`` / ``mse``) extends a single definition.
    """
    err = np.abs(circuit.eval_words().astype(np.int64)
                 - exact_values.astype(np.int64))
    return ErrorStats(wce=int(err.max()), mae=float(err.mean()),
                      mse=float((err.astype(np.float64) ** 2).mean()))


def worst_case_error(exact: Circuit, approx: Circuit) -> int:
    """Exhaustive worst-case |exact - approx| over all assignments."""
    assert exact.n_inputs == approx.n_inputs
    return measure_error(approx, exact.eval_words()).wce


def params_sound(
    template: NonsharedTemplate | SharedTemplate,
    params: TemplateParams,
    exact_values: np.ndarray,
    et: int,
) -> bool:
    """Exhaustive soundness check of a parameter assignment."""
    vals = values_from_tables(template.eval_outputs(params), template.n_inputs)
    return bool(np.abs(vals.astype(np.int64) - exact_values.astype(np.int64)).max() <= et)


# --------------------------------------------------------------------------
# Z3 miter
# --------------------------------------------------------------------------
@dataclass
class _SharedVars:
    use: list[list[z3.BoolRef]]   # (T, n)
    neg: list[list[z3.BoolRef]]   # (T, n)
    sel: list[list[z3.BoolRef]]   # (m, T)


class MiterZ3:
    """Quantifier-free Z3 encoding of the XPAT/SHARED miter.

    One instance per (exact circuit, template).  ``solve`` adds the proxy
    restriction constraints of the current grid point and asks for a model;
    the model is decoded back into :class:`TemplateParams` so that every
    SAT result is *re-verified exhaustively* before being trusted.
    """

    def __init__(
        self,
        exact: Circuit,
        template: NonsharedTemplate | SharedTemplate,
    ) -> None:
        if z3 is None:
            raise RuntimeError(
                "z3-solver is not installed; the SMT miter is unavailable "
                "(the exhaustive backend and the non-SMT searches still work)"
            )
        self.exact = exact
        self.template = template
        self.n = exact.n_inputs
        self.m = exact.n_outputs
        self.exact_values = exact.eval_words()
        self.shared = isinstance(template, SharedTemplate)
        self._build_vars()

    # ------------------------------------------------------------------ vars
    def _build_vars(self) -> None:
        n, m = self.n, self.m
        if self.shared:
            T = self.template.pit
            self.use = [[z3.Bool(f"u_{t}_{j}") for j in range(n)] for t in range(T)]
            self.neg = [[z3.Bool(f"g_{t}_{j}") for j in range(n)] for t in range(T)]
            self.sel = [[z3.Bool(f"s_{i}_{t}") for t in range(T)] for i in range(m)]
            self.T = T
        else:
            K = self.template.ppo
            self.use = [
                [[z3.Bool(f"u_{i}_{k}_{j}") for j in range(n)] for k in range(K)]
                for i in range(m)
            ]
            self.neg = [
                [[z3.Bool(f"g_{i}_{k}_{j}") for j in range(n)] for k in range(K)]
                for i in range(m)
            ]
            self.sel = [[z3.Bool(f"s_{i}_{k}") for k in range(K)] for i in range(m)]
            self.K = K

    # ------------------------------------------------------- product/out expr
    def _lit(self, use: z3.BoolRef, neg: z3.BoolRef, bit: bool) -> z3.BoolRef:
        # IGNORE (use=False) -> True; else bit XOR neg
        return z3.Or(z3.Not(use), z3.Not(neg) if bit else neg)

    def _product(self, use_row, neg_row, assignment: int) -> z3.BoolRef:
        terms = []
        for j in range(self.n):
            bit = bool((assignment >> j) & 1)
            terms.append(self._lit(use_row[j], neg_row[j], bit))
        return z3.And(*terms)

    def _out_bits(self, assignment: int) -> list[z3.BoolRef]:
        if self.shared:
            prods = [
                self._product(self.use[t], self.neg[t], assignment)
                for t in range(self.T)
            ]
            return [
                z3.Or(*[z3.And(self.sel[i][t], prods[t]) for t in range(self.T)])
                for i in range(self.m)
            ]
        return [
            z3.Or(
                *[
                    z3.And(
                        self.sel[i][k],
                        self._product(self.use[i][k], self.neg[i][k], assignment),
                    )
                    for k in range(self.K)
                ]
            )
            for i in range(self.m)
        ]

    # ----------------------------------------------------------- constraints
    def error_constraints(self, et: int) -> list[z3.BoolRef]:
        cons = []
        for a in range(1 << self.n):
            bits = self._out_bits(a)
            val = z3.Sum(*[z3.If(bits[k], 1 << k, 0) for k in range(self.m)])
            ev = int(self.exact_values[a])
            cons.append(val - ev <= et)
            cons.append(ev - val <= et)
        return cons

    def proxy_constraints(self, **bounds: int) -> list[z3.BoolRef]:
        """Shared: ``its``.  Nonshared: ``lpp``.

        PIT / PPO are enforced *structurally* (pool size T / bank size K),
        exactly as the template's structural parameter — the grid search
        rebuilds the miter per PIT/PPO value.
        """
        cons: list[z3.BoolRef] = []
        if self.shared:
            its = bounds.get("its")
            if its is not None and its < self.T:
                for i in range(self.m):
                    cons.append(z3.AtMost(*self.sel[i], its))
        else:
            lpp = bounds.get("lpp")
            if lpp is not None and lpp < self.n:
                for i in range(self.m):
                    for k in range(self.K):
                        cons.append(z3.AtMost(*self.use[i][k], lpp))
        return cons

    # ----------------------------------------------------------------- solve
    def solve(
        self,
        et: int,
        timeout_ms: int = 60_000,
        seed: int = 0,
        **proxy_bounds: int,
    ) -> TemplateParams | None:
        solver = z3.Solver()
        solver.set("timeout", timeout_ms)
        solver.set("random_seed", seed)
        solver.add(*self.error_constraints(et))
        solver.add(*self.proxy_constraints(**proxy_bounds))
        if solver.check() != z3.sat:
            return None
        return self._decode(solver.model())

    def _decode(self, model: z3.ModelRef) -> TemplateParams:
        def b(v: z3.BoolRef) -> bool:
            return bool(model.eval(v, model_completion=True))

        from .templates import IGNORE, NEG, USE

        if self.shared:
            lits = np.full((self.T, self.n), IGNORE, dtype=np.int8)
            for t in range(self.T):
                for j in range(self.n):
                    if b(self.use[t][j]):
                        lits[t, j] = NEG if b(self.neg[t][j]) else USE
            sel = np.array(
                [[b(self.sel[i][t]) for t in range(self.T)] for i in range(self.m)]
            )
            return TemplateParams(lits, sel)
        lits = np.full((self.m, self.K, self.n), IGNORE, dtype=np.int8)
        for i in range(self.m):
            for k in range(self.K):
                for j in range(self.n):
                    if b(self.use[i][k][j]):
                        lits[i, k, j] = NEG if b(self.neg[i][k][j]) else USE
        sel = np.array(
            [[b(self.sel[i][k]) for k in range(self.K)] for i in range(self.m)]
        )
        return TemplateParams(lits, sel)
