"""Light logic synthesis + standard-cell area model.

The paper synthesizes candidate netlists with Yosys + the Nangate 45nm
library and reports cell area.  Yosys is not available offline, so this
module implements the subset of synthesis that determines *relative area
ordering* for sum-of-products netlists (which is what the paper's claims —
proxy correlation and SHARED < XPAT — rest on):

1. binarization of n-ary AND/OR into balanced trees,
2. constant propagation & boolean simplification,
3. buffer / double-negation forwarding,
4. structural hashing (CSE) — *this is the pass that rewards product
   sharing*: two identical products collapse into one node,
5. single-use NOT+AND/OR fusion into NAND/NOR (cheaper cells),
6. dead-gate elimination.

Area is the sum of Nangate 45nm X1 cell areas (µm²) over live logic gates.
"""

from __future__ import annotations

import numpy as np

from .circuits import Circuit, Gate, Op

__all__ = ["synthesize", "binarize", "area", "NANGATE45_AREA"]

# Nangate Open Cell Library 45nm, X1 drive strength, cell area in µm².
NANGATE45_AREA: dict[Op, float] = {
    Op.NOT: 0.532,
    Op.BUF: 0.798,
    Op.AND: 1.064,
    Op.OR: 1.064,
    Op.NAND: 0.798,
    Op.NOR: 0.798,
    Op.XOR: 1.596,
    Op.XNOR: 1.596,
    Op.INPUT: 0.0,
    Op.CONST0: 0.0,
    Op.CONST1: 0.0,
}


def binarize(circuit: Circuit) -> Circuit:
    """Split n-ary AND/OR gates into balanced binary trees (a raw n-ary
    netlist is not a standard-cell netlist; all area numbers are post-
    binarization)."""
    out = Circuit.empty(circuit.n_inputs, name=circuit.name)
    remap: list[int] = list(range(circuit.n_inputs))

    def tree(op: Op, ids: list[int]) -> int:
        while len(ids) > 1:
            nxt = []
            for a, b in zip(ids[::2], ids[1::2]):
                nxt.append(out.add(op, a, b))
            if len(ids) % 2:
                nxt.append(ids[-1])
            ids = nxt
        return ids[0]

    for i, g in enumerate(circuit.nodes):
        if g.op is Op.INPUT:
            continue
        args = [remap[a] for a in g.args]
        if g.op in (Op.AND, Op.OR) and len(args) > 2:
            remap.append(tree(g.op, args))
        elif g.op in (Op.NAND, Op.NOR) and len(args) > 2:
            base = Op.AND if g.op is Op.NAND else Op.OR
            remap.append(out.add(Op.NOT, tree(base, args)))
        else:
            remap.append(out.add(g.op, *args))
    out.outputs = [remap[o] for o in circuit.outputs]
    return out


def _simplify_once(circuit: Circuit) -> tuple[Circuit, bool]:
    """One pass of const-prop + forwarding + structural hashing + DCE."""
    out = Circuit.empty(circuit.n_inputs, name=circuit.name)
    remap: list[int] = list(range(circuit.n_inputs))
    kind: list[str] = ["var"] * circuit.n_inputs  # 'var' | 'c0' | 'c1'
    cache: dict[tuple, int] = {}
    changed = False

    def emit(op: Op, *args: int) -> int:
        key = (op, tuple(sorted(args)) if op in (Op.AND, Op.OR, Op.XOR, Op.NAND, Op.NOR, Op.XNOR) else tuple(args))
        if key in cache:
            return cache[key]
        nid = out.add(op, *args)
        cache[key] = nid
        kind.append("var")
        return nid

    def emit_const(v: bool) -> int:
        key = ("const", v)
        if key in cache:
            return cache[key]
        nid = out.const(v)
        cache[key] = nid
        kind.append("c1" if v else "c0")
        return nid

    for i, g in enumerate(circuit.nodes):
        if g.op is Op.INPUT:
            continue
        if g.op is Op.CONST0:
            remap.append(emit_const(False))
            continue
        if g.op is Op.CONST1:
            remap.append(emit_const(True))
            continue
        args = [remap[a] for a in g.args]
        kinds = [kind[a] for a in args]

        if g.op is Op.BUF:
            remap.append(args[0])
            changed = True
            continue
        if g.op is Op.NOT:
            a = args[0]
            if kinds[0] == "c0":
                remap.append(emit_const(True)); changed = True
            elif kinds[0] == "c1":
                remap.append(emit_const(False)); changed = True
            elif out.nodes[a].op is Op.NOT:  # double negation
                remap.append(out.nodes[a].args[0]); changed = True
            else:
                remap.append(emit(Op.NOT, a))
            continue
        if g.op in (Op.AND, Op.OR):
            absorb = "c0" if g.op is Op.AND else "c1"   # dominating constant
            neutral = "c1" if g.op is Op.AND else "c0"  # identity constant
            if any(k == absorb for k in kinds):
                remap.append(emit_const(g.op is Op.OR)); changed = True
                continue
            live = sorted({a for a, k in zip(args, kinds) if k != neutral})
            if len(live) < len(args):
                changed = True
            if not live:
                remap.append(emit_const(g.op is Op.AND))  # empty AND=1, OR=0
                continue
            if len(live) == 1:
                remap.append(live[0])
                continue
            # x op x covered by the sorted-set dedup above (live is a set)
            remap.append(emit(g.op, *live))
            continue
        if g.op in (Op.XOR, Op.XNOR):
            a, b = args
            ka, kb = kinds
            base_is_xor = g.op is Op.XOR
            if ka in ("c0", "c1") and kb in ("c0", "c1"):
                v = (ka == "c1") ^ (kb == "c1")
                remap.append(emit_const(v if base_is_xor else not v)); changed = True
                continue
            if ka in ("c0", "c1") or kb in ("c0", "c1"):
                cval = (ka == "c1") if ka in ("c0", "c1") else (kb == "c1")
                var = b if ka in ("c0", "c1") else a
                inv = cval ^ (not base_is_xor)
                remap.append(emit(Op.NOT, var) if inv else var)
                changed = True
                continue
            if a == b:
                remap.append(emit_const(not base_is_xor)); changed = True
                continue
            remap.append(emit(g.op, a, b))
            continue
        if g.op in (Op.NAND, Op.NOR):
            base = Op.AND if g.op is Op.NAND else Op.OR
            inner = remap[-0]  # placeholder, not used
            # lower to NOT(base) and let fusion re-pack later
            tmp_args = args
            nid = emit(base, *sorted(set(tmp_args))) if len(set(tmp_args)) > 1 else tmp_args[0]
            remap.append(emit(Op.NOT, nid))
            changed = True
            continue
        raise ValueError(f"unexpected op {g.op}")  # pragma: no cover

    out.outputs = [remap[o] for o in circuit.outputs]
    return out, changed


def _fuse_inverters(circuit: Circuit) -> Circuit:
    """NOT(AND) -> NAND, NOT(OR) -> NOR, NOT(XOR) -> XNOR, when the inner
    gate has no other fanout (single-use)."""
    fanout = circuit.fanout_counts()
    out = Circuit.empty(circuit.n_inputs, name=circuit.name)
    remap: dict[int, int] = {i: i for i in range(circuit.n_inputs)}
    fused_inner: set[int] = set()
    fuse_map = {Op.AND: Op.NAND, Op.OR: Op.NOR, Op.XOR: Op.XNOR}

    # first decide which NOT gates fuse
    fuses: dict[int, tuple[Op, tuple[int, ...]]] = {}
    for i, g in enumerate(circuit.nodes):
        if g.op is Op.NOT:
            inner = circuit.nodes[g.args[0]]
            if inner.op in fuse_map and fanout[g.args[0]] == 1:
                fuses[i] = (fuse_map[inner.op], inner.args)
                fused_inner.add(g.args[0])

    for i, g in enumerate(circuit.nodes):
        if g.op is Op.INPUT:
            continue
        if i in fused_inner and i not in [o for o in circuit.outputs]:
            remap[i] = -1  # dead; nothing should reference it afterwards
            continue
        if i in fuses:
            op, inner_args = fuses[i]
            remap[i] = out.add(op, *[remap[a] for a in inner_args])
        else:
            remap[i] = out.add(g.op, *[remap[a] for a in g.args])
    out.outputs = [remap[o] for o in circuit.outputs]
    return out


def _dce(circuit: Circuit) -> Circuit:
    """Drop gates not reachable from the outputs."""
    live = circuit.live_nodes()
    out = Circuit.empty(circuit.n_inputs, name=circuit.name)
    remap: dict[int, int] = {i: i for i in range(circuit.n_inputs)}
    for i, g in enumerate(circuit.nodes):
        if g.op is Op.INPUT or not live[i]:
            continue
        remap[i] = out.add(g.op, *[remap[a] for a in g.args])
    out.outputs = [remap[o] for o in circuit.outputs]
    return out


def synthesize(circuit: Circuit, max_iters: int = 8) -> Circuit:
    """Run the pass pipeline to a fixpoint (bounded)."""
    c = binarize(circuit)
    for _ in range(max_iters):
        c, changed = _simplify_once(c)
        if not changed:
            break
    c = _dce(c)
    c = _fuse_inverters(c)
    c = _dce(c)
    return c


def area(circuit: Circuit, *, presynthesized: bool = False) -> float:
    """Nangate-45nm-equivalent cell area (µm²) after light synthesis."""
    c = circuit if presynthesized else synthesize(circuit)
    live = c.live_nodes()
    total = 0.0
    for i, g in enumerate(c.nodes):
        if live[i]:
            total += NANGATE45_AREA.get(g.op, 0.0)
    return round(total, 4)
