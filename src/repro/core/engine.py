"""Unified search-engine abstraction over every ALS search in the repo.

Before this module the three searches — the paper's progressive SMT
exploration (:mod:`repro.core.search`), the tensorized population search
(:mod:`repro.core.tensor_search`) and the annealing / rewrite baselines —
each invented their own report and result dataclasses and re-implemented
the re-verify-and-synthesize harvest.  Now they all speak one language:

* :class:`SearchJob` — what to search: ``(benchmark, bits, error_metric,
  et, engine, budget_s, seed)``.  Content-hashable (:meth:`SearchJob.key`)
  so a fleet can use it as a resume token.
* :class:`SearchEngine` — the protocol: ``run(job) -> SearchOutcome``.
* :class:`SearchOutcome` — the single report type: a list of
  exhaustively re-verified :class:`Candidate` netlists plus engine stats.
  It also serves engine-agnostic consumers (the perf hillclimb wraps its
  roofline records in one and queries :meth:`SearchOutcome.pareto`).
* :func:`harvest` — the one shared instantiate → synthesize → exhaustive
  re-verify path.  Every candidate that reaches an outcome went through
  it; an unsound model raises :class:`UnsoundResultError` with enough
  context for a fleet worker to report the failing job.

Registry: :func:`get_engine` maps ``shared`` / ``xpat`` (SMT), ``tensor``
(evolutionary), ``anneal`` (simulated annealing, numpy-only), ``muscat``
/ ``mecals`` (rewrite baselines) to engine instances;
:func:`available_engines` filters by what the image can actually run
(the SMT engines need z3).

This module stays jax-free at import time (engines lazy-import their
backends) so multiprocessing fleet workers fork cheaply.
"""

from __future__ import annotations

import hashlib
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Protocol, Sequence, runtime_checkable

import numpy as np

from ..obs.metrics import get_registry
from ..obs.trace import span as trace_span
from .arith import benchmark as _benchmark
from .circuits import Circuit
from .miter import ERROR_METRICS, HAVE_Z3, ErrorStats, measure_error, \
    values_from_tables
from .synth import area, synthesize
from .templates import IGNORE, SharedTemplate, TemplateParams

__all__ = [
    "SearchJob",
    "SearchOutcome",
    "Candidate",
    "SearchEngine",
    "UnsoundResultError",
    "harvest",
    "verify_circuit",
    "get_engine",
    "available_engines",
    "InstrumentedEngine",
    "ENGINE_NAMES",
]


class UnsoundResultError(RuntimeError):
    """A search result failed exhaustive re-verification.

    Raised instead of a bare ``assert`` so fleet workers can attribute the
    failure to a job instead of dying with a context-free traceback.
    """


# ---------------------------------------------------------------------------
# job / candidate / outcome
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SearchJob:
    """One unit of search work, addressable by content.

    ``benchmark`` is the operator *kind* (``"mul"`` / ``"adder"``); with
    ``bits`` it names the exact circuit (``mul_i4`` = 2-bit multiplier).
    """

    benchmark: str            # operator kind: "mul" | "adder"
    bits: int                 # operand bit width (paper: 2, 3, 4)
    et: int                   # error threshold under ``error_metric``
    engine: str               # registry name, see ENGINE_NAMES
    error_metric: str = "wce"
    budget_s: float = 60.0
    seed: int = 0

    @property
    def benchmark_name(self) -> str:
        return f"{self.benchmark}_i{2 * self.bits}"

    def exact(self) -> Circuit:
        """The exact reference circuit this job approximates."""
        return _benchmark(self.benchmark_name)

    def signature(self):
        """The :class:`~repro.library.store.OperatorSignature` results of
        this job are stored under."""
        from ..library.store import OperatorSignature

        return OperatorSignature(self.benchmark, self.bits,
                                 self.error_metric, self.et)

    def key(self) -> str:
        """Stable content key — the fleet's resume token."""
        blob = "|".join(
            str(v) for v in (self.benchmark, self.bits, self.et, self.engine,
                             self.error_metric, self.budget_s, self.seed)
        )
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def describe(self) -> str:
        return (f"{self.benchmark_name} {self.error_metric}<={self.et} "
                f"[{self.engine}] budget={self.budget_s:g}s seed={self.seed}")


@dataclass
class Candidate:
    """One sound, exhaustively re-verified approximation.

    The single result record shared by every engine (replaces the old
    ``SearchResult`` / ``TensorResult`` pair).
    """

    circuit: Circuit              # synthesized netlist
    area: float                   # synthesized area, µm²
    params: TemplateParams | None = None
    proxies: dict = field(default_factory=dict)
    wall_s: float = 0.0
    meta: dict = field(default_factory=dict)   # grid_point, generation, ...

    @property
    def proxy_score(self) -> int:
        return sum(self.proxies.values())


@dataclass
class SearchOutcome:
    """The unified search report (replaces ``SearchReport`` /
    ``TensorSearchReport`` / the hillclimb's ad-hoc record lists).

    ``results`` usually holds :class:`Candidate`\\ s; engine-agnostic
    consumers (the perf hillclimb) may hold other record types and use the
    generic :meth:`pareto` / :meth:`min_by` selectors instead of
    :attr:`best`.
    """

    engine: str
    benchmark: str
    et: int | None = None
    results: list = field(default_factory=list)
    stats: dict = field(default_factory=dict)  # grid_points_tried, generations, ...
    wall_s: float = 0.0
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def best(self):
        """Smallest-area candidate, or ``None``."""
        if not self.results or not hasattr(self.results[0], "area"):
            return None
        return min(self.results, key=lambda r: r.area)

    def min_by(self, objective: Callable) -> object | None:
        return min(self.results, key=objective) if self.results else None

    def pareto(self, objectives: Sequence[Callable]) -> list:
        """Non-dominated results under ``objectives`` (minimization)."""
        from ..library.pareto import pareto_front

        return pareto_front(self.results, objectives)


@runtime_checkable
class SearchEngine(Protocol):
    """What the fleet (and any other driver) programs against."""

    name: str

    def run(self, job: SearchJob) -> SearchOutcome: ...


# ---------------------------------------------------------------------------
# the shared harvest: instantiate -> synthesize -> exhaustive re-verify
# ---------------------------------------------------------------------------
def verify_circuit(circuit: Circuit, exact_values: np.ndarray, et: float,
                   *, metric: str = "wce", context: str = "") -> float:
    """Exhaustive error of ``circuit`` vs the exact values under the
    chosen metric (``wce`` / ``mae`` / ``mse``); raises
    :class:`UnsoundResultError` when it exceeds ``et``."""
    val = measure_error(circuit, exact_values).value(metric)
    if val > et:
        raise UnsoundResultError(
            f"search result failed exhaustive re-verification"
            f"{f' ({context})' if context else ''}: measured {metric} "
            f"{val:g} > ET {et:g} on {circuit.name!r} "
            f"({circuit.n_inputs} inputs)"
        )
    return val


def harvest(template, params: TemplateParams, exact_values: np.ndarray,
            et: float, *, engine: str, metric: str = "wce",
            name: str = "approx", wall_s: float = 0.0,
            meta: dict | None = None) -> Candidate:
    """Turn a raw parameter assignment into a verified :class:`Candidate`.

    This is the code path every engine's winners go through — previously
    copy-pasted between the SMT ``record`` and the tensor harvest loop.
    ``metric`` is the job's chosen error metric: the exhaustive re-verify
    bounds *that* statistic, so an ``mae``-signed store entry was really
    proven under mae.  (A wce-guided engine is sound for mae for free —
    ``mae <= wce`` pointwise — but mse has no such bound, and either way
    the verification here is what the signature's claim rests on.)
    """
    circuit = synthesize(template.instantiate(params, name=name))
    verify_circuit(circuit, exact_values, et, metric=metric,
                   context=f"engine={engine}, proxies={template.proxies(params)}")
    return Candidate(
        circuit=circuit,
        area=area(circuit, presynthesized=True),
        params=params,
        proxies=template.proxies(params),
        wall_s=wall_s,
        meta=dict(meta or {}),
    )


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------
def _check_metric(job: SearchJob, engine: str,
                  supported: tuple[str, ...]) -> None:
    """Reject metric/engine combinations that cannot be made sound.

    The SMT miter and the tensorized population search *guide* by
    worst-case error; a ``wce <= ET`` result is automatically
    ``mae <= ET`` (pointwise bound), so those engines also serve mae jobs
    (conservatively).  ``mse`` has no such bound — only the anneal engine
    scores it natively.
    """
    if job.error_metric not in ERROR_METRICS:
        raise KeyError(f"unknown error metric {job.error_metric!r}; "
                       f"known: {ERROR_METRICS}")
    if job.error_metric not in supported:
        raise ValueError(
            f"engine {engine!r} cannot bound metric {job.error_metric!r} "
            f"(supports {supported}); use the anneal engine"
        )


class SmtEngine:
    """The paper's progressive proxy-constrained SMT search (needs z3)."""

    def __init__(self, method: str = "shared", **search_kw):
        if method not in ("shared", "xpat"):
            raise ValueError(f"unknown SMT method {method!r}")
        self.name = method
        self.method = method
        self.search_kw = search_kw

    def run(self, job: SearchJob) -> SearchOutcome:
        from .search import progressive_search

        _check_metric(job, self.name, ("wce", "mae"))
        return progressive_search(
            job.exact(), et=job.et, method=self.method,
            wall_budget_s=job.budget_s, seed=job.seed, **self.search_kw
        )


class TensorEngine:
    """Tensorized population search; optionally shards the population over
    a jax mesh's ``data`` axis (TPU fleet workers)."""

    name = "tensor"

    def __init__(self, mesh=None, **search_kw):
        self.mesh = mesh
        self.search_kw = search_kw

    def run(self, job: SearchJob) -> SearchOutcome:
        from .tensor_search import tensor_search

        _check_metric(job, self.name, ("wce", "mae"))
        return tensor_search(
            job.exact(), et=job.et, seed=job.seed,
            wall_budget_s=job.budget_s, mesh=self.mesh, **self.search_kw
        )


class AnnealEngine:
    """Simulated annealing over shared-template parameters (numpy-only).

    The hillclimb's accept-if-better loop, ported into the unified engine
    with a temperature schedule and restarts: propose one literal/selector
    mutation, score by the same proxy-area energy the tensor search uses
    (unsound candidates ranked by violation), accept per Metropolis.
    Needs neither z3 nor jax — the engine of last resort on bare images
    and the cheap CPU filler for fleet sweeps.
    """

    name = "anneal"

    def __init__(self, *, steps: int = 4000, restarts: int = 3,
                 start_temp: float = 6.0, cooling: float = 0.999,
                 keep: int = 8, pit: int | None = None):
        self.steps = steps
        self.restarts = restarts
        self.start_temp = start_temp
        self.cooling = cooling
        self.keep = keep
        self.pit = pit

    def _energy(self, tpl: SharedTemplate, p: TemplateParams,
                exact_vals: np.ndarray, et: float, metric: str
                ) -> tuple[float, float]:
        """Energy + the candidate's error under the job's chosen metric
        — the one engine that *scores* mae/mse natively instead of
        bounding them through wce."""
        vals = values_from_tables(tpl.eval_outputs(p), tpl.n_inputs)
        err = np.abs(vals.astype(np.int64) - exact_vals)
        stats = ErrorStats(wce=int(err.max()), mae=float(err.mean()),
                           mse=float((err.astype(np.float64) ** 2).mean()))
        val = stats.value(metric)
        if val > et:
            return 1e6 + 100.0 * val + float(err.sum()) / err.size, val
        used = p.sel.any(axis=0)
        lit_cnt = int(((p.lits != IGNORE) & used[:, None]).sum())
        prox = tpl.proxies(p)
        return 10.0 * prox["PIT"] + 2.0 * lit_cnt + 3.0 * prox["ITS"], val

    def run(self, job: SearchJob) -> SearchOutcome:
        exact = job.exact()
        n, m = exact.n_inputs, exact.n_outputs
        T = self.pit if self.pit is not None else 2 * m
        tpl = SharedTemplate(n, m, pit=T)
        exact_vals = exact.eval_words().astype(np.int64)
        rng = np.random.default_rng(job.seed)
        t0 = time.time()
        outcome = SearchOutcome(engine=self.name, benchmark=exact.name,
                                et=job.et, stats={"steps": 0, "accepted": 0,
                                                  "restarts": 0})
        # distinct sound assignments seen, fingerprint -> (energy, params)
        pool: dict[bytes, tuple[float, TemplateParams]] = {}

        def propose(p: TemplateParams) -> TemplateParams:
            q = p.copy()
            slot = int(rng.integers(T * n + m * T))
            if slot < T * n:
                q.lits[slot // n, slot % n] = rng.integers(0, 3)
            else:
                slot -= T * n
                q.sel[slot // T, slot % T] ^= True
            return q

        for _ in range(self.restarts):
            if time.time() - t0 > job.budget_s:
                break
            outcome.stats["restarts"] += 1
            u = rng.random((T, n))
            p = TemplateParams(
                np.select([u < 0.25, u < 0.5], [0, 1], default=IGNORE).astype(np.int8),
                rng.random((m, T)) < 0.3,
            )
            e, val = self._energy(tpl, p, exact_vals, job.et,
                                  job.error_metric)
            temp = self.start_temp
            for _step in range(self.steps):
                if time.time() - t0 > job.budget_s:
                    break
                q = propose(p)
                e2, val2 = self._energy(tpl, q, exact_vals, job.et,
                                        job.error_metric)
                outcome.stats["steps"] += 1
                if e2 <= e or rng.random() < math.exp(-(e2 - e) / max(temp, 1e-9)):
                    p, e, val = q, e2, val2
                    outcome.stats["accepted"] += 1
                    if val <= job.et:
                        fp = p.lits.tobytes() + p.sel.tobytes()
                        if fp not in pool:
                            pool[fp] = (e, p.copy())
                            if len(pool) > 4 * self.keep:  # bound memory
                                for k in sorted(pool, key=lambda k: pool[k][0])[self.keep:]:
                                    del pool[k]
                temp *= self.cooling

        for _e, p in sorted(pool.values(), key=lambda ep: ep[0])[: self.keep]:
            outcome.results.append(
                harvest(tpl, p, exact_vals, job.et, engine=self.name,
                        metric=job.error_metric,
                        name=f"{exact.name}_anneal", wall_s=time.time() - t0)
            )
        outcome.wall_s = time.time() - t0
        return outcome


class RewriteEngine:
    """Wraps the circuit-rewrite baselines (MUSCAT- / MECALS-like) as
    engines: single-candidate outcomes, re-verified like everything else."""

    def __init__(self, name: str):
        if name not in ("muscat", "mecals"):
            raise ValueError(f"unknown rewrite engine {name!r}")
        self.name = name

    def run(self, job: SearchJob) -> SearchOutcome:
        from .baselines import mecals_like, muscat_like

        fn = muscat_like if self.name == "muscat" else mecals_like
        _check_metric(job, self.name, ("wce", "mae"))
        exact = job.exact()
        t0 = time.time()
        res = fn(exact, et=job.et, seed=job.seed, wall_budget_s=job.budget_s)
        outcome = SearchOutcome(engine=self.name, benchmark=exact.name,
                                et=job.et)
        verify_circuit(res.circuit, exact.eval_words(), job.et,
                       metric=job.error_metric, context=f"engine={self.name}")
        outcome.results.append(
            Candidate(circuit=res.circuit, area=res.area, wall_s=res.wall_s)
        )
        outcome.wall_s = time.time() - t0
        return outcome


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
ENGINE_NAMES = ("shared", "xpat", "tensor", "anneal", "muscat", "mecals")

# the per-engine evaluation counters differ in name across engines; the
# instrumented wrapper folds whichever is present into one
# ``search_evaluations_total`` rate so dashboards compare engines directly
_EVAL_STAT_KEYS = ("evaluations", "steps", "grid_points_tried")


class InstrumentedEngine:
    """Transparent observability wrapper every registry lookup returns.

    ``run`` wraps the inner engine in a ``search.run`` span and folds the
    outcome's stats into the process registry (evaluations/sec across
    engines, result counts, wall-time histogram, SMT solver seconds).
    Everything else — including engine-specific attributes like
    ``TensorEngine.mesh`` — passes through untouched, so callers keep
    programming against the :class:`SearchEngine` protocol.
    """

    def __init__(self, inner: SearchEngine) -> None:
        self._inner = inner
        self.name = inner.name

    def __getattr__(self, attr):
        return getattr(self._inner, attr)

    def run(self, job: SearchJob) -> SearchOutcome:
        reg = get_registry()
        with trace_span("search.run", engine=self.name,
                        benchmark=job.benchmark_name, et=job.et,
                        metric=job.error_metric, seed=job.seed) as sp:
            outcome = self._inner.run(job)
            stats = outcome.stats or {}
            evals = sum(int(stats.get(k, 0)) for k in _EVAL_STAT_KEYS)
            reg.counter("search_runs_total", engine=self.name).inc()
            reg.counter("search_evaluations_total",
                        engine=self.name).inc(evals)
            reg.counter("search_results_total",
                        engine=self.name).inc(len(outcome.results))
            reg.histogram("search_run_s",
                          engine=self.name).observe(outcome.wall_s)
            if stats.get("smt_solve_s"):
                reg.counter("search_smt_solve_s_total",
                            engine=self.name).inc(float(stats["smt_solve_s"]))
            sp.set(n_results=len(outcome.results), evaluations=evals,
                   wall_s=round(outcome.wall_s, 4), ok=outcome.ok)
        return outcome


def get_engine(name: str, **opts) -> SearchEngine:
    """Engine instance by registry name; ``opts`` are engine-specific
    constructor knobs (e.g. ``population=`` for tensor, ``steps=`` for
    anneal, ``timeout_ms=`` / ``sink=`` for the SMT engines).  Every
    engine comes back wrapped in :class:`InstrumentedEngine`."""
    if name in ("shared", "xpat"):
        return InstrumentedEngine(SmtEngine(method=name, **opts))
    if name == "tensor":
        return InstrumentedEngine(TensorEngine(**opts))
    if name == "anneal":
        return InstrumentedEngine(AnnealEngine(**opts))
    if name in ("muscat", "mecals"):
        if opts:
            raise TypeError(f"{name} engine takes no options, got {opts}")
        return InstrumentedEngine(RewriteEngine(name))
    raise KeyError(f"unknown engine {name!r}; known: {ENGINE_NAMES}")


def available_engines() -> tuple[str, ...]:
    """Engines runnable on this image (SMT engines need z3)."""
    return ENGINE_NAMES if HAVE_Z3 else tuple(
        n for n in ENGINE_NAMES if n not in ("shared", "xpat")
    )
