"""Progressive proxy-constrained design-space exploration (paper §III).

"The search starts with a strong restriction, which is progressively
weakened until an assignment is found."  The proxy pairs are:

* SHARED: ``(PIT, ITS)`` — PIT enforced structurally (pool size ``T``),
  ITS as a cardinality constraint per sum;
* XPAT (nonshared): ``(PPO, LPP)`` — PPO structural (bank size ``K``), LPP
  as a cardinality constraint per product.

Search strategy (documented refinement of the paper's linear weakening —
same proxy-constrained SMT queries, better schedule):

1. **Frontier probe** — double the structural parameter (PIT / PPO) with
   the secondary proxy unconstrained until the first SAT.  UNSAT points
   are cheap; this localizes the feasibility frontier in O(log) queries.
2. **Grid refinement** — walk the (primary, secondary) lattice downward
   from the frontier in ascending predicted-area order, collecting every
   sound assignment (the paper reports several per run, Fig. 4).
3. **Literal tightening** — at the best grid point, binary-search the
   total literal count (and selection count) with ``z3.AtMost``: the
   solver is asked for *smaller* circuits, not just satisfying ones.
   This is the proxy-descent the paper motivates, applied to the finest
   template parameter.

Every Z3 model is re-verified exhaustively before being trusted
(:func:`repro.core.miter.params_sound`).  Results are reported as the
unified :class:`~repro.core.engine.SearchOutcome` — the same type every
other engine emits — with grid/SAT counters in ``outcome.stats``.
"""

from __future__ import annotations

import time

try:
    import z3
except ImportError:  # pragma: no cover - exercised on z3-less images
    z3 = None

from .circuits import Circuit
from .engine import Candidate, SearchOutcome, UnsoundResultError, harvest
from .miter import MiterZ3, params_sound
from .templates import NonsharedTemplate, SharedTemplate, TemplateParams

__all__ = ["progressive_search", "main"]


class _Session:
    """One (exact, method, et) solving session with shared bookkeeping."""

    def __init__(self, exact: Circuit, method: str, et: int,
                 timeout_ms: int, seed: int, t_start: float, budget_s: float,
                 sink=None):
        self.exact = exact
        self.method = method
        self.et = et
        self.timeout_ms = timeout_ms
        self.seed = seed
        self.t_start = t_start
        self.budget_s = budget_s
        self.sink = sink
        self.exact_values = exact.eval_words()
        self.miters: dict[int, MiterZ3] = {}
        self.outcome = SearchOutcome(
            engine=method, benchmark=exact.name, et=et,
            stats={"grid_points_tried": 0, "sat_points": 0,
                   "smt_solve_s": 0.0},
        )

    def out_of_budget(self) -> bool:
        return time.time() - self.t_start > self.budget_s

    def miter(self, primary: int) -> MiterZ3:
        if primary not in self.miters:
            n, m = self.exact.n_inputs, self.exact.n_outputs
            tpl = (
                SharedTemplate(n, m, pit=primary)
                if self.method == "shared"
                else NonsharedTemplate(n, m, ppo=primary)
            )
            self.miters[primary] = MiterZ3(self.exact, tpl)
        return self.miters[primary]

    # -- one query ----------------------------------------------------------
    def query(
        self,
        primary: int,
        secondary: int | None,
        extra: list | None = None,
    ) -> TemplateParams | None:
        self.outcome.stats["grid_points_tried"] += 1
        miter = self.miter(primary)
        solver = z3.Solver()
        solver.set("timeout", self.timeout_ms)
        solver.set("random_seed", self.seed)
        solver.add(*miter.error_constraints(self.et))
        if secondary is not None:
            key = "its" if self.method == "shared" else "lpp"
            solver.add(*miter.proxy_constraints(**{key: secondary}))
        if extra:
            solver.add(*extra)
        # pure solver wall-time, split out from constraint building and the
        # python-side decode — the number a fleet report attributes to z3
        t_solve = time.time()
        sat = solver.check()
        self.outcome.stats["smt_solve_s"] += time.time() - t_solve
        if sat != z3.sat:
            return None
        params = miter._decode(solver.model())
        if not params_sound(miter.template, params, self.exact_values, self.et):
            raise UnsoundResultError(
                f"Z3 model failed exhaustive re-verification "
                f"({self.exact.name}, method={self.method}, ET={self.et}, "
                f"primary={primary}, secondary={secondary})"
            )
        return params

    def record(self, primary: int, secondary: int, params: TemplateParams) -> Candidate:
        tpl = self.miter(primary).template
        cand = harvest(
            tpl, params, self.exact_values, self.et, engine=self.method,
            name=f"{self.exact.name}_approx",
            wall_s=time.time() - self.t_start,
            meta={"grid_point": [primary, secondary]},
        )
        self.outcome.results.append(cand)
        self.outcome.stats["sat_points"] += 1
        if self.sink is not None:
            self.sink(cand)
        return cand

    # -- literal tightening ---------------------------------------------------
    def tighten(self, primary: int, secondary: int) -> None:
        """Binary-search total literal count (then selection count) downward."""
        miter = self.miter(primary)
        if self.method == "shared":
            use_bits = [u for row in miter.use for u in row]
            sel_bits = [s for row in miter.sel for s in row]
        else:
            use_bits = [u for bank in miter.use for row in bank for u in row]
            sel_bits = [s for row in miter.sel for s in row]

        def best_count(bits, other_cons, hi):
            lo, best = 0, None
            while lo <= hi and not self.out_of_budget():
                mid = (lo + hi) // 2
                params = self.query(
                    primary, secondary, extra=[z3.AtMost(*bits, mid)] + other_cons
                )
                if params is not None:
                    best = (mid, params)
                    hi = mid - 1
                else:
                    lo = mid + 1
            return best

        got = best_count(use_bits, [], len(use_bits))
        if got is None:
            return
        lit_count, params = got
        self.record(primary, secondary, params)
        got2 = best_count(sel_bits, [z3.AtMost(*use_bits, lit_count)], len(sel_bits))
        if got2 is not None:
            self.record(primary, secondary, got2[1])


def progressive_search(
    exact: Circuit,
    et: int,
    method: str = "shared",
    *,
    max_primary: int | None = None,
    explore_after_sat: int = 6,
    timeout_ms: int = 30_000,
    wall_budget_s: float = 600.0,
    seed: int = 0,
    tighten: bool = True,
    sink=None,
) -> SearchOutcome:
    """Run the progressive search for one benchmark and ET.

    ``method``: ``"shared"`` (the paper) or ``"xpat"`` (nonshared baseline).
    ``sink``: optional callable invoked with every sound
    :class:`~repro.core.engine.Candidate` as it is found — e.g.
    ``repro.library.OperatorStore.sink(...)`` to persist the whole Pareto
    sweep instead of keeping only ``outcome.best``.
    """
    if z3 is None:
        raise RuntimeError(
            "z3-solver is not installed; progressive_search needs the SMT "
            "backend (use repro.core.baselines / tensor_search instead)"
        )
    n, m = exact.n_inputs, exact.n_outputs
    if max_primary is None:
        max_primary = 4 * m if method == "shared" else m + 4
    sess = _Session(exact, method, et, timeout_ms, seed, time.time(),
                    wall_budget_s, sink)

    # ---- phase 1: frontier probe (secondary unconstrained) ------------------
    frontier = None
    probe = 1
    probes: list[int] = []
    while probe <= max_primary:
        probes.append(probe)
        probe *= 2
    if probes[-1] != max_primary:
        probes.append(max_primary)
    for primary in probes:
        if sess.out_of_budget():
            break
        params = sess.query(primary, None)
        if params is not None:
            frontier = primary
            sess.record(primary, primary, params)
            break
    if frontier is None:
        sess.outcome.wall_s = time.time() - sess.t_start
        return sess.outcome

    # tighten primary: walk down from the frontier until UNSAT
    lo = (frontier // 2) + 1 if frontier > 1 else 1
    best_primary = frontier
    for primary in range(frontier - 1, lo - 1, -1):
        if sess.out_of_budget():
            break
        params = sess.query(primary, None)
        if params is None:
            break
        best_primary = primary
        sess.record(primary, primary, params)

    # ---- phase 2: refine the secondary proxy at / near the frontier --------
    sec_hi = exact.n_inputs if method == "xpat" else best_primary
    best_secondary = sec_hi
    explored = 0
    for secondary in range(sec_hi - 1, 0, -1):
        if sess.out_of_budget() or explored >= explore_after_sat:
            break
        params = sess.query(best_primary, secondary)
        explored += 1
        if params is None:
            break
        best_secondary = secondary
        sess.record(best_primary, secondary, params)

    # ---- phase 3: literal tightening at the best grid point ----------------
    # minimal PIT is not minimal area: a larger pool can admit strictly
    # fewer literals (smaller / wire-only products).  Tighten at the
    # frontier, one above it, and at PIT=m (the one-product-per-output
    # corner where gate-free solutions live).
    if tighten and not sess.out_of_budget():
        sess.tighten(best_primary, best_secondary)
        if best_primary + 1 <= max_primary and not sess.out_of_budget():
            sess.tighten(best_primary + 1, best_secondary + 1)
        if method == "shared" and m > best_primary + 1 and not sess.out_of_budget():
            sess.tighten(m, 1)

    sess.outcome.wall_s = time.time() - sess.t_start
    return sess.outcome


# ---------------------------------------------------------------------------
# CLI: run a search and fill an operator library
# ---------------------------------------------------------------------------
def main(argv: list[str] | None = None) -> None:
    """``python -m repro.core.search --benchmark mul_i4 --et 1 2 4
    --library runs/lib`` — search and persist every sound result.

    One-benchmark front-end over the unified engine registry
    (:mod:`repro.core.engine`); sweeps over many benchmarks / engines are
    ``python -m repro.fleet``'s job.  ``--method auto`` uses the paper's
    SMT search when z3 is available and falls back to the annealer
    otherwise, so library filling works on solver-less images too.
    """
    import argparse

    from ..library import OperatorStore
    from .arith import parse_benchmark_name
    from .engine import ENGINE_NAMES, SearchJob, get_engine

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--benchmark", default="mul_i4",
                    help="e.g. mul_i4 (2-bit), mul_i8 (4-bit), adder_i4")
    ap.add_argument("--et", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--method", default="auto",
                    choices=["auto", *ENGINE_NAMES])
    ap.add_argument("--library", default=None,
                    help="operator-store directory to sink results into")
    ap.add_argument("--budget-s", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    try:
        kind, bits = parse_benchmark_name(args.benchmark)
    except KeyError:
        ap.error(f"unknown benchmark {args.benchmark!r} "
                 "(expected e.g. mul_i4, adder_i6, mul_i8)")
    method = args.method
    if method == "auto":
        method = "shared" if z3 is not None else "anneal"
        print(f"--method auto -> {method} (z3 {'available' if z3 else 'missing'})")

    store = OperatorStore(args.library) if args.library else None
    for et in args.et:
        job = SearchJob(benchmark=kind, bits=bits, et=et, engine=method,
                        budget_s=args.budget_s, seed=args.seed)
        outcome = get_engine(method).run(job)
        stored = 0
        if store is not None:
            sig = job.signature()
            n_before = len(store)
            for cand in outcome.results:
                store.put_circuit(cand.circuit, sig, area=cand.area,
                                  source=method, proxies=cand.proxies,
                                  params=cand.params,
                                  meta={**cand.meta, "wall_s": cand.wall_s})
            stored = len(store) - n_before
        best = outcome.best
        print(f"{args.benchmark} ET={et:3d} [{method}]: "
              + (f"best area {best.area} µm²" if best else "no sound result")
              + (f", {stored} new operator(s) -> {args.library}"
                 if store is not None else ""))


if __name__ == "__main__":
    main()
