"""Multi-bit-width operator pipeline: width as a first-class axis.

The paper's template search lives at 1–4-bit operands, but the
edge-deployment regime its operators target is W8A8.  This package makes
the bridge systematic instead of hardwired:

* :mod:`repro.precision.widths` — the width registry: code ranges, LUT
  shapes, signed-code biases, accumulator contracts, per-layer stack
  shapes.  Pure facts, numpy-only.
* :mod:`repro.precision.compose` — the composer: generalizes the 16x16
  tile/chain lowering to any target width (searched 1–4-bit blocks
  shift-add into 256x256 product tables; adders carry-chain), with
  build-time exactness identities (exact blocks must compose to exact
  tables) and the tile<->table inversion the two-level Pallas kernel
  relies on.  Numpy-only.
* :mod:`repro.precision.plans` — the planner: width selection from a
  model config, width-compiled frontiers, per-width plan-ladder
  construction.  Imports :mod:`repro.library` (and so is lazy here, the
  same PEP 562 arrangement the library package uses, keeping
  widths/compose importable from jax-free fleet workers).

Consumers: ``library/compile.py`` lowers through the composer,
``kernels/approx_matmul`` dispatches on the table side, ``quant``
generalizes its signed decomposition per width, ``qos``/``serving``
validate stacks per width, and ``launch/serve.py`` exposes ``--width``.
"""

from .compose import (
    CompositionError,
    chain_add,
    compose_blocks,
    compose_table,
    extract_tile,
    is_composed,
    tile_mul,
    tile_to_width,
    verify_exactness,
)
from .widths import (
    NATIVE_BLOCK_BITS,
    SUPPORTED_WIDTHS,
    WIDTHS,
    WidthSpec,
    exact_table,
    get_width,
    stack_shape,
    width_from_lut,
    width_from_side,
    width_from_stack,
)

# plans.py imports repro.library (which imports this package back for the
# composer) — lazy export breaks the cycle and keeps widths/compose
# importable without the library/jax stack.
_LAZY = {
    "DEFAULT_WIDTH_BITS": ".plans",
    "select_width": ".plans",
    "load_frontier": ".plans",
    "WidthFrontier": ".plans",
    "build_ladder": ".plans",
    "MixedFrontier": ".plans",
    "load_mixed_frontier": ".plans",
    "mixed_cost_matrix": ".plans",
    "select_width_map": ".plans",
    "mixed_comparison": ".plans",
    "choose_mixed_budget": ".plans",
    "build_mixed_ladder": ".plans",
    "stack_mixed_luts": ".plans",
    "exact_mixed_stacks": ".plans",
    "group_layers": ".plans",
    "width_of_key": ".plans",
}


def __getattr__(name: str):
    if name in _LAZY:
        from importlib import import_module

        value = getattr(import_module(_LAZY[name], __name__), name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "NATIVE_BLOCK_BITS",
    "SUPPORTED_WIDTHS",
    "WIDTHS",
    "WidthSpec",
    "exact_table",
    "get_width",
    "stack_shape",
    "width_from_lut",
    "width_from_side",
    "width_from_stack",
    "CompositionError",
    "chain_add",
    "compose_blocks",
    "compose_table",
    "extract_tile",
    "is_composed",
    "tile_mul",
    "tile_to_width",
    "verify_exactness",
    "DEFAULT_WIDTH_BITS",
    "select_width",
    "load_frontier",
    "WidthFrontier",
    "build_ladder",
    "MixedFrontier",
    "load_mixed_frontier",
    "mixed_cost_matrix",
    "select_width_map",
    "mixed_comparison",
    "choose_mixed_budget",
    "build_mixed_ladder",
    "stack_mixed_luts",
    "exact_mixed_stacks",
    "group_layers",
    "width_of_key",
]
