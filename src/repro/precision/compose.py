"""Operator composition: small approximate blocks -> wide behaviour tables.

Generalizes ``repro.library.compile``'s hardcoded 16x16 ``_tile_mul`` /
``_chain_add`` to any target width, the way hardware builds wide
approximate multipliers out of small approximate sub-blocks (Kulkarni
2x2s composing a 4x4; AxOSyn composing larger operators from smaller
ones):

* :func:`tile_mul` — a ``target``-bit multiplier table from a ``b``-bit
  multiplier block: split each operand into ``ceil(target/b)`` b-bit
  chunks and sum the shifted chunk products ``M[a_i, b_j] << b(i+j)``.
* :func:`chain_add` — a ``target``-bit adder table by carry-rippling
  b-bit adder blocks (the carry is folded in with a second block
  application per chunk).
* :func:`tile_to_width` / :func:`extract_tile` — the *two-level* 8-bit
  form the Pallas kernel consumes: a 256x256 product table is the exact
  shift-add of one 16x16 tile over operand nibbles, and that tile is
  exactly recoverable from the composed table (integer inversion of the
  shift-add).  ``extract_tile(tile_to_width(T)) == T`` for any int tile.

Composition for targets wider than the native 4-bit search regime is
defined *two-stage*: a block first tiles up to the 16x16 tile (stage 1,
:func:`tile_mul` with ``target=4``), then the tile shift-adds to the
target (stage 2, :func:`tile_to_width`).  This is what makes every
composed wide table mechanically consumable by the two-level kernel —
the kernel re-applies stage 2 on the MXU, four 16x16-tile LUT matmuls
combined by shift-add.

**Exactness identities, checked at build time.**  Composing the *exact*
b-bit block must reproduce the *exact* target table bit-for-bit — if it
doesn't, the chunk bookkeeping is wrong and every "approximate" result
downstream is garbage.  The first composition at each
``(op_kind, block_bits, target_bits)`` runs that identity
(:func:`verify_exactness`) and caches the verdict; a failure raises
:class:`CompositionError` immediately instead of poisoning a library.
"""

from __future__ import annotations

import numpy as np

from .widths import NATIVE_BLOCK_BITS, exact_table

__all__ = [
    "CompositionError",
    "chunk_codes",
    "tile_mul",
    "chain_add",
    "tile_to_width",
    "extract_tile",
    "is_composed",
    "compose_table",
    "compose_blocks",
    "compose_glue_bits",
    "verify_exactness",
]


class CompositionError(AssertionError):
    """A composition exactness identity failed (build-time self-check)."""


def chunk_codes(x: np.ndarray, block_bits: int, total_bits: int
                ) -> list[np.ndarray]:
    """Split ``total_bits``-bit codes into ``ceil(total/block)`` b-bit
    chunks, LSB-first: ``sum_i chunks[i] << (block_bits * i) == x``."""
    mask = (1 << block_bits) - 1
    n = -(-total_bits // block_bits)
    return [(x >> (block_bits * i)) & mask for i in range(n)]


def tile_mul(base: np.ndarray, block_bits: int,
             target_bits: int = NATIVE_BLOCK_BITS) -> np.ndarray:
    """Compose a ``target``-bit multiplier table from a b-bit block.

    ``base`` is the block's ``(2**b, 2**b)`` behaviour map.  The two
    operand chunk lists are derived from *separate* ``a`` and ``b`` code
    axes — they coincide for the square tables searched today, but the
    composer must not silently rely on that symmetry.
    """
    side = 1 << target_bits
    a_codes = np.arange(side)
    b_codes = np.arange(side)
    ai = chunk_codes(a_codes, block_bits, target_bits)
    bj = chunk_codes(b_codes, block_bits, target_bits)
    out = np.zeros((side, side), dtype=np.int64)
    for i, ac in enumerate(ai):
        for j, bc in enumerate(bj):
            out += base[ac[:, None], bc[None, :]] << (block_bits * (i + j))
    return out


def chain_add(base: np.ndarray, block_bits: int,
              target_bits: int = NATIVE_BLOCK_BITS) -> np.ndarray:
    """Compose a ``target``-bit adder table by carry-rippling b-bit blocks.

    Each chunk sum goes through the approximate adder block; the carry is
    folded in with a second block application, and chunk results
    concatenate.  The final carry sits one chunk above the last block.
    """
    mask = (1 << block_bits) - 1
    side = 1 << target_bits
    a_codes = np.arange(side)
    b_codes = np.arange(side)
    ai = chunk_codes(a_codes, block_bits, target_bits)
    bj = chunk_codes(b_codes, block_bits, target_bits)
    carry = np.zeros((side, side), dtype=np.int64)
    out = np.zeros((side, side), dtype=np.int64)
    for i, (ac, bc) in enumerate(zip(ai, bj)):
        t = base[ac[:, None], bc[None, :]]
        if i == 0:
            s, carry = t & mask, t >> block_bits
        else:
            t2 = base[t & mask, carry]
            s = t2 & mask
            carry = np.minimum(1, (t >> block_bits) + (t2 >> block_bits))
        out += s << (block_bits * i)
    return out + (carry << (block_bits * len(ai)))


# ---------------------------------------------------------------------------
# two-level form: 16x16 tile <-> wide table (the kernel contract)
# ---------------------------------------------------------------------------
def tile_to_width(tile: np.ndarray, target_bits: int = 8) -> np.ndarray:
    """Shift-add a ``(16, 16)`` tile over 4-bit operand chunks into the
    ``(2**t, 2**t)`` table — the exact composition the two-level Pallas
    kernel re-derives on the MXU."""
    assert tile.shape == (16, 16), f"expected a 16x16 tile, got {tile.shape}"
    assert target_bits % NATIVE_BLOCK_BITS == 0 and target_bits > 0
    return tile_mul(np.asarray(tile, dtype=np.int64), NATIVE_BLOCK_BITS,
                    target_bits)


def extract_tile(lut: np.ndarray) -> np.ndarray:
    """Exact inverse of :func:`tile_to_width` for an 8-bit composed table.

    With nibble planes ``a = 16*ah + al``, the composition reads
    ``LUT[a, b] = T[al, bl] + (T[al, bh] + T[ah, bl]) << 4 + T[ah, bh] << 8``,
    which inverts in integer arithmetic::

        T[0, 0] = LUT[0, 0] // 289                        (289 = 1+2*16+256)
        T[x, 0] = (LUT[x, 0] - 272 * T[0, 0]) // 17       (x < 16; 272 = 16+256)
        T[0, y] = (LUT[0, y] - 272 * T[0, 0]) // 17
        T[x, y] =  LUT[x, y] - 16 * (T[x, 0] + T[0, y]) - 256 * T[0, 0]

    Exact whenever ``lut`` really is a composed table; callers that need
    the guarantee verify ``tile_to_width(extract_tile(lut)) == lut``
    (:func:`is_composed`).  Written in pure array ops so the jnp twin in
    ``repro.kernels.approx_matmul`` stays line-for-line identical.
    """
    assert lut.shape == (256, 256), f"expected a 256x256 table, got {lut.shape}"
    lo = lut[:16, :16]
    t00 = lut[0, 0] // 289
    tx0 = (lut[:16, 0] - 272 * t00) // 17            # (16,)
    t0y = (lut[0, :16] - 272 * t00) // 17            # (16,)
    return lo - 16 * (tx0[:, None] + t0y[None, :]) - 256 * t00


def is_composed(lut: np.ndarray) -> bool:
    """Whether an 8-bit table is exactly a :func:`tile_to_width` image —
    the precondition of the Pallas two-level path (the ref backend eats
    arbitrary tables)."""
    lut = np.asarray(lut, dtype=np.int64)
    return bool(np.array_equal(tile_to_width(extract_tile(lut)), lut))


# ---------------------------------------------------------------------------
# build-time exactness identities
# ---------------------------------------------------------------------------
_VERIFIED: set[tuple[str, int, int]] = set()


def verify_exactness(op_kind: str, block_bits: int, target_bits: int) -> None:
    """Check (once per combination) that composing the *exact* block
    reproduces the *exact* target table.  Raises :class:`CompositionError`
    on any mismatch — a wrong chunk weight or carry slot must fail the
    build, not ship a silently-wrong library."""
    key = (op_kind, block_bits, target_bits)
    if key in _VERIFIED:
        return
    exact_block = exact_table(op_kind, block_bits)
    got = compose_table(exact_block, op_kind, block_bits, target_bits,
                        _verify=False)
    want = exact_table(op_kind, target_bits)
    if not np.array_equal(got, want):
        bad = int(np.abs(got - want).max())
        raise CompositionError(
            f"exactness identity failed for {op_kind} {block_bits}b -> "
            f"{target_bits}b: exact blocks composed with max deviation {bad}"
        )
    if op_kind == "mul" and target_bits > NATIVE_BLOCK_BITS:
        # the kernel contract: composed tables must invert to their tile
        tile = (exact_block if block_bits == NATIVE_BLOCK_BITS
                else tile_mul(exact_block, block_bits))
        if not np.array_equal(extract_tile(got), tile):
            raise CompositionError(
                f"tile round-trip failed for mul {block_bits}b -> "
                f"{target_bits}b (extract_tile is not inverting tile_to_width)"
            )
    _VERIFIED.add(key)


def compose_table(base: np.ndarray, op_kind: str, block_bits: int,
                  target_bits: int, *, _verify: bool = True) -> np.ndarray:
    """One b-bit block's behaviour map -> the target-width table.

    Multipliers wider than the native block width go through the
    two-stage (tile, then shift-add) form so the result is always
    kernel-consumable; adders carry-chain directly at the target width.
    """
    base = np.asarray(base, dtype=np.int64)
    assert base.shape == (1 << block_bits, 1 << block_bits), (
        f"block table shape {base.shape} does not match {block_bits}-bit codes"
    )
    if _verify:
        verify_exactness(op_kind, block_bits, target_bits)
    if op_kind == "adder":
        if block_bits == target_bits:
            return base.copy()
        return chain_add(base, block_bits, target_bits)
    if op_kind != "mul":
        raise ValueError(f"unknown op_kind {op_kind!r}")
    if block_bits == target_bits:
        return base.copy()
    tile = (base if block_bits == NATIVE_BLOCK_BITS
            else tile_mul(base, block_bits, min(target_bits,
                                                NATIVE_BLOCK_BITS)))
    if target_bits <= NATIVE_BLOCK_BITS:
        return tile
    return tile_to_width(tile, target_bits)


def compose_blocks(block_bits: int, target_bits: int) -> int:
    """How many block instances the composed operator spends — the area
    model of composition (adder glue between partial products is ignored;
    this is the documented *lower bound* — :func:`compose_glue_bits`
    bounds the glue from above, and the two together give the
    ``area_lo``/``area_hi`` bracket ``CompiledLut`` carries for the cost
    plane).

    Two-stage for wide multipliers: ``ceil(4/b)**2`` blocks per 16x16
    tile, ``(target/4)**2`` tiles.
    """
    if target_bits <= NATIVE_BLOCK_BITS:
        n = -(-target_bits // block_bits)
        return n * n
    per_tile = (-(-NATIVE_BLOCK_BITS // block_bits)) ** 2
    n_tiles = (target_bits // NATIVE_BLOCK_BITS) ** 2
    return per_tile * n_tiles


def compose_glue_bits(block_bits: int, target_bits: int) -> int:
    """Upper bound on the full-adder *bit positions* the shift-add glue
    of a composed multiplier spends — the part :func:`compose_blocks`
    deliberately ignores.

    Every stage that sums ``P`` partial products needs ``P - 1``
    two-input additions; bounding each at the stage's full product width
    (``2 × stage bits`` — real shift-add chains are narrower because the
    shifted operands only overlap partially) makes the result a sound
    ceiling: multiply by a per-bit ripple-adder cell area and add it to
    the block-count area to get ``area_hi``.
    """
    b, t = int(block_bits), int(target_bits)
    if t <= b:
        return 0
    if t <= NATIVE_BLOCK_BITS:
        n = (-(-t // b)) ** 2
        return (n - 1) * 2 * t
    # two-level form: every 16x16 tile is itself a b->4 composition
    # (its glue repeats per tile instance), then the tile products are
    # summed at the full target width
    per_tile = compose_glue_bits(b, NATIVE_BLOCK_BITS)
    n_tiles = (t // NATIVE_BLOCK_BITS) ** 2
    return n_tiles * per_tile + (n_tiles - 1) * 2 * t
