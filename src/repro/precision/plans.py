"""Per-width planning: which width a model serves at, and the plan
ladders the serving runtime walks at that width.

:mod:`repro.library.qos` is deliberately width-agnostic — it sees
operators as ``(record, compiled table)`` pairs with areas and error
metrics.  What makes a plan *4-bit* or *8-bit* is which frontier those
pairs came from and which exact reference anchors the area accounting.
This module owns that choice:

* :func:`select_width` — the model-config side: a config built with
  ``.with_approx_mlp(bits=8)`` serves W8A8, default stays W4A4.
* :func:`load_frontier` — the library side: the width-compiled frontier
  triple ``(compiled, exact_area, bits)`` (thin, explicit wrapper over
  :func:`repro.library.compile.load_mul_frontier`).
* :class:`WidthFrontier` + :func:`build_ladder` — one loaded width held
  together with its plan-ladder construction, so launchers ask for "an
  8-bit ladder over this store" in one call.

Layering: this module sits *above* :mod:`repro.library` (it imports
compile/qos) and *below* :mod:`repro.serving` (the serving controller
consumes the plans built here).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .widths import NATIVE_BLOCK_BITS, WidthSpec, get_width

__all__ = [
    "DEFAULT_WIDTH_BITS",
    "select_width",
    "load_frontier",
    "WidthFrontier",
    "build_ladder",
]

DEFAULT_WIDTH_BITS = NATIVE_BLOCK_BITS


def select_width(cfg=None, requested: int | None = None) -> WidthSpec:
    """Resolve the serving width: an explicit request wins, else the
    model config's ``approx_bits``, else the native 4-bit default.

    A mismatch between the two (config says 8, caller asks 4) raises —
    a quantized checkpoint's width is not a runtime preference.  A config
    that has not opted into LUT routing yet (``approx_mlp=False``) pins
    nothing: its ``approx_bits`` default is not a commitment.
    """
    cfg_bits = None
    if cfg is not None and getattr(cfg, "approx_mlp", False):
        cfg_bits = getattr(cfg, "approx_bits", None)
    if requested is not None and cfg_bits is not None \
            and int(requested) != int(cfg_bits):
        raise ValueError(
            f"requested width {requested} contradicts the model config's "
            f"approx_bits={cfg_bits}"
        )
    bits = requested if requested is not None else (cfg_bits or
                                                    DEFAULT_WIDTH_BITS)
    return get_width(int(bits))


def load_frontier(library, width: WidthSpec | int):
    """The width-compiled multiplier frontier of a store:
    ``(compiled, exact_area, bits)``, areas and error metrics both at the
    target width (composed, for widths above the native block width)."""
    from ..library.compile import load_mul_frontier

    w = width if isinstance(width, WidthSpec) else get_width(width)
    if w.bits == NATIVE_BLOCK_BITS:
        # native regime: keep the legacy loader semantics (block frontier)
        return load_mul_frontier(library)
    return load_mul_frontier(library, target_bits=w.bits)


@dataclass
class WidthFrontier:
    """One store's frontier, pinned to one serving width."""

    width: WidthSpec
    compiled: list            # [(OperatorRecord, CompiledLut)]
    exact_area: float
    library: str | None = None
    meta: dict = field(default_factory=dict)

    @classmethod
    def load(cls, library, width: WidthSpec | int) -> "WidthFrontier":
        w = width if isinstance(width, WidthSpec) else get_width(width)
        compiled, exact_area, bits = load_frontier(library, w)
        return cls(width=w, compiled=compiled, exact_area=float(exact_area),
                   library=str(library), meta={"frontier_bits": bits})

    def __len__(self) -> int:
        return len(self.compiled)

    def select_plan(self, sensitivities, budget: float):
        from ..library.qos import select_plan

        return select_plan(self.compiled, sensitivities, budget,
                           exact_area=self.exact_area)

    def ladder(self, n_layers: int, *, sensitivities=None, levels: int = 6):
        return build_ladder(self.compiled, n_layers,
                            exact_area=self.exact_area,
                            sensitivities=sensitivities, levels=levels)


def build_ladder(compiled, n_layers: int, *, exact_area: float,
                 sensitivities=None, levels: int = 6):
    """A serving :class:`~repro.serving.controller.PlanLadder` over one
    width's frontier — every level's LUT stack shares the frontier's
    table side, so controller moves and watcher refreshes stay
    swap-compatible (``validate_lut_stack``)."""
    from ..serving.controller import PlanLadder

    sens = (np.ones(n_layers) if sensitivities is None
            else np.asarray(sensitivities, dtype=np.float64))
    return PlanLadder.build(compiled, n_layers, exact_area=exact_area,
                            sensitivities=sens, levels=levels)
