"""Per-width planning: which width a model serves at, and the plan
ladders the serving runtime walks at that width.

:mod:`repro.library.qos` is deliberately width-agnostic — it sees
operators as ``(record, compiled table)`` pairs with areas and error
metrics.  What makes a plan *4-bit* or *8-bit* is which frontier those
pairs came from and which exact reference anchors the area accounting.
This module owns that choice:

* :func:`select_width` — the model-config side: a config built with
  ``.with_approx_mlp(bits=8)`` serves W8A8, default stays W4A4.
* :func:`load_frontier` — the library side: the width-compiled frontier
  triple ``(compiled, exact_area, bits)`` (thin, explicit wrapper over
  :func:`repro.library.compile.load_mul_frontier`).
* :class:`WidthFrontier` + :func:`build_ladder` — one loaded width held
  together with its plan-ladder construction, so launchers ask for "an
  8-bit ladder over this store" in one call.

Layering: this module sits *above* :mod:`repro.library` (it imports
compile/qos) and *below* :mod:`repro.serving` (the serving controller
consumes the plans built here).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from .widths import NATIVE_BLOCK_BITS, WidthSpec, exact_table, get_width

__all__ = [
    "DEFAULT_WIDTH_BITS",
    "select_width",
    "load_frontier",
    "WidthFrontier",
    "build_ladder",
    "MixedFrontier",
    "load_mixed_frontier",
    "mixed_cost_matrix",
    "select_width_map",
    "mixed_comparison",
    "choose_mixed_budget",
    "build_mixed_ladder",
    "stack_mixed_luts",
    "exact_mixed_stacks",
    "group_layers",
    "width_of_key",
]

DEFAULT_WIDTH_BITS = NATIVE_BLOCK_BITS


def select_width(cfg=None, requested: int | None = None) -> WidthSpec:
    """Resolve the serving width: an explicit request wins, else the
    model config's ``approx_bits``, else the native 4-bit default.

    A mismatch between the two (config says 8, caller asks 4) raises —
    a quantized checkpoint's width is not a runtime preference.  A config
    that has not opted into LUT routing yet (``approx_mlp=False``) pins
    nothing: its ``approx_bits`` default is not a commitment.
    """
    cfg_bits = None
    if cfg is not None and getattr(cfg, "approx_mlp", False):
        cfg_bits = getattr(cfg, "approx_bits", None)
    if requested is not None and cfg_bits is not None \
            and int(requested) != int(cfg_bits):
        raise ValueError(
            f"requested width {requested} contradicts the model config's "
            f"approx_bits={cfg_bits}"
        )
    bits = requested if requested is not None else (cfg_bits or
                                                    DEFAULT_WIDTH_BITS)
    return get_width(int(bits))


def load_frontier(library, width: WidthSpec | int):
    """The width-compiled multiplier frontier of a store:
    ``(compiled, exact_area, bits)``, areas and error metrics both at the
    target width (composed, for widths above the native block width)."""
    from ..library.compile import load_mul_frontier

    w = width if isinstance(width, WidthSpec) else get_width(width)
    if w.bits == NATIVE_BLOCK_BITS:
        # native regime: keep the legacy loader semantics (block frontier)
        return load_mul_frontier(library)
    return load_mul_frontier(library, target_bits=w.bits)


@dataclass
class WidthFrontier:
    """One store's frontier, pinned to one serving width."""

    width: WidthSpec
    compiled: list            # [(OperatorRecord, CompiledLut)]
    exact_area: float
    library: str | None = None
    meta: dict = field(default_factory=dict)

    @classmethod
    def load(cls, library, width: WidthSpec | int) -> "WidthFrontier":
        w = width if isinstance(width, WidthSpec) else get_width(width)
        compiled, exact_area, bits = load_frontier(library, w)
        return cls(width=w, compiled=compiled, exact_area=float(exact_area),
                   library=str(library), meta={"frontier_bits": bits})

    def __len__(self) -> int:
        return len(self.compiled)

    def select_plan(self, sensitivities, budget: float):
        from ..library.qos import select_plan

        return select_plan(self.compiled, sensitivities, budget,
                           exact_area=self.exact_area)

    def ladder(self, n_layers: int, *, sensitivities=None, levels: int = 6):
        return build_ladder(self.compiled, n_layers,
                            exact_area=self.exact_area,
                            sensitivities=sensitivities, levels=levels)


def build_ladder(compiled, n_layers: int, *, exact_area: float,
                 sensitivities=None, levels: int = 6):
    """A serving :class:`~repro.serving.controller.PlanLadder` over one
    width's frontier — every level's LUT stack shares the frontier's
    table side, so controller moves and watcher refreshes stay
    swap-compatible (``validate_lut_stack``)."""
    from ..serving.controller import PlanLadder

    sens = (np.ones(n_layers) if sensitivities is None
            else np.asarray(sensitivities, dtype=np.float64))
    return PlanLadder.build(compiled, n_layers, exact_area=exact_area,
                            sensitivities=sens, levels=levels)


# ---------------------------------------------------------------------------
# mixed-width plans: a per-layer width map over two frontiers at once
# ---------------------------------------------------------------------------
# A uniform-width serve prices every layer against one frontier.  The
# cross-layer lever the approximate-computing surveys point at is *mixed*
# assignment: sensitive layers stay on the native 4-bit tiles (the exact
# 16x16 tile is the cheapest zero-drift anchor there is), tolerant layers
# take aggressively-approximated composed 256x256 W8A8 tables whose
# composed areas undercut the exact native multiplier while their *model*
# drift stays low (the finer 8-bit quantization grid shrinks the scale
# every table error is multiplied by).  The width map is frozen per serve
# — group shapes are jit-static — so plan swaps inside a map never
# retrace, exactly like the single-width contract.

def width_of_key(key: str | None, native_bits: int = NATIVE_BLOCK_BITS) -> int:
    """Serving width encoded in a merged-frontier operator key
    (``"w8:<content key>"``); ``None`` (the exact rung of the *union*
    selection) anchors at the native width."""
    if key is None:
        return native_bits
    if not key.startswith("w") or ":" not in key:
        raise ValueError(f"not a width-namespaced operator key: {key!r}")
    return int(key[1:key.index(":")])


def group_layers(width_map, bits: int) -> tuple[int, ...]:
    """Layers serving at ``bits``, in layer order — the packing order of
    that width group's ``(n_group, side, side)`` stack."""
    return tuple(l for l, b in enumerate(width_map) if int(b) == int(bits))


@dataclass
class MixedFrontier:
    """Two (or more) width-compiled frontiers of one store, merged.

    ``compiled`` holds every frontier operator once, its record key
    namespaced with its serving width (``"w4:..."`` / ``"w8:..."``) so a
    merged plan's per-layer keys are unambiguous; ``op_bits[o]`` is the
    serving width of ``compiled[o]``.  ``by_width`` keeps the per-width
    frontiers (original keys) for uniform-plan comparisons and profile
    lookups.
    """

    by_width: dict[int, WidthFrontier]
    compiled: list                 # merged [(namespaced record, CompiledLut)]
    op_bits: np.ndarray            # (O,) serving width per merged operator
    library: str | None = None

    @property
    def widths(self) -> tuple[int, ...]:
        return tuple(sorted(self.by_width))

    @property
    def native_bits(self) -> int:
        return min(self.by_width)

    def exact_area(self, bits: int) -> float:
        return self.by_width[int(bits)].exact_area

    def exact_areas(self, width_map) -> np.ndarray:
        """Per-layer exact-multiplier areas under a width map."""
        return np.array([self.exact_area(b) for b in width_map])


def load_mixed_frontier(library, widths=(4, 8)) -> MixedFrontier:
    """Load and merge one store's frontier at every serving width.

    Raises :class:`LookupError` (from the per-width loaders) when the
    store holds no multipliers.
    """
    by_width = {int(b): WidthFrontier.load(library, int(b))
                for b in sorted(widths)}
    compiled, op_bits = [], []
    for bits, fr in sorted(by_width.items()):
        for rec, comp in fr.compiled:
            compiled.append(
                (dataclasses.replace(rec, key=f"w{bits}:{rec.key}"), comp))
            op_bits.append(bits)
    return MixedFrontier(by_width=by_width, compiled=compiled,
                         op_bits=np.asarray(op_bits), library=str(library))


def _width_cost_block(fr: WidthFrontier, sens, n_layers: int) -> np.ndarray:
    """One width's ``(L, O_w)`` drift-cost block: a measured matrix is
    taken as-is, a per-layer vector prices each operator linearly by its
    compiled-table mae."""
    s = np.asarray(sens, dtype=np.float64)
    if s.ndim == 2:
        if s.shape != (n_layers, len(fr.compiled)):
            # ValueError so a stale measured matrix surfacing through the
            # watcher refresh skips the refresh instead of killing the
            # serve (the loop catches LookupError/ValueError only)
            raise ValueError(
                f"measured cost matrix is {s.shape}, frontier wants "
                f"({n_layers}, {len(fr.compiled)}); re-price against the "
                f"refreshed frontier (sensitivity.profile.costs_for)")
        return s
    assert s.shape == (n_layers,), s.shape
    maes = np.array([comp.mae for _, comp in fr.compiled])
    return s[:, None] * maes[None, :]


def mixed_cost_matrix(mixed: MixedFrontier, sens_by_width,
                      n_layers: int) -> np.ndarray:
    """The merged ``(L, O)`` cost matrix, column-aligned with
    ``mixed.compiled``.  ``sens_by_width[bits]`` is either a measured
    ``(L, O_bits)`` matrix aligned with that width's frontier or a
    per-layer ``(L,)`` sensitivity vector (drift per unit compiled-table
    mae at that width)."""
    blocks = [_width_cost_block(fr, sens_by_width[bits], n_layers)
              for bits, fr in sorted(mixed.by_width.items())]
    return np.concatenate(blocks, axis=1)


def select_width_map(mixed: MixedFrontier, sens_by_width, budget: float,
                     n_layers: int):
    """Choose the per-layer serving width: one greedy area-descent over
    the *union* of both frontiers' rungs (exact native tile as the
    zero-drift anchor), then read each layer's width off its chosen
    operator.  Returns ``(width_map, union_plan)``; the union plan's
    total area is the mixed-width area the acceptance benchmark compares
    against uniform plans."""
    from ..library.qos import select_plan

    costs = mixed_cost_matrix(mixed, sens_by_width, n_layers)
    plan = select_plan(mixed.compiled, costs, budget,
                       exact_area=mixed.exact_area(mixed.native_bits))
    width_map = tuple(width_of_key(c.key, mixed.native_bits)
                      for c in plan.choices)
    return width_map, plan


def mixed_comparison(mixed: MixedFrontier, sens_by_width, budget: float,
                     n_layers: int):
    """The acceptance measurement: mixed-width vs best uniform-width
    composed area at one shared drift budget.  Returns
    ``(report dict, width_map, union_plan)``."""
    from ..library.qos import select_plan

    width_map, plan = select_width_map(mixed, sens_by_width, budget,
                                       n_layers)
    uniform = {}
    for bits, fr in sorted(mixed.by_width.items()):
        costs_w = _width_cost_block(fr, sens_by_width[bits], n_layers)
        p = select_plan(fr.compiled, costs_w, budget,
                        exact_area=fr.exact_area)
        uniform[bits] = p.total_area
    best_uniform = min(uniform.values())
    report = {
        "budget": float(budget),
        "mixed_area": plan.total_area,
        "uniform_area": {str(b): a for b, a in uniform.items()},
        "best_uniform_area": best_uniform,
        "advantage": best_uniform - plan.total_area,
        "width_layers": {str(b): len(group_layers(width_map, b))
                         for b in mixed.widths},
        "width_map": [int(b) for b in width_map],
    }
    return report, width_map, plan


def choose_mixed_budget(mixed: MixedFrontier, sens_by_width,
                        n_layers: int, *, levels: int = 9) -> float:
    """Pick a drift budget where the mixed assignment actually pays:
    scan the union greedy descent's breakpoint budgets and take the one
    with the largest area advantage over the best uniform plan among
    those that use every width; fall back to any both-widths budget,
    then to the full-descent budget.  Deterministic (pure plan
    arithmetic, no model evaluation)."""
    from ..library.qos import plan_ladder

    costs = mixed_cost_matrix(mixed, sens_by_width, n_layers)
    plans = plan_ladder(mixed.compiled, costs,
                        exact_area=mixed.exact_area(mixed.native_bits),
                        levels=levels)
    best: tuple[float, float] | None = None    # (advantage, budget)
    fallback: float | None = None
    for p in plans[1:]:
        report, width_map, _ = mixed_comparison(
            mixed, sens_by_width, p.budget, n_layers)
        if len(set(width_map)) < len(mixed.widths):
            continue
        if fallback is None:
            fallback = p.budget
        if report["advantage"] > 0 and (best is None
                                        or report["advantage"] > best[0]):
            best = (report["advantage"], p.budget)
    if best is not None:
        return best[1]
    if fallback is not None:
        return fallback
    return plans[-1].budget


def stack_mixed_luts(plan, records, width_map) -> dict[int, np.ndarray]:
    """Materialize a width-map plan as one ``(n_group, side, side) int32``
    stack per width group (layer order within each group).  ``key is
    None`` serves the exact product table of the layer's width."""
    by_key = {rec.key: comp for rec, comp in records}
    out: dict[int, np.ndarray] = {}
    for bits in sorted(set(int(b) for b in width_map)):
        w = get_width(bits)
        exact = exact_table("mul", bits).astype(np.int32)
        layers = group_layers(width_map, bits)
        arr = np.zeros((len(layers), w.side, w.side), dtype=np.int32)
        for j, l in enumerate(layers):
            c = plan.choices[l]
            if c.key is None:
                arr[j] = exact
            else:
                comp = by_key[c.key]
                if comp.lut.shape[-1] != w.side:
                    raise ValueError(
                        f"layer {l} is mapped to {bits}-bit but its plan "
                        f"operator {c.key} compiled to a "
                        f"{comp.lut.shape[-1]}x{comp.lut.shape[-1]} table")
                arr[j] = comp.lut
        out[bits] = arr
    return out


def exact_mixed_stacks(width_map) -> dict[int, np.ndarray]:
    """The all-exact group stacks of a width map — the mixed serving
    engine's shadow-step baseline."""
    out: dict[int, np.ndarray] = {}
    for bits in sorted(set(int(b) for b in width_map)):
        w = get_width(bits)
        exact = exact_table("mul", bits).astype(np.int32)
        n = len(group_layers(width_map, bits))
        out[bits] = np.broadcast_to(exact, (n, w.side, w.side)).copy()
    return out


def build_mixed_ladder(mixed: MixedFrontier, width_map, sens_by_width,
                       *, levels: int = 6):
    """A serving :class:`~repro.serving.controller.PlanLadder` *within* a
    frozen width map: each layer's downgrade rungs are restricted to its
    own width's operators (plus the exact table of that width as rung 0),
    and every level stacks as a ``{bits: (n_group, side, side)}`` dict —
    controller moves and watcher refreshes re-stack group arrays only,
    never changing the traced group shapes."""
    from ..library.qos import plan_ladder
    from ..serving.controller import PlanLadder

    width_map = tuple(int(b) for b in width_map)
    n_layers = len(width_map)
    costs = mixed_cost_matrix(mixed, sens_by_width, n_layers)
    allowed = (mixed.op_bits[None, :]
               == np.asarray(width_map)[:, None])
    ex = mixed.exact_areas(width_map)
    plans = plan_ladder(mixed.compiled, costs, exact_area=ex,
                        levels=levels, allowed=allowed)
    return PlanLadder(
        mixed.compiled, plans, float(ex.mean()), costs,
        requested_levels=levels,
        stacker=lambda plan: stack_mixed_luts(plan, mixed.compiled,
                                              width_map),
    )
