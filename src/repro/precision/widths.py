"""Width registry: operator bit-width as a first-class pipeline axis.

Every layer of the stack — compile, kernels, quantization, QoS, serving —
used to hard-code the 4-bit regime (codes in ``[0, 16)``, ``(16, 16)``
LUTs, bias 8).  A :class:`WidthSpec` names all of those facts once, and
the registry below is the single source the other layers read them from:

* ``side`` / ``lut_shape``: the code range and behaviour-table shape the
  LUT kernels consume;
* ``bias`` / ``qmax``: the biased-unsigned signed-code decomposition
  :func:`repro.quant.int4.quantize_intb` uses (``x ≈ (code - bias) * s``);
* ``accum_dtype`` / ``max_k``: the accumulator contract of the Pallas
  kernels — ``max_k`` is the largest contraction depth for which integer
  accumulation provably cannot overflow (table entries are bounded by
  ``max_entry``);
* ``tile_chunks``: how many 4-bit tile applications the two-level kernel
  form needs per output element (1 for the native 16x16 path).

The 8-bit regime is the edge-deployment workload (W8A8): its 256x256
tables are *composed* from searched 1–4-bit blocks by
:mod:`repro.precision.compose`, never searched directly.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "WidthSpec",
    "WIDTHS",
    "SUPPORTED_WIDTHS",
    "get_width",
    "width_from_side",
    "width_from_lut",
    "width_from_stack",
    "exact_table",
    "stack_shape",
]

# the widest operand the template searches cover; wider targets compose
NATIVE_BLOCK_BITS = 4


@dataclass(frozen=True)
class WidthSpec:
    """Everything width-dependent about one operand bit-width."""

    bits: int                 # operand width (codes are `bits`-bit unsigned)

    @property
    def side(self) -> int:
        """Code range: codes live in ``[0, side)``."""
        return 1 << self.bits

    @property
    def lut_shape(self) -> tuple[int, int]:
        """Behaviour-table shape the kernels and plans carry."""
        return (self.side, self.side)

    @property
    def bias(self) -> int:
        """Signed-code bias: ``x ≈ (code - bias) * scale``."""
        return 1 << (self.bits - 1)

    @property
    def qmax(self) -> int:
        """Largest quantized magnitude (symmetric range, code 0 unused)."""
        return self.bias - 1

    @property
    def max_entry(self) -> int:
        """Upper bound on an exact product-table entry."""
        top = self.side - 1
        return top * top

    @property
    def accum_dtype(self) -> np.dtype:
        return np.dtype(np.int32)

    @property
    def max_k(self) -> int:
        """Largest contraction depth with overflow-free int32 accumulation.

        The two-level 8-bit kernel accumulates ``tile_entry * shift_sum``
        per k (shift weights sum to 289 = 1 + 2*16 + 256), the 4-bit path
        a single table entry; both are bounded by ``max_entry``-ish terms,
        so ``(2**31 - 1) // bound`` is the provable depth.
        """
        if self.bits <= NATIVE_BLOCK_BITS:
            bound = 255          # any 8-output-bit netlist entry
        else:
            bound = 255 * 289    # worst tile entry through the shift-add
        return (2**31 - 1) // bound

    @property
    def tile_chunks(self) -> int:
        """4-bit tile applications per LUT lookup in the kernel form."""
        n = -(-self.bits // NATIVE_BLOCK_BITS)
        return n * n

    def stack_shape(self, n_layers: int) -> tuple[int, int, int]:
        """Shape of a per-layer LUT stack at this width."""
        return (n_layers, self.side, self.side)

    @property
    def benchmark_name(self) -> str:
        """The exact reference circuit for this width's multiplier."""
        return f"mul_i{2 * self.bits}"


# supported *target* widths.  4 is the native searched regime; 8 is the
# composed W8A8 regime.  (Sub-4-bit blocks are library signatures, not
# pipeline targets — they always compose up to one of these.)
WIDTHS: dict[int, WidthSpec] = {4: WidthSpec(4), 8: WidthSpec(8)}
SUPPORTED_WIDTHS: tuple[int, ...] = tuple(sorted(WIDTHS))


def get_width(bits: int) -> WidthSpec:
    try:
        return WIDTHS[int(bits)]
    except KeyError:
        raise KeyError(
            f"unsupported target width {bits}; supported: {SUPPORTED_WIDTHS}"
        ) from None


def width_from_side(side: int) -> WidthSpec:
    """Width spec from a LUT side length (16 -> 4-bit, 256 -> 8-bit)."""
    bits = int(side).bit_length() - 1
    if (1 << bits) != side:
        raise ValueError(f"LUT side {side} is not a power of two")
    return get_width(bits)


def width_from_lut(lut) -> WidthSpec:
    """Infer the operating width from a behaviour table's shape.

    Works on numpy arrays, jax arrays and tracers alike — shapes are
    static under jit, so width dispatch never breaks tracing.
    """
    if lut.ndim < 2 or lut.shape[-1] != lut.shape[-2]:
        raise ValueError(f"not a square LUT: shape {tuple(lut.shape)}")
    return width_from_side(lut.shape[-1])


def width_from_stack(stack) -> WidthSpec:
    """Infer the width of a per-layer ``(L, side, side)`` LUT stack."""
    if stack.ndim != 3:
        raise ValueError(
            f"expected a (L, side, side) stack, got shape {tuple(stack.shape)}"
        )
    return width_from_lut(stack)


def exact_table(op_kind: str, bits: int) -> np.ndarray:
    """Exact ``(2**bits, 2**bits)`` reference semantics at any width.

    The width-generic successor of ``repro.library.compile.exact_lut16``
    (which now delegates here with ``bits=4``).
    """
    a = np.arange(1 << bits, dtype=np.int64)
    if op_kind == "mul":
        return a[:, None] * a[None, :]
    if op_kind == "adder":
        return a[:, None] + a[None, :]
    raise ValueError(f"unknown op_kind {op_kind!r}")


def stack_shape(bits: int, n_layers: int) -> tuple[int, int, int]:
    return get_width(bits).stack_shape(n_layers)
