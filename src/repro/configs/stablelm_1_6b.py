"""StableLM-2 1.6B [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (MHA, kv=32) d_ff=5632 vocab=100352.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
)

REDUCED = ModelConfig(
    name="stablelm-1.6b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=512,
)
