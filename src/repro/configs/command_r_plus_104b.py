"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-plus].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, no biases.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75e6,
)

REDUCED = ModelConfig(
    name="command-r-plus-104b-reduced",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
)
