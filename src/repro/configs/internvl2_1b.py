"""InternVL2-1B [arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B].

LM backbone (Qwen2-0.5B-style): 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  The InternViT frontend is a STUB per the assignment —
``input_specs`` provides precomputed patch embeddings (B, 256, 1024),
projected into the LM width and prepended to the text sequence.
"""

from ..models.config import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    rope_theta=1e6,
    vision=VisionConfig(n_patches=256, d_vision=1024),
)

REDUCED = ModelConfig(
    name="internvl2-1b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    vision=VisionConfig(n_patches=16, d_vision=48),
)
