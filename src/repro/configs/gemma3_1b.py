"""Gemma 3 1B [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1, head_dim=256) d_ff=6912 vocab=262144,
5 local : 1 global attention pattern (local window 512), tied embeddings.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    qk_norm=True,
    local_global_every=6,   # every 6th layer is global (5:1 local:global)
    local_window=512,
    rope_theta=1e6,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma3-1b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv_heads=1,
    head_dim=32,
    d_ff=192,
    vocab_size=512,
    qk_norm=True,
    local_global_every=2,
    local_window=16,
    tie_embeddings=True,
)
