"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (the exact published configuration) and
``REDUCED`` (a same-family miniature for CPU smoke tests).  The full
configs are only ever *lowered* (ShapeDtypeStruct dry-runs); the reduced
ones actually run.
"""

from __future__ import annotations

from importlib import import_module

from ..models.config import ModelConfig

_ARCH_MODULES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "stablelm-1.6b": "stablelm_1_6b",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen3-4b": "qwen3_4b",
    "gemma3-1b": "gemma3_1b",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-3b": "rwkv6_3b",
    "internvl2-1b": "internvl2_1b",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch: str, *, reduced: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = import_module(f".{_ARCH_MODULES[arch]}", __package__)
    return mod.REDUCED if reduced else mod.CONFIG
