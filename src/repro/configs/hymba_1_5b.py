"""Hymba 1.5B [arXiv:2411.13676; hf:nvidia/Hymba-1.5B-Base].

32L d_model=1600 25H (GQA kv=5, head_dim=64) d_ff=5504 vocab=32001,
parallel attention + Mamba (SSM state 16) heads fused per layer; sliding
window on most layers with periodic global layers.
"""

from ..models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    local_global_every=16,  # a few global layers, rest sliding-window
    local_window=1024,
    ssm=SSMConfig(state_dim=16, dt_rank=48),
)

REDUCED = ModelConfig(
    name="hymba-1.5b-reduced",
    family="hybrid",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    local_global_every=2,
    local_window=16,
    ssm=SSMConfig(state_dim=8, dt_rank=8),
)
