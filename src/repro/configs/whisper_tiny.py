"""Whisper tiny [arXiv:2212.04356].

Encoder-decoder: 4+4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
The conv audio frontend is a STUB per the assignment — ``input_specs``
provides precomputed frame embeddings (B, 1500, d_model).
"""

from ..models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    encoder=EncoderConfig(n_layers=4, n_frames=1500),
)

REDUCED = ModelConfig(
    name="whisper-tiny-reduced",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    encoder=EncoderConfig(n_layers=2, n_frames=64),
)
