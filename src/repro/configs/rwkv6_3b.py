"""RWKV-6 (Finch) 3B [arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b].

32L d_model=2560 (attention-free, 40 heads of 64) d_ff=8960 vocab=65536,
data-dependent decay via LoRA.
"""

from ..models.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # d_model / rwkv.head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
)

REDUCED = ModelConfig(
    name="rwkv6-3b-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab_size=512,
    rwkv=RWKVConfig(head_dim=16, decay_lora=8),
)
