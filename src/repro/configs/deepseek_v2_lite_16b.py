"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite].

27L d_model=2048 16H, MLA kv_lora=512, MoE 64 routed top-6 + 2 shared,
d_ff_expert=1408, vocab=102400.

Assignment-bracket notes followed here: "MoE 64e top-6, 2 shared"
(the full V2 uses 160 routed experts; the Lite model uses 64 — we follow
the bracket's 64e).  The real model's first dense layer (d_ff=10944) is
kept MoE for scan-over-layers homogeneity; noted in DESIGN.md.
"""

from ..models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
)

REDUCED = ModelConfig(
    name="deepseek-v2-lite-16b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=512,
    mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=1),
)
