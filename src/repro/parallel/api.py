"""Logical-axis sharding: the one place mesh layout decisions live.

Model code annotates tensors with *logical* axis names (``'batch'``,
``'heads'``, ``'ffn'``, …).  A :class:`ShardingContext` resolves those to
mesh axes under the active mesh, with a divisibility guard: a logical axis
whose dimension does not divide by its mesh extent falls back to
replication instead of producing uneven shards (e.g. whisper's prime-ish
vocab).  Outside any context every annotation is a no-op, so the same
model code runs single-device tests and 512-chip dry-runs unchanged.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (in priority order; filtered by mesh)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),        # data parallel (pod is outer DP)
    "fsdp": ("data",),               # weight/optimizer-state sharding
    "model": ("model",),             # tensor parallel
    "expert": ("data",),             # expert parallelism (MoE dispatch)
    "expert_fsdp": ("data",),        # expert-stack weight sharding
    "cache_seq": ("data",),          # context-parallel long KV caches
}


def axis_extent(name: str) -> int:
    """Mesh extent a logical axis would shard over (1 outside a context)."""
    ctx = current()
    if ctx is None:
        return 1
    extent = 1
    for a in ctx.rules.get(name, ()):
        if a in ctx.mesh.axis_names:
            extent *= ctx.mesh.shape[a]
    return extent


@dataclass(frozen=True)
class ShardingContext:
    mesh: Mesh
    rules: dict[str, tuple[str, ...]] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def resolve(self, shape: tuple[int, ...], axes: tuple[str | None, ...]) -> P:
        """Logical names -> PartitionSpec with divisibility fallback."""
        assert len(shape) == len(axes), (shape, axes)
        parts: list = []
        for dim, name in zip(shape, axes):
            if name is None:
                parts.append(None)
                continue
            mesh_axes = tuple(
                a for a in self.rules.get(name, ()) if a in self.mesh.axis_names
            )
            extent = 1
            for a in mesh_axes:
                extent *= self.mesh.shape[a]
            if not mesh_axes or extent <= 1 or dim % extent != 0:
                parts.append(None)  # replicate rather than shard unevenly
            else:
                parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        return P(*parts)


_state = threading.local()


def current() -> ShardingContext | None:
    return getattr(_state, "ctx", None)


@contextmanager
def activate(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    prev = current()
    _state.ctx = ShardingContext(mesh, {**DEFAULT_RULES, **(rules or {})})
    try:
        yield _state.ctx
    finally:
        _state.ctx = prev


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate ``x`` with logical axes; no-op outside a context."""
    ctx = current()
    if ctx is None:
        return x
    spec = ctx.resolve(x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def spec_for_logical(shape: tuple[int, ...], axes: tuple[str | None, ...]) -> P:
    """Resolve a spec under the active context (replicated if none)."""
    ctx = current()
    if ctx is None:
        return P()
    return ctx.resolve(shape, axes)
