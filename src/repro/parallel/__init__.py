from .api import (
    ShardingContext,
    activate,
    axis_extent,
    current,
    shard,
    spec_for_logical,
)

__all__ = [
    "ShardingContext",
    "activate",
    "axis_extent",
    "current",
    "shard",
    "spec_for_logical",
]
