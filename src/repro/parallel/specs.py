"""Parameter / batch / cache PartitionSpec resolution.

Weight sharding follows the 2-D scheme (DESIGN.md §7): the TP dimension
(heads / ffn / vocab) shards over ``model``; the other large dimension
shards over ``data`` (FSDP / ZeRO-3 — GSPMD inserts the weight
all-gathers in forward and reduce-scatters in backward).  Optimizer
moments inherit the parameter specs, so optimizer state is fully
distributed.  Every rule passes through the divisibility guard in
:class:`repro.parallel.api.ShardingContext` — a dimension that does not
divide falls back to replication (e.g. whisper's 51865 vocab).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig
from .api import ShardingContext

# leaf-name -> logical axes, by array rank.  'F' = fsdp(data), 'M' = model.
_IN_OUT = ("fsdp", "model")    # (d_in, d_out) projections
_OUT_IN = ("model", "fsdp")    # (d_out, d_in) / second projections
_BY_NAME: dict[str, tuple[str | None, ...]] = {
    "embed": ("model", "fsdp"),       # (vocab, d_model)
    "lm_head": _IN_OUT,               # (d_model, vocab)
    "wq": _IN_OUT, "wk": _IN_OUT, "wv": _IN_OUT, "wg": _IN_OUT,
    "wr": _IN_OUT, "ck": _IN_OUT, "cr": _IN_OUT, "win": _IN_OUT,
    "wdkv": _IN_OUT, "wuk": _IN_OUT, "wuv": _IN_OUT,
    "w1": _IN_OUT, "w3": _IN_OUT, "w_a": _IN_OUT, "wdt1": _IN_OUT,
    "wB": _IN_OUT, "wC": _IN_OUT,
    "wo": _OUT_IN, "w2": _OUT_IN, "cv": _OUT_IN, "wout": _OUT_IN,
    "w_b": _OUT_IN, "wdt2": _OUT_IN,
    "router": ("fsdp", None),
    "vis_proj": (None, "fsdp"),
}
# MoE expert stacks: expert-parallel (E over data) when E divides the data
# extent — expert compute then needs zero weight collectives and dispatch
# becomes the classic MoE all-to-all; otherwise FSDP over the d_model dim.
_MOE_3D_EP = {"w1": ("expert_fsdp", None, "model"),
              "w3": ("expert_fsdp", None, "model"),
              "w2": ("expert_fsdp", "model", None)}
_MOE_3D = {"w1": (None, "fsdp", "model"), "w3": (None, "fsdp", "model"),
           "w2": (None, "model", "fsdp")}


def param_specs(ctx: ShardingContext, params_shapes: Any) -> Any:
    """ShapeDtypeStruct tree -> PartitionSpec tree (same structure)."""

    def resolve(path, leaf) -> P:
        names = [
            p.key for p in path
            if isinstance(p, (jax.tree_util.DictKey,))
        ]
        name = names[-1] if names else ""
        shape = leaf.shape
        if len(shape) < 2:
            return P()
        # scan-stacked layer params carry a leading L axis -> prepend None
        lead = ()
        core_shape = shape
        if "layers" in names or "enc_layers" in names or "dec_layers" in names:
            lead = (None,)
            core_shape = shape[1:]
        if len(core_shape) == 3 and name in _MOE_3D:
            ep_extent = 1
            for a in ctx.rules.get("expert_fsdp", ()):
                if a in ctx.mesh.axis_names:
                    ep_extent *= ctx.mesh.shape[a]
            ep = ep_extent > 1 and core_shape[0] % ep_extent == 0
            axes = (_MOE_3D_EP if ep else _MOE_3D)[name]
        elif name in _BY_NAME and len(core_shape) == len(_BY_NAME[name]):
            axes = _BY_NAME[name]
        elif len(core_shape) >= 2:
            axes = ("fsdp", "model") + (None,) * (len(core_shape) - 2)
        else:
            axes = (None,) * len(core_shape)
        spec = ctx.resolve(core_shape, axes)
        return P(*lead, *spec)

    return jax.tree_util.tree_map_with_path(resolve, params_shapes)


def opt_specs(ctx: ShardingContext, params_shapes: Any, p_specs: Any) -> dict:
    """Optimizer state mirrors the parameter specs (f32 moments)."""
    return {
        "mu": p_specs,
        "nu": p_specs,
        "step": P(),
    }


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardingContext) -> dict:
    dp = "batch"
    out: dict[str, P] = {}
    if shape.kind == "decode":
        out["tokens"] = ctx.resolve((shape.global_batch, 1), (dp, None))
    else:
        out["tokens"] = ctx.resolve((shape.global_batch, shape.seq_len), (dp, None))
    if cfg.family == "audio" and shape.kind != "decode":
        out["frames"] = ctx.resolve(
            (shape.global_batch, cfg.encoder.n_frames, cfg.d_model), (dp, None, None)
        )
    if cfg.family == "vlm" and shape.kind != "decode":
        out["patches"] = ctx.resolve(
            (shape.global_batch, cfg.vision.n_patches, cfg.vision.d_vision),
            (dp, None, None),
        )
    return out


def cache_specs(cfg: ModelConfig, caches_shapes: list, ctx: ShardingContext) -> list:
    """Decode-cache specs: batch over data when divisible; otherwise the
    cache sequence axis goes context-parallel over data (long_500k, B=1)."""

    def one(cache_shapes: dict) -> dict:
        specs = {}
        for k, leaf in cache_shapes.items():
            shape = leaf.shape
            batch_div = ctx.resolve((shape[0],), ("batch",))[0] is not None
            seq_name = None if batch_div else "cache_seq"
            if k in ("k", "v", "xk", "xv"):
                specs[k] = ctx.resolve(shape, ("batch", seq_name, "model", None))
            elif k == "ckv":
                specs[k] = ctx.resolve(shape, ("batch", seq_name, "model"))
            elif k == "kr":
                specs[k] = ctx.resolve(shape, ("batch", seq_name, None))
            elif k == "ssm":
                specs[k] = ctx.resolve(shape, ("batch", "model", None))
            elif k == "wkv":
                specs[k] = ctx.resolve(shape, ("batch", "model", None, None))
            else:  # x_tm / x_cm and other small states
                specs[k] = ctx.resolve(shape, ("batch",) + (None,) * (len(shape) - 1))
        return specs

    return [one(c) for c in caches_shapes]


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
