"""Training launcher: config -> mesh -> (restore?) -> step loop -> checkpoints.

CPU-runnable end to end with ``--reduced`` (the CI path and the
``examples/train_small.py`` driver); on a real cluster the same script runs
under ``jax.distributed`` with the production mesh — the data pipeline is
host-local by construction and checkpoints restore under any divisible
mesh (elastic rescale; see train/checkpoint.py).

Fault tolerance: checkpoint every ``--ckpt-every`` steps (atomic), resume
from LATEST automatically; a SIGTERM-killed run restarts bit-identically
(tests/test_checkpoint.py).  Straggler mitigation at scale: synchronous
data parallelism with deterministic host-local input generation leaves no
data-service stragglers; slow-chip stragglers are handled above this layer
(re-slicing the pod), documented in README §Operations.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .. import parallel
from ..configs import ARCH_IDS, get_config
from ..models import init_model
from ..train import (
    DataState, OptimizerConfig, checkpoint, init_opt_state, make_train_step,
    next_batch,
)
from .mesh import make_smoke_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(1, args.steps // 10),
                              total_steps=args.steps)
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(args.seed)

    with parallel.activate(mesh), mesh:
        params = init_model(cfg, key)
        opt_state = init_opt_state(params)
        ds = DataState(seed=args.seed, step=0)
        start = 0
        if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
            params, opt_state, meta, start = checkpoint.restore(
                args.ckpt_dir, params, opt_state)
            ds = DataState.from_dict(meta["data_state"])
            print(f"resumed from step {start}")

        step_fn = jax.jit(make_train_step(
            cfg, opt_cfg, microbatches=args.microbatches, remat=args.remat))

        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
              f"steps={args.steps} batch={args.batch}x{args.seq}")

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            batch, ds = next_batch(cfg, args.batch, args.seq, ds)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                dt = (time.time() - t0) / (step + 1 - start)
                print(f"step {step+1:5d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f} ms/step",
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, step + 1, params, opt_state,
                                data_state=ds.as_dict())

        if args.ckpt_dir:
            checkpoint.save(args.ckpt_dir, args.steps, params, opt_state,
                            data_state=ds.as_dict())
        first = np.mean(losses[: max(1, len(losses) // 10)])
        last = np.mean(losses[-max(1, len(losses) // 10):])
        print(f"done: loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
