"""Production mesh construction.

A *function*, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
outer data-parallelism (batch shards over pod x data via the 'batch'
logical rule), so cross-pod traffic is gradient all-reduce only — the
layout that survives slow inter-pod links.
"""

from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; older jax defaults to Auto anyway
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:  # pragma: no cover - exercised on older jax images

    def _axis_kw(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    n = jax.device_count()
    return jax.make_mesh((1, n), ("data", "model"), **_axis_kw(2))


def make_fleet_mesh():
    """All local devices on one ``data`` axis — the search-fleet layout.

    ``repro.core.tensor_search`` shards its candidate population over
    ``data``, so a single fleet worker drives every chip it can see; the
    per-generation elite selection is the only cross-device collective.
    """
    return jax.make_mesh((jax.device_count(),), ("data",), **_axis_kw(1))


# TPU v5e hardware constants (per chip) — the roofline denominators.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW_PER_LINK = 50e9         # bytes/s/link (~45-50 GB/s on v5e)
