"""Compiled-artifact analysis: roofline terms from the dry-run.

``compiled.cost_analysis()`` gives HLO FLOPs and bytes accessed;
collective bytes are *not* in cost_analysis, so we parse the optimized
HLO text and sum wire bytes of every collective op, using ring-algorithm
wire factors with the participant count taken from ``replica_groups``.

Terms (per step, whole mesh -> seconds):

    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * hbm_bw)
    collective = wire_bytes / (chips * ici_bw)
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from . import mesh as hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?\S+\s*=\s*(?P<otype>\([^)]*\)|\S+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+[0-9]*)\[(?P<dims>[0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(?P<body>.*?)\}\}?")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(?P<g>\d+),(?P<n>\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group("n"))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group("body").split("}", 1)[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip()]
        return max(1, len(ids))
    return 1


# ring-algorithm wire factors: bytes on the wire per participant,
# as a multiple of the (per-shard input / full output) payload.
def _wire_bytes(op: str, out_bytes: int, group: int) -> float:
    if op == "collective-permute":  # uses source_target_pairs, not groups
        return float(out_bytes)
    if group <= 1:
        return 0.0
    f = (group - 1) / group
    if op == "all-gather":
        return f * out_bytes                 # output is the gathered buffer
    if op == "all-reduce":
        return 2.0 * f * out_bytes           # reduce-scatter + all-gather
    if op == "reduce-scatter":
        return f * out_bytes * group         # output is the scattered shard
    if op == "all-to-all":
        return f * out_bytes
    if op == "collective-permute":
        return float(out_bytes)
    return 0.0


@dataclass
class CollectiveStats:
    counts: dict[str, int] = field(default_factory=dict)
    payload_bytes: dict[str, float] = field(default_factory=dict)
    wire_bytes_total: float = 0.0

    def add(self, op: str, payload: int, wire: float) -> None:
        self.counts[op] = self.counts.get(op, 0) + 1
        self.payload_bytes[op] = self.payload_bytes.get(op, 0.0) + payload
        self.wire_bytes_total += wire


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        if "-done(" in line:  # async pair: count the -start only
            continue
        op = m.group("op")
        out_bytes = _shape_bytes(m.group("otype"))
        group = _group_size(line)
        stats.add(op, out_bytes, _wire_bytes(op, out_bytes, group))
    return stats


@dataclass
class Roofline:
    """All HLO-derived quantities are PER DEVICE (jax's cost_analysis on an
    SPMD module reports the per-partition program); ``model_flops`` is the
    GLOBAL analytic 6·N·D / 2·N·D."""

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float        # per device
    hlo_bytes: float        # per device
    wire_bytes: float       # per device (ring wire bytes)
    model_flops: float      # global
    bytes_per_device: float | None
    collectives: dict[str, int]
    model_bytes: float = 0.0  # global minimum HBM traffic (decode: weights+cache)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / hw.ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def t_star(self) -> float:
        """Ideal step time: useful FLOPs at peak, or (for bandwidth-bound
        steps like decode) the unavoidable HBM traffic at full bandwidth —
        whichever bound is tighter."""
        return max(
            self.model_flops / (self.chips * hw.PEAK_FLOPS_BF16),
            self.model_bytes / (self.chips * hw.HBM_BW),
        )

    @property
    def roofline_fraction(self) -> float:
        """ideal step time / modelled step time (max-of-terms = perfect
        overlap; the sum-of-terms pessimistic variant is in EXPERIMENTS)."""
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_star / t_step if t_step else 0.0

    def as_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes, "wire_bytes": self.wire_bytes,
            "model_flops": self.model_flops,
            "bytes_per_device": self.bytes_per_device,
            "model_bytes": self.model_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "t_star": self.t_star,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> Roofline:
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    stats = collective_stats(compiled.as_text())
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
    except Exception:
        mem = None
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, wire_bytes=stats.wire_bytes_total,
        model_flops=model_flops, bytes_per_device=mem,
        collectives=stats.counts,
    )


# ---------------------------------------------------------------------------
# QoS layer-plan reporting: which approximate operator each layer runs on
# ---------------------------------------------------------------------------
def plan_report(plan) -> str:
    """Human-readable per-layer operator table for a QoS
    :class:`~repro.library.qos.LayerPlan` — operator key, area vs the exact
    baseline, compiled-table error, and the plan-level totals."""
    lines = [
        f"{'layer':>5s}  {'operator':<18s} {'area µm²':>9s} {'Δarea':>7s} "
        f"{'pred.drift':>10s}"
    ]
    for c in plan.choices:
        name = c.key if c.key is not None else "exact"
        saving = 1.0 - c.area / plan.exact_area if plan.exact_area else 0.0
        lines.append(
            f"{c.layer:>5d}  {name:<18s} {c.area:>9.3f} {100 * saving:>6.1f}% "
            f"{c.predicted_drift:>10.5f}"
        )
    lines.append(
        f"total area {plan.total_area:.3f} µm² vs exact "
        f"{plan.exact_total_area:.3f} µm² "
        f"({100 * plan.area_saving:.1f}% saving), predicted drift "
        f"{plan.predicted_total:.5f} <= budget {plan.budget:.5f}"
    )
    return "\n".join(lines)


def sensitivity_report(profile) -> str:
    """Human-readable measured per-layer sensitivity table for a
    :class:`~repro.sensitivity.profile.SensitivityProfile` — one column
    per profiled serving width (drift per unit compiled-table mae),
    printed next to the per-layer operator table so a plan can be read
    against the measurements that priced it."""
    widths = profile.widths
    head = f"{'layer':>5s}"
    for b in widths:
        head += f"  {'w' + str(b) + ' drift/mae':>14s}"
    lines = [f"measured sensitivities: {profile.model} "
             f"({profile.n_layers} layers)", head]
    sens = {b: profile.sensitivities(b) for b in widths}
    for l in range(profile.n_layers):
        row = f"{l:>5d}"
        for b in widths:
            row += f"  {sens[b][l]:>14.5f}"
        lines.append(row)
    for b in widths:
        hot = int(sens[b].argmax())
        lines.append(
            f"w{b}: most sensitive layer {hot} "
            f"({sens[b][hot]:.5f}), least {int(sens[b].argmin())} "
            f"({sens[b].min():.5f})"
            + (f", measured cost matrix over "
               f"{len(profile.costs[b][0])} operator(s)"
               if b in profile.costs else "")
        )
    return "\n".join(lines)


def model_flops_train(n_active_params: int, tokens: int) -> float:
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: int, tokens: int) -> float:
    return 2.0 * n_active_params * tokens
