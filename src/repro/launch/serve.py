"""Serving launcher: batched autoregressive decode with KV caches.

CPU-runnable with ``--reduced``; the same serve_step is what the dry-run
lowers for the decode_32k / long_500k cells on the production mesh.
Requests are synthetic prompts; decoding is greedy.  Throughput and
per-token latency are reported at the end.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import parallel
from ..configs import ARCH_IDS, get_config
from ..models import decode_fn, init_caches, init_model
from ..train.data import DataState, synth_batch
from .mesh import make_smoke_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(args.seed)

    with parallel.activate(mesh), mesh:
        params = init_model(cfg, key)
        total = args.prompt_len + args.gen_len
        caches = init_caches(cfg, args.batch, total)
        step = decode_fn(cfg)
        if cfg.family == "audio":
            from ..models.encdec import prefill_cross
            frames = synth_batch(cfg, args.batch, 1, DataState(args.seed, 0))["frames"]
            caches = prefill_cross(cfg, params, frames, caches)

        jit_step = jax.jit(
            lambda p, c, t, pos: step(cfg, p, c, t, pos),
            donate_argnums=(1,),
        )

        prompts = synth_batch(cfg, args.batch, args.prompt_len,
                              DataState(args.seed, 1))["tokens"]
        # prefill by stepping the prompt (decode-path prefill keeps one code path)
        tok = prompts[:, :1]
        t0 = time.time()
        for t in range(args.prompt_len):
            logits, caches = jit_step(params, caches, prompts[:, t:t+1], jnp.int32(t))
        generated = []
        for t in range(args.prompt_len, total):
            tok = jnp.argmax(logits, axis=-1)[:, None]
            generated.append(tok)
            logits, caches = jit_step(params, caches, tok, jnp.int32(t))
        dt = time.time() - t0
        toks = args.batch * total
        print(f"arch={cfg.name} batch={args.batch} "
              f"{toks} tokens in {dt:.2f}s = {toks/dt:.1f} tok/s "
              f"({dt/total*1e3:.1f} ms/step)")
        out = jnp.concatenate(generated, axis=1)
        print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
