"""Serving launcher: a thin CLI over :mod:`repro.serving`.

CPU-runnable with ``--reduced``; the same decode step is what the dry-run
lowers for the decode_32k / long_500k cells on the production mesh.
Requests are synthetic prompts on a deterministic load profile
(steady / ramp / spike); decoding is greedy.  Prefill and decode
throughput are reported *separately* — prefill here is a python-loop over
the prompt through the decode step, so folding it into one number would
silently understate decode throughput.

Three serving modes:

* plain                — exact decode, no operator library.
* ``--library``        — one QoS plan selected at startup (as before).
* ``--adaptive``       — the plan is a runtime input: a QoS controller
  walks the operator frontier between batches (latency target vs drift
  budget), and ``--watch-library`` additionally picks up operators a
  background ``python -m repro.fleet`` sweep adds mid-serve.  The decode
  step never retraces across swaps.

``--continuous`` switches any mode from batch-boundary admission to
continuous batching over a fixed pool of ``--max-slots`` decode slots
with paged KV (``--page-size`` / ``--pages``): requests join and leave
the running batch per step, classes declaring a latency SLO
(``--qos-class "gold:0.02@8ms,batch:0.2"``) preempt lower tiers, and
``--prompt-dist "bimodal:4-16"`` makes arrivals heterogeneous in length.
``--compare-fixed`` runs the fixed-batch engine on the *same* profile
first and emits paired rows; ``--replicas N`` fronts N engines (sharing
one watched store, per-replica plan state) with a class-affinity router.

``--width`` picks the LUT operand width for any library mode: 4 serves
W4A4 on the native 16x16 tables, 8 serves W8A8 on 256x256 tables composed
from the same searched blocks (:mod:`repro.precision`); all three modes
and the watcher work at either width.

Measured sensitivities, QoS classes, mixed width
(:mod:`repro.sensitivity`):

* ``--profile p.json`` prices plans with a *measured* per-layer
  sensitivity profile (``python -m repro.sensitivity.profile``) instead
  of the uniform linear model;
* ``--qos-class "gold:0.02,batch:0.2"`` declares per-request traffic
  tiers with their own drift budgets — per-class queues drain in priority
  order and each batch decodes on its class's ladder level (with
  ``--adaptive`` the load-driven global level still caps everyone);
  ``--class-mix`` shapes the synthetic arrival mix;
* ``--mixed-width`` serves a per-layer width map — sensitive layers on
  native 16x16 tiles, tolerant layers on composed 256x256 W8A8 tables —
  chosen by one greedy descent over both frontiers at once
  (``--mixed-budget``, default auto).  The decode step still traces
  exactly once; the bench summary reports the mixed plan's area against
  the best uniform-width plan at the same budget.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .. import parallel
from ..configs import ARCH_IDS, get_config
from ..models import init_model
from ..obs.export import dump_metrics, write_bench_json
from ..obs.health import HealthPlane, state_rank
from ..obs.metrics import MetricRegistry, get_registry
from ..obs.trace import configure as configure_tracing
from ..serving import (
    ContinuousServingEngine,
    ControllerConfig,
    LibraryWatcher,
    PlanLadder,
    QoSController,
    Replica,
    ReplicaRouter,
    ServingEngine,
    Telemetry,
    make_profile,
    parse_prompt_dist,
)
from ..serving.loadgen import PROFILES
from .mesh import make_smoke_mesh


def _frontier(library: str, width):
    from ..precision.plans import load_frontier

    try:
        return load_frontier(library, width)
    except LookupError as e:
        raise SystemExit(str(e))


def _startup_plan(cfg, compiled, exact_area, budget: float, sens=None):
    """The one-shot selection: uniform sensitivities (mae16-unit budget)
    unless a measured ``--profile`` cost model is at hand."""
    from ..library import select_plan
    from .analysis import plan_report

    plan = select_plan(compiled,
                       np.ones(cfg.n_layers) if sens is None else sens,
                       budget, exact_area=exact_area)
    print(f"QoS plan ({len(compiled)} frontier operator(s)):")
    print(plan_report(plan))
    if all(c.key is None for c in plan.choices):
        print("note: budget admits no downgrade — every layer stays exact "
              "(try a larger --qos-budget)")
    return plan


def _budget_level(ladder, budget: float) -> int:
    """Deepest ladder level whose selection budget fits ``budget`` — the
    startup level of a non-adaptive mixed-width serve."""
    lvl = 0
    for i, p in enumerate(ladder.plans):
        if p.budget <= budget:
            lvl = i
    return lvl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--library", default=None,
                    help="approximate-operator store; routes MLP matmuls "
                         "through QoS-selected per-layer LUT multipliers")
    ap.add_argument("--width", type=int, choices=(4, 8), default=4,
                    help="LUT operand width: 4 = native W4A4 (16x16 "
                         "tables), 8 = W8A8 — searched blocks composed "
                         "into 256x256 tables (repro.precision)")
    ap.add_argument("--qos-budget", type=float, default=None,
                    help="startup QoS budget (non-adaptive mode only). "
                         "Without --profile: summed compiled-table mae16 "
                         "units, default 50.0.  With --profile the plan is "
                         "priced in measured-drift (mean |Δlogit|) units, "
                         "so the budget must be given explicitly — the "
                         "mae16-scaled default would admit the full "
                         "greedy descent.")
    # ---- measured sensitivities / QoS classes / mixed width ---------------
    ap.add_argument("--profile", default=None,
                    help="measured SensitivityProfile JSON (produced by "
                         "python -m repro.sensitivity.profile); plans and "
                         "ladders price operators with measured per-layer "
                         "sensitivities instead of the uniform model")
    ap.add_argument("--qos-class", default=None, metavar="SPEC",
                    help='per-request QoS classes with drift budgets, e.g. '
                         '"gold:0.02,std:0.05,batch:0.2" (listed order = '
                         'drain priority); requires --library')
    ap.add_argument("--class-mix", default=None, metavar="SPEC",
                    help='synthetic arrival mix over the declared classes, '
                         'e.g. "gold:0.1,std:0.6,batch:0.3" (default: '
                         'equal shares)')
    ap.add_argument("--mixed-width", action="store_true",
                    help="serve a per-layer width map (native 16x16 tiles "
                         "for sensitive layers, composed 256x256 W8A8 "
                         "tables for tolerant ones) chosen jointly over "
                         "both frontiers; incompatible with --width 8")
    ap.add_argument("--mixed-budget", type=float, default=None,
                    help="drift budget for the width-map selection "
                         "(default: auto — the greedy breakpoint with the "
                         "largest mixed-vs-uniform area advantage)")
    # ---- load profile -----------------------------------------------------
    ap.add_argument("--schedule", choices=PROFILES, default="steady",
                    help="synthetic load profile shape")
    ap.add_argument("--ticks", type=int, default=1,
                    help="load-profile length in arrival ticks")
    ap.add_argument("--per-tick", type=int, default=None,
                    help="arrivals per tick (steady) / peak (ramp, spike); "
                         "default: --batch")
    ap.add_argument("--prompt-dist", default=None, metavar="SPEC",
                    help='heterogeneous prompt lengths, "kind:lo-hi" with '
                         'kind uniform|bimodal (e.g. "bimodal:4-16"); '
                         "deterministic per seed, truncation-stable vs "
                         "fixed-length prompts")
    # ---- continuous batching ---------------------------------------------
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: token-level admission over a "
                         "fixed slot pool with paged KV; requests join/"
                         "leave per step, SLO classes (--qos-class "
                         '"gold:0.02@8ms") preempt lower tiers')
    ap.add_argument("--max-slots", type=int, default=None,
                    help="decode-slot pool size (default: --batch)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="KV page size in cache positions")
    ap.add_argument("--pages", type=int, default=None,
                    help="KV page-pool size (default: every slot's worst "
                         "case plus one slot of preemption headroom)")
    ap.add_argument("--steps-per-tick", type=int, default=None,
                    help="decode steps between arrival ticks "
                         "(default: --gen-len)")
    ap.add_argument("--compare-fixed", action="store_true",
                    help="also serve the same profile on the fixed-batch "
                         "engine and emit paired fixed-vs-continuous rows "
                         "in the bench summary")
    ap.add_argument("--replicas", type=int, default=1,
                    help=">=2 fronts that many continuous engines with a "
                         "class-affinity router sharing one watched store")
    # ---- adaptive runtime -------------------------------------------------
    ap.add_argument("--adaptive", action="store_true",
                    help="QoS controller walks the operator frontier between "
                         "batches (requires --library)")
    ap.add_argument("--target-ms-per-step", type=float, default=50.0,
                    help="controller latency target (EWMA decode ms/step)")
    ap.add_argument("--drift-budget", type=float, default=0.05,
                    help="mean |Δlogit| allowed vs the exact shadow step")
    ap.add_argument("--shadow-every", type=int, default=4,
                    help="sample the exact shadow step every N batches")
    ap.add_argument("--ladder-levels", type=int, default=6,
                    help="plan-ladder resolution across the frontier")
    ap.add_argument("--watch-library", action="store_true",
                    help="poll the store between batches and hot-swap in "
                         "operators a background fleet sweep adds")
    ap.add_argument("--poll-s", type=float, default=2.0,
                    help="minimum seconds between store version polls")
    # ---- output -----------------------------------------------------------
    ap.add_argument("--telemetry", default=None,
                    help="write the full telemetry dump (JSON) here")
    ap.add_argument("--bench-json", default=None,
                    help="write the telemetry summary (tok/s, ms/step, swap "
                         "count) here, e.g. BENCH_serve.json")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="observability trace dir: batch/prefill/decode "
                         "spans + a metric snapshot land there; point it at "
                         "a fleet run's trace dir for one merged view "
                         "(python -m repro.obs summary --trace DIR)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve live GET /metrics (Prometheus text), "
                         "/healthz (health state as HTTP status) and "
                         "/costs.json (cost-dividend attribution; needs "
                         "--trace) on 127.0.0.1:PORT for the duration of "
                         "the serve; 0 picks a free port")
    ap.add_argument("--health", action="store_true",
                    help="run the SLO health plane: multi-window burn-rate "
                         "monitors over the declared --qos-class SLOs and "
                         "drift budgets, streaming anomaly detectors "
                         "attributed to swap/refresh/control events, and a "
                         "flight recorder; the state lands in the bench "
                         "summary for python -m repro.obs health")
    ap.add_argument("--postmortem-dir", default=None, metavar="DIR",
                    help="dump atomic post-mortem bundles (flight-recorder "
                         "ring + health state) here on SLO breach, fired "
                         "anomaly, or crash; implies --health "
                         "(python -m repro.obs postmortem --dir DIR)")
    args = ap.parse_args()

    if args.postmortem_dir:
        args.health = True
    if args.trace:
        configure_tracing(args.trace)

    if args.adaptive and not args.library:
        raise SystemExit("--adaptive requires --library (the frontier to walk)")
    if args.watch_library and not args.library:
        raise SystemExit("--watch-library requires --library")
    if args.qos_class and not args.library:
        raise SystemExit("--qos-class requires --library (classes pick "
                         "ladder levels)")
    if args.qos_class and not args.profile:
        raise SystemExit(
            "--qos-class budgets are measured-drift (mean |Δlogit|) units "
            "and cap ladder levels by predicted drift — without a measured "
            "--profile the ladder's predictions are in mae16 cost units "
            "and the caps would be meaningless.  Measure one first: "
            "python -m repro.sensitivity.profile --library <dir> ...")
    if args.class_mix and not args.qos_class:
        raise SystemExit("--class-mix requires --qos-class")
    if args.mixed_width and not args.library:
        raise SystemExit("--mixed-width requires --library")
    if args.mixed_width and args.width != 4:
        raise SystemExit("--mixed-width chooses per-layer widths itself; "
                         "drop --width")
    if not args.continuous and (
            args.max_slots is not None or args.pages is not None
            or args.steps_per_tick is not None or args.compare_fixed
            or args.replicas > 1):
        raise SystemExit("--max-slots/--pages/--steps-per-tick/"
                         "--compare-fixed/--replicas require --continuous")
    if args.replicas < 1:
        raise SystemExit(f"--replicas must be >= 1, got {args.replicas}")
    if args.compare_fixed and args.replicas > 1:
        raise SystemExit("--compare-fixed compares single engines; "
                         "drop --replicas")
    prompt_dist = None
    if args.prompt_dist:
        try:
            prompt_dist = parse_prompt_dist(args.prompt_dist,
                                            args.prompt_len)
        except ValueError as e:
            raise SystemExit(f"--prompt-dist: {e}")

    profile_obj = None
    if args.profile:
        from ..sensitivity.profile import load_profile

        profile_obj = load_profile(args.profile)

    cfg = get_config(args.arch, reduced=args.reduced)
    plan = compiled = exact_area = controller = watcher = None
    ladder = scheduler = online = None
    mixed_report = width_map = None
    class_mix = book = None
    if args.library:
        from ..precision.plans import select_width
        from ..sensitivity.profile import costs_for

        if cfg.family == "audio":
            raise SystemExit("--library: LUT routing supports LM families only")
        if profile_obj is not None:
            from .analysis import sensitivity_report

            print(sensitivity_report(profile_obj))
        need_ladder = args.adaptive or bool(args.qos_class)
        if args.mixed_width:
            from ..precision.plans import (
                build_mixed_ladder,
                choose_mixed_budget,
                load_mixed_frontier,
                mixed_comparison,
            )

            cfg = cfg.with_approx_mlp()
            mixed = load_mixed_frontier(args.library)
            sens = {bits: costs_for(profile_obj, bits, fr.compiled,
                                    cfg.n_layers)
                    for bits, fr in mixed.by_width.items()}
            # what the *engine* keeps for watcher re-pricing: with a
            # profile it re-derives matrices itself; without one it needs
            # per-width vectors (a frozen (L, O) matrix cannot follow a
            # frontier a background sweep changes)
            engine_sens = (sens if profile_obj is not None
                           else {b: np.ones(cfg.n_layers)
                                 for b in mixed.widths})
            budget = (args.mixed_budget if args.mixed_budget is not None
                      else choose_mixed_budget(mixed, sens, cfg.n_layers))
            mixed_report, width_map, union_plan = mixed_comparison(
                mixed, sens, budget, cfg.n_layers)
            compiled = mixed.compiled
            exact_area = mixed.exact_area(mixed.native_bits)
            counts = mixed_report["width_layers"]
            per_w = ", ".join(f"{len(fr.compiled)} op(s) @ W{b}"
                              for b, fr in sorted(mixed.by_width.items()))
            print(f"library {args.library}: mixed-width frontier ({per_w})")
            print(f"width map (budget {budget:.5f}): "
                  f"{' '.join('w' + str(b) for b in width_map)} — "
                  f"layers per width {counts}")
            print(f"mixed area {mixed_report['mixed_area']:.3f} µm² vs best "
                  f"uniform {mixed_report['best_uniform_area']:.3f} µm² "
                  f"(advantage {mixed_report['advantage']:.3f})")
            ladder = build_mixed_ladder(mixed, width_map, sens,
                                        levels=args.ladder_levels)
            plan = ladder.plan(0 if need_ladder else
                               min(len(ladder) - 1, _budget_level(
                                   ladder, budget)))
            if args.watch_library:
                watcher = LibraryWatcher(args.library,
                                         min_poll_s=args.poll_s,
                                         widths=mixed.widths)
        else:
            width = select_width(cfg, requested=args.width)
            cfg = cfg.with_approx_mlp(bits=width.bits)
            compiled, exact_area, bits = _frontier(args.library, width)
            sens = (costs_for(profile_obj, width.bits, compiled,
                              cfg.n_layers)
                    if profile_obj is not None else None)
            print(f"library {args.library}: {len(compiled)} operator(s) on "
                  f"the {bits}-bit multiplier frontier "
                  f"(serving W{width.bits}A{width.bits}, "
                  f"{width.side}x{width.side} tables)")
            if need_ladder:
                ladder = PlanLadder.build(compiled, cfg.n_layers,
                                          exact_area=exact_area,
                                          sensitivities=sens,
                                          levels=args.ladder_levels)
                plan = ladder.plan(0)   # start exact
            else:
                if sens is not None and args.qos_budget is None:
                    raise SystemExit(
                        "--profile prices the startup plan in measured-"
                        "drift units; give an explicit --qos-budget in "
                        "mean-|Δlogit| terms (the mae16-scaled default "
                        "of 50.0 would max-downgrade every layer)")
                plan = _startup_plan(
                    cfg, compiled, exact_area,
                    50.0 if args.qos_budget is None else args.qos_budget,
                    sens=sens)
            if args.watch_library:
                # non-native widths pin the watcher to the composed
                # frontier; width 4 keeps the legacy block-frontier
                # reload semantics
                tb = width.bits if width.bits != 4 else None
                watcher = LibraryWatcher(args.library, min_poll_s=args.poll_s,
                                         target_bits=tb)
        if args.adaptive:
            controller = QoSController(ladder, ControllerConfig(
                target_ms_per_step=args.target_ms_per_step,
                drift_budget=args.drift_budget,
                shadow_every=args.shadow_every,
            ))
            print(f"adaptive: {len(ladder)}-level plan ladder, target "
                  f"{args.target_ms_per_step} ms/step, drift budget "
                  f"{args.drift_budget}")
        if args.qos_class:
            from ..sensitivity.classes import (ClassBook, ClassScheduler,
                                               parse_class_mix)

            book = ClassBook.parse(args.qos_class)
            scheduler = ClassScheduler(book, ladder,
                                       shadow_every=args.shadow_every)
            class_mix = (parse_class_mix(args.class_mix) if args.class_mix
                         else book.equal_mix())
            tiers = ", ".join(
                f"{c.name}(budget {c.drift_budget}, cap level "
                f"{scheduler.cap(c.name)}"
                + (f", SLO {c.slo_ms}ms" if c.slo_ms is not None else "")
                + ")" for c in book)
            print(f"QoS classes: {tiers}")
        if args.adaptive or args.qos_class:
            from ..sensitivity import OnlineSensitivity

            if profile_obj is not None:
                online = OnlineSensitivity.from_profile(
                    profile_obj, args.width, width_map=width_map)
            else:
                online = OnlineSensitivity(cfg.n_layers)

    def fresh_control():
        """A fresh controller/scheduler/online triple.  QoS state (EWMA,
        hysteresis, per-class backoff, online sensitivities) is strictly
        per-engine, so the --compare-fixed baseline and every extra
        --replicas engine each get their own."""
        c = sc = on = None
        if args.adaptive:
            c = QoSController(ladder, ControllerConfig(
                target_ms_per_step=args.target_ms_per_step,
                drift_budget=args.drift_budget,
                shadow_every=args.shadow_every))
        if args.qos_class:
            from ..sensitivity.classes import ClassScheduler

            sc = ClassScheduler(book, ladder,
                                shadow_every=args.shadow_every)
        if args.adaptive or args.qos_class:
            from ..sensitivity import OnlineSensitivity

            on = (OnlineSensitivity.from_profile(
                profile_obj, args.width, width_map=width_map)
                if profile_obj is not None
                else OnlineSensitivity(cfg.n_layers))
        return c, sc, on

    def make_health(tag):
        """One HealthPlane per engine (states and burn windows are
        per-engine, exactly like the QoS control plane)."""
        if not args.health:
            return None
        return HealthPlane(book, postmortem_dir=args.postmortem_dir,
                           tag=tag)

    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(args.seed)
    profile = make_profile(args.schedule, ticks=args.ticks,
                           per_tick=args.per_tick or args.batch,
                           prompt_len=args.prompt_len, gen_len=args.gen_len,
                           class_mix=class_mix, prompt_dist=prompt_dist)

    if args.continuous and cfg.family == "audio":
        raise SystemExit("--continuous: continuous batching serves LM "
                         "families only (paged decode)")

    with parallel.activate(mesh), mesh:
        params = init_model(cfg, key)
        warmup = None
        if cfg.family == "audio":
            from ..models.encdec import prefill_cross
            from ..train.data import DataState, synth_batch

            frames = synth_batch(cfg, args.batch, 1,
                                 DataState(args.seed, 0))["frames"]
            warmup = lambda caches: prefill_cross(cfg, params, frames, caches)

        common = dict(
            plan=plan, compiled=compiled, exact_area=exact_area,
            width_map=width_map,
            sensitivities=(engine_sens if args.library and args.mixed_width
                           else None),
            sens_profile=profile_obj,
        )
        router = None
        fixed_row = None
        health = None
        mserver = None

        def start_metrics(telemetries, health_obj=None, replicas=None):
            """Live scrape endpoint over the registries the serve is about
            to write into — the same snapshots the --trace dump merges at
            exit, read fresh on every GET."""
            if args.metrics_port is None:
                return None
            from ..obs.httpd import MetricsServer

            providers = [get_registry().snapshot]
            providers += [t.registry.snapshot for t in telemetries]
            if replicas is not None:
                def health_provider():
                    reports = {r.name: r.health.report()
                               for r in replicas if r.health is not None}
                    if not reports:
                        return {"state": "ok"}
                    worst = max(reports, key=lambda n: state_rank(
                        reports[n]["state"]))
                    return dict(reports[worst], replica=worst)
            elif health_obj is not None:
                health_provider = health_obj.report
            else:
                health_provider = None
            srv = MetricsServer(port=args.metrics_port,
                                snapshot_providers=providers,
                                health_provider=health_provider,
                                trace_dir=args.trace)
            port = srv.start()
            print(f"metrics endpoint -> http://127.0.0.1:{port}/metrics "
                  f"(/healthz, /costs.json)")
            return srv

        if args.continuous:
            max_slots = args.max_slots or args.batch

            def make_engine():
                return ContinuousServingEngine(
                    cfg, params, max_slots=max_slots,
                    prompt_len=args.prompt_len, gen_len=args.gen_len,
                    page_size=args.page_size, n_pages=args.pages,
                    steps_per_tick=args.steps_per_tick, **common)

            if args.compare_fixed:
                # same model, same profile, same (fresh) control plane —
                # the only variable is the batching discipline
                fc, fs, fo = fresh_control()
                baseline = ServingEngine(
                    cfg, params, batch=args.batch,
                    prompt_len=args.prompt_len, gen_len=args.gen_len,
                    **common)
                tb = time.time()
                fixed_row = baseline.serve(
                    profile, controller=fc, scheduler=fs, online=fo,
                    telemetry=Telemetry(), seed=args.seed).summary()
                fixed_row["wall_s"] = round(time.time() - tb, 3)
                fixed_row["mode"] = "fixed"
                fixed_row["batch"] = args.batch
                fixed_row["trace_count"] = baseline.trace_count

            if args.replicas > 1:
                class_names = ([c.name for c in book]
                               if book is not None else [])
                replicas = []
                for i in range(args.replicas):
                    c, sc, on = ((controller, scheduler, online) if i == 0
                                 else fresh_control())
                    aff = tuple(n for j, n in enumerate(class_names)
                                if j % args.replicas == i)
                    replicas.append(Replica(
                        f"replica{i}", make_engine(), controller=c,
                        scheduler=sc, online=on, classes=aff,
                        health=make_health(f"replica{i}")))
                router = ReplicaRouter(replicas, watcher=watcher)
                mserver = start_metrics(
                    [r.telemetry for r in replicas], replicas=replicas)
                t0 = time.time()
                s = router.serve(profile, seed=args.seed,
                                 steps_per_tick=args.steps_per_tick,
                                 log=print)
                wall = time.time() - t0
                engine = replicas[0].engine
                telemetry = replicas[0].telemetry
            else:
                engine = make_engine()
                health = make_health("serve")
                serve_tel = Telemetry()
                mserver = start_metrics([serve_tel], health_obj=health)
                t0 = time.time()
                telemetry = engine.serve(
                    profile, controller=controller, watcher=watcher,
                    scheduler=scheduler, online=online,
                    telemetry=serve_tel, seed=args.seed,
                    steps_per_tick=args.steps_per_tick, health=health,
                    log=print)
                wall = time.time() - t0
        else:
            engine = ServingEngine(
                cfg, params, batch=args.batch, prompt_len=args.prompt_len,
                gen_len=args.gen_len, warmup_caches=warmup, **common)
            health = make_health("serve")
            serve_tel = Telemetry()
            mserver = start_metrics([serve_tel], health_obj=health)
            t0 = time.time()
            telemetry = engine.serve(profile, controller=controller,
                                     watcher=watcher, scheduler=scheduler,
                                     online=online, telemetry=serve_tel,
                                     seed=args.seed, health=health,
                                     log=print)
            wall = time.time() - t0

    if router is not None:
        print(f"arch={cfg.name} profile={profile.name} mode=router "
              f"replicas={args.replicas} requests={s['requests']} "
              f"preemptions={s.get('preemptions', 0)} wall={wall:.2f}s")
        for name, row in s["replicas"].items():
            print(f"  {name:<10s}: routed {row['routed']}, "
                  f"{row['decode_tok_s']:.1f} tok/s, "
                  f"{row['ms_per_step']:.2f} ms/step, "
                  f"trace {row['trace_count']}x"
                  + (f", plan {row['plan']}" if "plan" in row else ""))
        s["mode"] = "router"
    else:
        s = telemetry.summary()
    if router is not None:
        pass
    elif args.continuous:
        print(f"arch={cfg.name} profile={profile.name} mode=continuous "
              f"slots={engine.max_slots} steps={s.get('steps', 0)} "
              f"requests={s['requests']} wall={wall:.2f}s")
        lat = s.get("latency_ms_per_step", {})
        print(f"  decode : {s['decode_tok_s']:.1f} tok/s "
              f"({s['ms_per_step']:.2f} ms/step"
              + (f", p95 {lat['p95']}" if "p95" in lat else "") + ")")
        if "ttft_ms" in s:
            print(f"  ttft   : p50 {s['ttft_ms']['p50']} ms, "
                  f"p95 {s['ttft_ms']['p95']} ms")
        if s.get("preemptions"):
            print(f"  preemptions: {s['preemptions']}")
    else:
        print(f"arch={cfg.name} profile={profile.name} "
              f"batches={s['batches']} requests={s['requests']} "
              f"wall={wall:.2f}s")
        print(f"  decode : {s['decode_tok_s']:.1f} tok/s "
              f"({s['ms_per_step']:.1f} ms/step)")
        print(f"  prefill: {s['prefill_tok_s']:.1f} tok/s "
              f"(python-loop prefill, timed separately from decode)")
        if engine.last_tokens is not None:
            print("sample:", engine.last_tokens[0, :16].tolist())
    if router is None and engine.plan is not None:
        print(f"  plan swaps: {s['swaps']} {s['swaps_by_reason']} — decode "
              f"step traced {engine.trace_count}x")
    if scheduler is not None and router is None:
        for name, row in s.get("classes", {}).items():
            budget = scheduler.book.get(name).drift_budget
            slo = scheduler.book.get(name).slo_ms
            drift = row.get("mean_drift")
            p95 = row.get("p95_ms_per_step")
            print(f"  class {name:<8s}: {row['requests']} req, "
                  f"{row['ms_per_step']} ms/step"
                  + (f" (p50 {row['p50_ms_per_step']} / p95 {p95} / "
                     f"p99 {row['p99_ms_per_step']})" if p95 is not None
                     else "")
                  + f", mean drift {'-' if drift is None else drift} "
                  f"(budget {budget})"
                  + (f", SLO {slo}ms "
                     + ("OK" if p95 is not None and p95 <= slo else "MISS")
                     if slo is not None else ""))
    if online is not None and online.n_updates:
        print(f"  online sensitivities ({online.n_updates} samples): "
              f"{np.round(online.sensitivities(), 4).tolist()}")
    if args.telemetry:
        telemetry.dump(args.telemetry)
        print(f"telemetry -> {args.telemetry}")
    if router is None and engine.plan is not None:
        # routing facts for smoke gates: the serving width and how many
        # layers actually run a searched (non-exact) operator
        s["width_bits"] = engine.width.bits if engine.width else None
        s["widths"] = list(engine.widths)
        s["approx_layers"] = sum(
            1 for c in engine.plan.choices if c.key is not None)
    if router is None:
        s["trace_count"] = engine.trace_count
    if router is None and args.continuous:
        s["mode"] = "continuous"
        s["max_slots"] = engine.max_slots
        s["page_size"] = engine.page_size
        s["n_pages"] = engine.n_pages
        if fixed_row is not None:
            # the paired rows the acceptance gate reads: same model, same
            # profile, only the batching discipline differs
            cmp = {"fixed": fixed_row}
            if fixed_row.get("decode_tok_s"):
                cmp["decode_tok_s_gain"] = round(
                    s["decode_tok_s"] / fixed_row["decode_tok_s"] - 1, 4)
            fp50 = fixed_row.get("decode_tok_s_pct", {}).get("p50")
            cp50 = s.get("decode_tok_s_pct", {}).get("p50")
            if fp50 and cp50:
                # steady-state (median per-observation) throughput gain:
                # robust to the one-off trace/compile step both engines pay
                cmp["decode_tok_s_p50_gain"] = round(cp50 / fp50 - 1, 4)
            p95g = {}
            for cname, crow in s.get("classes", {}).items():
                frow = fixed_row.get("classes", {}).get(cname, {})
                if crow.get("p95_ms_per_step") and frow.get(
                        "p95_ms_per_step"):
                    p95g[cname] = round(
                        1 - crow["p95_ms_per_step"]
                        / frow["p95_ms_per_step"], 4)
            if p95g:
                cmp["p95_ms_per_step_reduction"] = p95g
            s["compare"] = cmp
            print(f"  vs fixed: decode {fixed_row['decode_tok_s']:.1f} -> "
                  f"{s['decode_tok_s']:.1f} tok/s "
                  f"({100 * cmp.get('decode_tok_s_gain', 0.0):+.1f}%"
                  + (f"; steady-state p50 "
                     f"{100 * cmp['decode_tok_s_p50_gain']:+.1f}%"
                     if "decode_tok_s_p50_gain" in cmp else "") + ")"
                  + (f", p95 ms/step reduction {p95g}" if p95g else ""))
    if mixed_report is not None:
        s["mixed"] = mixed_report
    if scheduler is not None and router is None:
        for name, row in s.get("classes", {}).items():
            row["drift_budget"] = scheduler.book.get(name).drift_budget
            row["slo_ms"] = scheduler.book.get(name).slo_ms
        s["class_state"] = scheduler.snapshot(
            controller.level if controller is not None else None)
    if online is not None and online.n_updates:
        s["online_sensitivity"] = np.round(
            online.sensitivities(), 6).tolist()
    if args.health:
        # the gateable health doc: single engines report their own plane,
        # a router reports its worst replica (per-replica reports already
        # sit in s["replicas"][name]["health"])
        if router is not None:
            reports = {r.name: r.health.report() for r in router.replicas}
            worst = max(reports, key=lambda n: state_rank(
                reports[n]["state"]))
            hr = dict(reports[worst], replica=worst)
        else:
            hr = health.report()
        s["health"] = hr
        print(f"  health : {hr['state']} "
              f"({hr['anomalies_fired']} anomaly(ies), "
              f"{hr['pages']} page transition(s), "
              f"{hr['dumps']} post-mortem(s))"
              + (f" [worst replica: {worst}]" if router is not None
                 else ""))
        for a in hr.get("recent_anomalies", [])[-3:]:
            cause = a.get("cause")
            print(f"    anomaly {a['signal']}@{a['step']} "
                  f"{a['direction']} z={a['zscore']:+.1f}"
                  + (f" <- {cause['event']}@{cause['step']}"
                     + (f" [{cause['event_id']}]" if cause["event_id"]
                        else "")
                     if cause else " (no recent control event)"))
        if args.postmortem_dir and hr["dumps"]:
            print(f"post-mortems -> {args.postmortem_dir} "
                  f"({hr['dumps']} bundle(s); "
                  f"python -m repro.obs postmortem --dir "
                  f"{args.postmortem_dir})")
    if mserver is not None:
        # stop before the exit snapshot lands in the trace dir: the live
        # endpoint merges trace-dir snapshots into every scrape, so
        # serving past the dump would double-count this process
        mserver.stop()
    if args.trace:
        # the serve-side metric snapshot joins any fleet-side ones already
        # in the dir: per-batch latency/throughput histograms (telemetry's
        # own registry) plus the process registry the watcher and class
        # scheduler record into; a router merges every replica's registry
        snaps = [get_registry().snapshot()]
        if router is not None:
            snaps += [r.telemetry.registry.snapshot()
                      for r in router.replicas]
        else:
            snaps.append(telemetry.registry.snapshot())
        merged = MetricRegistry.from_snapshots(snaps)
        dump_metrics(args.trace, merged)
        print(f"trace -> {args.trace}")
        if args.continuous:
            # lifecycle roll-up for the provenance-smoke gate: how many
            # request chains the trace reconstructs, and how many are
            # causally complete (python -m repro.obs requests drills in)
            from ..obs.requests import build_timelines
            from ..obs.trace import read_trace
            tls = build_timelines(read_trace(args.trace))
            s["requests_traced"] = len(tls)
            s["requests_complete"] = sum(
                1 for t in tls.values() if t.complete)
            print(f"  request chains: {s['requests_complete']}/"
                  f"{s['requests_traced']} complete "
                  f"(python -m repro.obs requests --trace {args.trace})")
    if args.bench_json:
        write_bench_json(args.bench_json, s)
        print(f"bench summary -> {args.bench_json}")


if __name__ == "__main__":
    main()
