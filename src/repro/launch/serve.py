"""Serving launcher: a thin CLI over :mod:`repro.serving`.

CPU-runnable with ``--reduced``; the same decode step is what the dry-run
lowers for the decode_32k / long_500k cells on the production mesh.
Requests are synthetic prompts on a deterministic load profile
(steady / ramp / spike); decoding is greedy.  Prefill and decode
throughput are reported *separately* — prefill here is a python-loop over
the prompt through the decode step, so folding it into one number would
silently understate decode throughput.

Three serving modes:

* plain                — exact decode, no operator library.
* ``--library``        — one QoS plan selected at startup (as before).
* ``--adaptive``       — the plan is a runtime input: a QoS controller
  walks the operator frontier between batches (latency target vs drift
  budget), and ``--watch-library`` additionally picks up operators a
  background ``python -m repro.fleet`` sweep adds mid-serve.  The decode
  step never retraces across swaps.

``--width`` picks the LUT operand width for any library mode: 4 serves
W4A4 on the native 16x16 tables, 8 serves W8A8 on 256x256 tables composed
from the same searched blocks (:mod:`repro.precision`); all three modes
and the watcher work at either width.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from .. import parallel
from ..configs import ARCH_IDS, get_config
from ..models import init_model
from ..serving import (
    ControllerConfig,
    LibraryWatcher,
    PlanLadder,
    QoSController,
    ServingEngine,
    Telemetry,
    make_profile,
)
from ..serving.loadgen import PROFILES
from .mesh import make_smoke_mesh


def _frontier(library: str, width):
    from ..precision.plans import load_frontier

    try:
        return load_frontier(library, width)
    except LookupError as e:
        raise SystemExit(str(e))


def _startup_plan(cfg, compiled, exact_area, budget: float):
    """The legacy one-shot selection (uniform sensitivities, mae16-unit
    budget); ``examples/approx_inference.py --library`` measures real
    per-layer drift budgets."""
    from ..library import select_plan
    from .analysis import plan_report

    plan = select_plan(compiled, np.ones(cfg.n_layers), budget,
                       exact_area=exact_area)
    print(f"QoS plan ({len(compiled)} frontier operator(s)):")
    print(plan_report(plan))
    if all(c.key is None for c in plan.choices):
        print("note: budget admits no downgrade — every layer stays exact "
              "(serving budgets are mae16 units; try a larger --qos-budget)")
    return plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--library", default=None,
                    help="approximate-operator store; routes MLP matmuls "
                         "through QoS-selected per-layer LUT multipliers")
    ap.add_argument("--width", type=int, choices=(4, 8), default=4,
                    help="LUT operand width: 4 = native W4A4 (16x16 "
                         "tables), 8 = W8A8 — searched blocks composed "
                         "into 256x256 tables (repro.precision)")
    ap.add_argument("--qos-budget", type=float, default=50.0,
                    help="startup QoS budget in summed compiled-table mae16 "
                         "units (non-adaptive mode only)")
    # ---- load profile -----------------------------------------------------
    ap.add_argument("--schedule", choices=PROFILES, default="steady",
                    help="synthetic load profile shape")
    ap.add_argument("--ticks", type=int, default=1,
                    help="load-profile length in arrival ticks")
    ap.add_argument("--per-tick", type=int, default=None,
                    help="arrivals per tick (steady) / peak (ramp, spike); "
                         "default: --batch")
    # ---- adaptive runtime -------------------------------------------------
    ap.add_argument("--adaptive", action="store_true",
                    help="QoS controller walks the operator frontier between "
                         "batches (requires --library)")
    ap.add_argument("--target-ms-per-step", type=float, default=50.0,
                    help="controller latency target (EWMA decode ms/step)")
    ap.add_argument("--drift-budget", type=float, default=0.05,
                    help="mean |Δlogit| allowed vs the exact shadow step")
    ap.add_argument("--shadow-every", type=int, default=4,
                    help="sample the exact shadow step every N batches")
    ap.add_argument("--ladder-levels", type=int, default=6,
                    help="plan-ladder resolution across the frontier")
    ap.add_argument("--watch-library", action="store_true",
                    help="poll the store between batches and hot-swap in "
                         "operators a background fleet sweep adds")
    ap.add_argument("--poll-s", type=float, default=2.0,
                    help="minimum seconds between store version polls")
    # ---- output -----------------------------------------------------------
    ap.add_argument("--telemetry", default=None,
                    help="write the full telemetry dump (JSON) here")
    ap.add_argument("--bench-json", default=None,
                    help="write the telemetry summary (tok/s, ms/step, swap "
                         "count) here, e.g. BENCH_serve.json")
    args = ap.parse_args()

    if args.adaptive and not args.library:
        raise SystemExit("--adaptive requires --library (the frontier to walk)")
    if args.watch_library and not args.library:
        raise SystemExit("--watch-library requires --library")

    cfg = get_config(args.arch, reduced=args.reduced)
    plan = compiled = exact_area = controller = watcher = None
    if args.library:
        from ..precision.plans import select_width

        if cfg.family == "audio":
            raise SystemExit("--library: LUT routing supports LM families only")
        width = select_width(cfg, requested=args.width)
        cfg = cfg.with_approx_mlp(bits=width.bits)
        compiled, exact_area, bits = _frontier(args.library, width)
        print(f"library {args.library}: {len(compiled)} operator(s) on the "
              f"{bits}-bit multiplier frontier "
              f"(serving W{width.bits}A{width.bits}, "
              f"{width.side}x{width.side} tables)")
        if args.adaptive:
            ladder = PlanLadder.build(compiled, cfg.n_layers,
                                      exact_area=exact_area,
                                      levels=args.ladder_levels)
            controller = QoSController(ladder, ControllerConfig(
                target_ms_per_step=args.target_ms_per_step,
                drift_budget=args.drift_budget,
                shadow_every=args.shadow_every,
            ))
            plan = ladder.plan(0)   # start exact; the controller walks up
            print(f"adaptive: {len(ladder)}-level plan ladder, target "
                  f"{args.target_ms_per_step} ms/step, drift budget "
                  f"{args.drift_budget}")
        else:
            plan = _startup_plan(cfg, compiled, exact_area, args.qos_budget)
        if args.watch_library:
            # non-native widths pin the watcher to the composed frontier;
            # width 4 keeps the legacy block-frontier reload semantics
            tb = width.bits if width.bits != 4 else None
            watcher = LibraryWatcher(args.library, min_poll_s=args.poll_s,
                                     target_bits=tb)

    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(args.seed)
    profile = make_profile(args.schedule, ticks=args.ticks,
                           per_tick=args.per_tick or args.batch,
                           prompt_len=args.prompt_len, gen_len=args.gen_len)

    with parallel.activate(mesh), mesh:
        params = init_model(cfg, key)
        warmup = None
        if cfg.family == "audio":
            from ..models.encdec import prefill_cross
            from ..train.data import DataState, synth_batch

            frames = synth_batch(cfg, args.batch, 1,
                                 DataState(args.seed, 0))["frames"]
            warmup = lambda caches: prefill_cross(cfg, params, frames, caches)

        engine = ServingEngine(
            cfg, params, batch=args.batch, prompt_len=args.prompt_len,
            gen_len=args.gen_len, plan=plan, compiled=compiled,
            exact_area=exact_area, warmup_caches=warmup,
        )
        t0 = time.time()
        telemetry = engine.serve(profile, controller=controller,
                                 watcher=watcher, telemetry=Telemetry(),
                                 seed=args.seed, log=print)
        wall = time.time() - t0

    s = telemetry.summary()
    print(f"arch={cfg.name} profile={profile.name} "
          f"batches={s['batches']} requests={s['requests']} "
          f"wall={wall:.2f}s")
    print(f"  decode : {s['decode_tok_s']:.1f} tok/s "
          f"({s['ms_per_step']:.1f} ms/step)")
    print(f"  prefill: {s['prefill_tok_s']:.1f} tok/s "
          f"(python-loop prefill, timed separately from decode)")
    if engine.last_tokens is not None:
        print("sample:", engine.last_tokens[0, :16].tolist())
    if engine.plan is not None:
        print(f"  plan swaps: {s['swaps']} {s['swaps_by_reason']} — decode "
              f"step traced {engine.trace_count}x")
    if args.telemetry:
        telemetry.dump(args.telemetry)
        print(f"telemetry -> {args.telemetry}")
    if engine.plan is not None:
        # routing facts for smoke gates: the serving width and how many
        # layers actually run a searched (non-exact) operator
        s["width_bits"] = engine.width.bits if engine.width else None
        s["approx_layers"] = sum(
            1 for c in engine.plan.choices if c.key is not None)
    if args.bench_json:
        from pathlib import Path

        out = Path(args.bench_json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(s, indent=1, sort_keys=True))
        print(f"bench summary -> {args.bench_json}")


if __name__ == "__main__":
    main()
