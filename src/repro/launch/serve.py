"""Serving launcher: batched autoregressive decode with KV caches.

CPU-runnable with ``--reduced``; the same serve_step is what the dry-run
lowers for the decode_32k / long_500k cells on the production mesh.
Requests are synthetic prompts; decoding is greedy.  Throughput and
per-token latency are reported at the end.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import parallel
from ..configs import ARCH_IDS, get_config
from ..models import decode_fn, init_caches, init_model
from ..train.data import DataState, synth_batch
from .mesh import make_smoke_mesh


def _qos_luts(cfg, library: str, budget: float):
    """Build the per-layer LUT stack from a stored operator frontier.

    Serving has no calibration batch, so sensitivities are uniform and the
    budget is in summed compiled-table mae16 units (one mid-grade 2-bit
    operator costs ~30); run ``examples/approx_inference.py --library``
    for measured per-layer drift budgets."""
    import numpy as np

    from ..library import load_mul_frontier, select_plan, stack_luts
    from .analysis import plan_report

    try:
        compiled, exact_area, _bits = load_mul_frontier(library)
    except LookupError as e:
        raise SystemExit(str(e))
    plan = select_plan(compiled, np.ones(cfg.n_layers), budget,
                       exact_area=exact_area)
    print(f"QoS plan from {library} ({len(compiled)} frontier operator(s)):")
    print(plan_report(plan))
    if all(c.key is None for c in plan.choices):
        print("note: budget admits no downgrade — every layer stays exact "
              "(serving budgets are mae16 units; try a larger --qos-budget)")
    return jnp.asarray(stack_luts(plan, compiled))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--library", default=None,
                    help="approximate-operator store; routes MLP matmuls "
                         "through QoS-selected per-layer LUT multipliers")
    ap.add_argument("--qos-budget", type=float, default=50.0,
                    help="QoS budget in summed compiled-table mae16 units "
                         "(uniform layer sensitivities; measure real "
                         "per-layer drift with examples/approx_inference.py)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    luts = None
    if args.library:
        if cfg.family == "audio":
            raise SystemExit("--library: LUT routing supports LM families only")
        cfg = cfg.with_approx_mlp()
        luts = _qos_luts(cfg, args.library, args.qos_budget)
    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(args.seed)

    with parallel.activate(mesh), mesh:
        params = init_model(cfg, key)
        total = args.prompt_len + args.gen_len
        caches = init_caches(cfg, args.batch, total)
        step = decode_fn(cfg)
        if cfg.family == "audio":
            from ..models.encdec import prefill_cross
            frames = synth_batch(cfg, args.batch, 1, DataState(args.seed, 0))["frames"]
            caches = prefill_cross(cfg, params, frames, caches)

        if luts is not None:
            step_fn = lambda p, c, t, pos: step(cfg, p, c, t, pos, luts=luts)
        else:  # encdec's decode step has no luts parameter
            step_fn = lambda p, c, t, pos: step(cfg, p, c, t, pos)
        jit_step = jax.jit(step_fn, donate_argnums=(1,))

        prompts = synth_batch(cfg, args.batch, args.prompt_len,
                              DataState(args.seed, 1))["tokens"]
        # prefill by stepping the prompt (decode-path prefill keeps one code path)
        tok = prompts[:, :1]
        t0 = time.time()
        for t in range(args.prompt_len):
            logits, caches = jit_step(params, caches, prompts[:, t:t+1], jnp.int32(t))
        generated = []
        for t in range(args.prompt_len, total):
            tok = jnp.argmax(logits, axis=-1)[:, None]
            generated.append(tok)
            logits, caches = jit_step(params, caches, tok, jnp.int32(t))
        dt = time.time() - t0
        toks = args.batch * total
        print(f"arch={cfg.name} batch={args.batch} "
              f"{toks} tokens in {dt:.2f}s = {toks/dt:.1f} tok/s "
              f"({dt/total*1e3:.1f} ms/step)")
        out = jnp.concatenate(generated, axis=1)
        print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
