"""Serving launcher: a thin CLI over :mod:`repro.serving`.

CPU-runnable with ``--reduced``; the same decode step is what the dry-run
lowers for the decode_32k / long_500k cells on the production mesh.
Requests are synthetic prompts on a deterministic load profile
(steady / ramp / spike); decoding is greedy.  Prefill and decode
throughput are reported *separately* — prefill here is a python-loop over
the prompt through the decode step, so folding it into one number would
silently understate decode throughput.

Three serving modes:

* plain                — exact decode, no operator library.
* ``--library``        — one QoS plan selected at startup (as before).
* ``--adaptive``       — the plan is a runtime input: a QoS controller
  walks the operator frontier between batches (latency target vs drift
  budget), and ``--watch-library`` additionally picks up operators a
  background ``python -m repro.fleet`` sweep adds mid-serve.  The decode
  step never retraces across swaps.

``--width`` picks the LUT operand width for any library mode: 4 serves
W4A4 on the native 16x16 tables, 8 serves W8A8 on 256x256 tables composed
from the same searched blocks (:mod:`repro.precision`); all three modes
and the watcher work at either width.

Measured sensitivities, QoS classes, mixed width
(:mod:`repro.sensitivity`):

* ``--profile p.json`` prices plans with a *measured* per-layer
  sensitivity profile (``python -m repro.sensitivity.profile``) instead
  of the uniform linear model;
* ``--qos-class "gold:0.02,batch:0.2"`` declares per-request traffic
  tiers with their own drift budgets — per-class queues drain in priority
  order and each batch decodes on its class's ladder level (with
  ``--adaptive`` the load-driven global level still caps everyone);
  ``--class-mix`` shapes the synthetic arrival mix;
* ``--mixed-width`` serves a per-layer width map — sensitive layers on
  native 16x16 tiles, tolerant layers on composed 256x256 W8A8 tables —
  chosen by one greedy descent over both frontiers at once
  (``--mixed-budget``, default auto).  The decode step still traces
  exactly once; the bench summary reports the mixed plan's area against
  the best uniform-width plan at the same budget.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .. import parallel
from ..configs import ARCH_IDS, get_config
from ..models import init_model
from ..obs.export import dump_metrics, write_bench_json
from ..obs.metrics import MetricRegistry, get_registry
from ..obs.trace import configure as configure_tracing
from ..serving import (
    ControllerConfig,
    LibraryWatcher,
    PlanLadder,
    QoSController,
    ServingEngine,
    Telemetry,
    make_profile,
)
from ..serving.loadgen import PROFILES
from .mesh import make_smoke_mesh


def _frontier(library: str, width):
    from ..precision.plans import load_frontier

    try:
        return load_frontier(library, width)
    except LookupError as e:
        raise SystemExit(str(e))


def _startup_plan(cfg, compiled, exact_area, budget: float, sens=None):
    """The one-shot selection: uniform sensitivities (mae16-unit budget)
    unless a measured ``--profile`` cost model is at hand."""
    from ..library import select_plan
    from .analysis import plan_report

    plan = select_plan(compiled,
                       np.ones(cfg.n_layers) if sens is None else sens,
                       budget, exact_area=exact_area)
    print(f"QoS plan ({len(compiled)} frontier operator(s)):")
    print(plan_report(plan))
    if all(c.key is None for c in plan.choices):
        print("note: budget admits no downgrade — every layer stays exact "
              "(try a larger --qos-budget)")
    return plan


def _budget_level(ladder, budget: float) -> int:
    """Deepest ladder level whose selection budget fits ``budget`` — the
    startup level of a non-adaptive mixed-width serve."""
    lvl = 0
    for i, p in enumerate(ladder.plans):
        if p.budget <= budget:
            lvl = i
    return lvl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--library", default=None,
                    help="approximate-operator store; routes MLP matmuls "
                         "through QoS-selected per-layer LUT multipliers")
    ap.add_argument("--width", type=int, choices=(4, 8), default=4,
                    help="LUT operand width: 4 = native W4A4 (16x16 "
                         "tables), 8 = W8A8 — searched blocks composed "
                         "into 256x256 tables (repro.precision)")
    ap.add_argument("--qos-budget", type=float, default=None,
                    help="startup QoS budget (non-adaptive mode only). "
                         "Without --profile: summed compiled-table mae16 "
                         "units, default 50.0.  With --profile the plan is "
                         "priced in measured-drift (mean |Δlogit|) units, "
                         "so the budget must be given explicitly — the "
                         "mae16-scaled default would admit the full "
                         "greedy descent.")
    # ---- measured sensitivities / QoS classes / mixed width ---------------
    ap.add_argument("--profile", default=None,
                    help="measured SensitivityProfile JSON (produced by "
                         "python -m repro.sensitivity.profile); plans and "
                         "ladders price operators with measured per-layer "
                         "sensitivities instead of the uniform model")
    ap.add_argument("--qos-class", default=None, metavar="SPEC",
                    help='per-request QoS classes with drift budgets, e.g. '
                         '"gold:0.02,std:0.05,batch:0.2" (listed order = '
                         'drain priority); requires --library')
    ap.add_argument("--class-mix", default=None, metavar="SPEC",
                    help='synthetic arrival mix over the declared classes, '
                         'e.g. "gold:0.1,std:0.6,batch:0.3" (default: '
                         'equal shares)')
    ap.add_argument("--mixed-width", action="store_true",
                    help="serve a per-layer width map (native 16x16 tiles "
                         "for sensitive layers, composed 256x256 W8A8 "
                         "tables for tolerant ones) chosen jointly over "
                         "both frontiers; incompatible with --width 8")
    ap.add_argument("--mixed-budget", type=float, default=None,
                    help="drift budget for the width-map selection "
                         "(default: auto — the greedy breakpoint with the "
                         "largest mixed-vs-uniform area advantage)")
    # ---- load profile -----------------------------------------------------
    ap.add_argument("--schedule", choices=PROFILES, default="steady",
                    help="synthetic load profile shape")
    ap.add_argument("--ticks", type=int, default=1,
                    help="load-profile length in arrival ticks")
    ap.add_argument("--per-tick", type=int, default=None,
                    help="arrivals per tick (steady) / peak (ramp, spike); "
                         "default: --batch")
    # ---- adaptive runtime -------------------------------------------------
    ap.add_argument("--adaptive", action="store_true",
                    help="QoS controller walks the operator frontier between "
                         "batches (requires --library)")
    ap.add_argument("--target-ms-per-step", type=float, default=50.0,
                    help="controller latency target (EWMA decode ms/step)")
    ap.add_argument("--drift-budget", type=float, default=0.05,
                    help="mean |Δlogit| allowed vs the exact shadow step")
    ap.add_argument("--shadow-every", type=int, default=4,
                    help="sample the exact shadow step every N batches")
    ap.add_argument("--ladder-levels", type=int, default=6,
                    help="plan-ladder resolution across the frontier")
    ap.add_argument("--watch-library", action="store_true",
                    help="poll the store between batches and hot-swap in "
                         "operators a background fleet sweep adds")
    ap.add_argument("--poll-s", type=float, default=2.0,
                    help="minimum seconds between store version polls")
    # ---- output -----------------------------------------------------------
    ap.add_argument("--telemetry", default=None,
                    help="write the full telemetry dump (JSON) here")
    ap.add_argument("--bench-json", default=None,
                    help="write the telemetry summary (tok/s, ms/step, swap "
                         "count) here, e.g. BENCH_serve.json")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="observability trace dir: batch/prefill/decode "
                         "spans + a metric snapshot land there; point it at "
                         "a fleet run's trace dir for one merged view "
                         "(python -m repro.obs summary --trace DIR)")
    args = ap.parse_args()

    if args.trace:
        configure_tracing(args.trace)

    if args.adaptive and not args.library:
        raise SystemExit("--adaptive requires --library (the frontier to walk)")
    if args.watch_library and not args.library:
        raise SystemExit("--watch-library requires --library")
    if args.qos_class and not args.library:
        raise SystemExit("--qos-class requires --library (classes pick "
                         "ladder levels)")
    if args.qos_class and not args.profile:
        raise SystemExit(
            "--qos-class budgets are measured-drift (mean |Δlogit|) units "
            "and cap ladder levels by predicted drift — without a measured "
            "--profile the ladder's predictions are in mae16 cost units "
            "and the caps would be meaningless.  Measure one first: "
            "python -m repro.sensitivity.profile --library <dir> ...")
    if args.class_mix and not args.qos_class:
        raise SystemExit("--class-mix requires --qos-class")
    if args.mixed_width and not args.library:
        raise SystemExit("--mixed-width requires --library")
    if args.mixed_width and args.width != 4:
        raise SystemExit("--mixed-width chooses per-layer widths itself; "
                         "drop --width")

    profile_obj = None
    if args.profile:
        from ..sensitivity.profile import load_profile

        profile_obj = load_profile(args.profile)

    cfg = get_config(args.arch, reduced=args.reduced)
    plan = compiled = exact_area = controller = watcher = None
    ladder = scheduler = online = None
    mixed_report = width_map = None
    class_mix = None
    if args.library:
        from ..precision.plans import select_width
        from ..sensitivity.profile import costs_for

        if cfg.family == "audio":
            raise SystemExit("--library: LUT routing supports LM families only")
        if profile_obj is not None:
            from .analysis import sensitivity_report

            print(sensitivity_report(profile_obj))
        need_ladder = args.adaptive or bool(args.qos_class)
        if args.mixed_width:
            from ..precision.plans import (
                build_mixed_ladder,
                choose_mixed_budget,
                load_mixed_frontier,
                mixed_comparison,
            )

            cfg = cfg.with_approx_mlp()
            mixed = load_mixed_frontier(args.library)
            sens = {bits: costs_for(profile_obj, bits, fr.compiled,
                                    cfg.n_layers)
                    for bits, fr in mixed.by_width.items()}
            # what the *engine* keeps for watcher re-pricing: with a
            # profile it re-derives matrices itself; without one it needs
            # per-width vectors (a frozen (L, O) matrix cannot follow a
            # frontier a background sweep changes)
            engine_sens = (sens if profile_obj is not None
                           else {b: np.ones(cfg.n_layers)
                                 for b in mixed.widths})
            budget = (args.mixed_budget if args.mixed_budget is not None
                      else choose_mixed_budget(mixed, sens, cfg.n_layers))
            mixed_report, width_map, union_plan = mixed_comparison(
                mixed, sens, budget, cfg.n_layers)
            compiled = mixed.compiled
            exact_area = mixed.exact_area(mixed.native_bits)
            counts = mixed_report["width_layers"]
            per_w = ", ".join(f"{len(fr.compiled)} op(s) @ W{b}"
                              for b, fr in sorted(mixed.by_width.items()))
            print(f"library {args.library}: mixed-width frontier ({per_w})")
            print(f"width map (budget {budget:.5f}): "
                  f"{' '.join('w' + str(b) for b in width_map)} — "
                  f"layers per width {counts}")
            print(f"mixed area {mixed_report['mixed_area']:.3f} µm² vs best "
                  f"uniform {mixed_report['best_uniform_area']:.3f} µm² "
                  f"(advantage {mixed_report['advantage']:.3f})")
            ladder = build_mixed_ladder(mixed, width_map, sens,
                                        levels=args.ladder_levels)
            plan = ladder.plan(0 if need_ladder else
                               min(len(ladder) - 1, _budget_level(
                                   ladder, budget)))
            if args.watch_library:
                watcher = LibraryWatcher(args.library,
                                         min_poll_s=args.poll_s,
                                         widths=mixed.widths)
        else:
            width = select_width(cfg, requested=args.width)
            cfg = cfg.with_approx_mlp(bits=width.bits)
            compiled, exact_area, bits = _frontier(args.library, width)
            sens = (costs_for(profile_obj, width.bits, compiled,
                              cfg.n_layers)
                    if profile_obj is not None else None)
            print(f"library {args.library}: {len(compiled)} operator(s) on "
                  f"the {bits}-bit multiplier frontier "
                  f"(serving W{width.bits}A{width.bits}, "
                  f"{width.side}x{width.side} tables)")
            if need_ladder:
                ladder = PlanLadder.build(compiled, cfg.n_layers,
                                          exact_area=exact_area,
                                          sensitivities=sens,
                                          levels=args.ladder_levels)
                plan = ladder.plan(0)   # start exact
            else:
                if sens is not None and args.qos_budget is None:
                    raise SystemExit(
                        "--profile prices the startup plan in measured-"
                        "drift units; give an explicit --qos-budget in "
                        "mean-|Δlogit| terms (the mae16-scaled default "
                        "of 50.0 would max-downgrade every layer)")
                plan = _startup_plan(
                    cfg, compiled, exact_area,
                    50.0 if args.qos_budget is None else args.qos_budget,
                    sens=sens)
            if args.watch_library:
                # non-native widths pin the watcher to the composed
                # frontier; width 4 keeps the legacy block-frontier
                # reload semantics
                tb = width.bits if width.bits != 4 else None
                watcher = LibraryWatcher(args.library, min_poll_s=args.poll_s,
                                         target_bits=tb)
        if args.adaptive:
            controller = QoSController(ladder, ControllerConfig(
                target_ms_per_step=args.target_ms_per_step,
                drift_budget=args.drift_budget,
                shadow_every=args.shadow_every,
            ))
            print(f"adaptive: {len(ladder)}-level plan ladder, target "
                  f"{args.target_ms_per_step} ms/step, drift budget "
                  f"{args.drift_budget}")
        if args.qos_class:
            from ..sensitivity.classes import (ClassBook, ClassScheduler,
                                               parse_class_mix)

            book = ClassBook.parse(args.qos_class)
            scheduler = ClassScheduler(book, ladder,
                                       shadow_every=args.shadow_every)
            class_mix = (parse_class_mix(args.class_mix) if args.class_mix
                         else book.equal_mix())
            tiers = ", ".join(
                f"{c.name}(budget {c.drift_budget}, cap level "
                f"{scheduler.cap(c.name)})" for c in book)
            print(f"QoS classes: {tiers}")
        if args.adaptive or args.qos_class:
            from ..sensitivity import OnlineSensitivity

            if profile_obj is not None:
                online = OnlineSensitivity.from_profile(
                    profile_obj, args.width, width_map=width_map)
            else:
                online = OnlineSensitivity(cfg.n_layers)

    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(args.seed)
    profile = make_profile(args.schedule, ticks=args.ticks,
                           per_tick=args.per_tick or args.batch,
                           prompt_len=args.prompt_len, gen_len=args.gen_len,
                           class_mix=class_mix)

    with parallel.activate(mesh), mesh:
        params = init_model(cfg, key)
        warmup = None
        if cfg.family == "audio":
            from ..models.encdec import prefill_cross
            from ..train.data import DataState, synth_batch

            frames = synth_batch(cfg, args.batch, 1,
                                 DataState(args.seed, 0))["frames"]
            warmup = lambda caches: prefill_cross(cfg, params, frames, caches)

        engine = ServingEngine(
            cfg, params, batch=args.batch, prompt_len=args.prompt_len,
            gen_len=args.gen_len, plan=plan, compiled=compiled,
            exact_area=exact_area, warmup_caches=warmup,
            width_map=width_map,
            sensitivities=(engine_sens if args.library and args.mixed_width
                           else None),
            sens_profile=profile_obj,
        )
        t0 = time.time()
        telemetry = engine.serve(profile, controller=controller,
                                 watcher=watcher, scheduler=scheduler,
                                 online=online, telemetry=Telemetry(),
                                 seed=args.seed, log=print)
        wall = time.time() - t0

    s = telemetry.summary()
    print(f"arch={cfg.name} profile={profile.name} "
          f"batches={s['batches']} requests={s['requests']} "
          f"wall={wall:.2f}s")
    print(f"  decode : {s['decode_tok_s']:.1f} tok/s "
          f"({s['ms_per_step']:.1f} ms/step)")
    print(f"  prefill: {s['prefill_tok_s']:.1f} tok/s "
          f"(python-loop prefill, timed separately from decode)")
    if engine.last_tokens is not None:
        print("sample:", engine.last_tokens[0, :16].tolist())
    if engine.plan is not None:
        print(f"  plan swaps: {s['swaps']} {s['swaps_by_reason']} — decode "
              f"step traced {engine.trace_count}x")
    if scheduler is not None:
        for name, row in s.get("classes", {}).items():
            budget = scheduler.book.get(name).drift_budget
            drift = row.get("mean_drift")
            p95 = row.get("p95_ms_per_step")
            print(f"  class {name:<8s}: {row['requests']} req, "
                  f"{row['ms_per_step']} ms/step"
                  + (f" (p50 {row['p50_ms_per_step']} / p95 {p95} / "
                     f"p99 {row['p99_ms_per_step']})" if p95 is not None
                     else "")
                  + f", mean drift {'-' if drift is None else drift} "
                  f"(budget {budget})")
    if online is not None and online.n_updates:
        print(f"  online sensitivities ({online.n_updates} samples): "
              f"{np.round(online.sensitivities(), 4).tolist()}")
    if args.telemetry:
        telemetry.dump(args.telemetry)
        print(f"telemetry -> {args.telemetry}")
    if engine.plan is not None:
        # routing facts for smoke gates: the serving width and how many
        # layers actually run a searched (non-exact) operator
        s["width_bits"] = engine.width.bits if engine.width else None
        s["widths"] = list(engine.widths)
        s["approx_layers"] = sum(
            1 for c in engine.plan.choices if c.key is not None)
        s["trace_count"] = engine.trace_count
    if mixed_report is not None:
        s["mixed"] = mixed_report
    if scheduler is not None:
        for name, row in s.get("classes", {}).items():
            row["drift_budget"] = scheduler.book.get(name).drift_budget
        s["class_state"] = scheduler.snapshot(
            controller.level if controller is not None else None)
    if online is not None and online.n_updates:
        s["online_sensitivity"] = np.round(
            online.sensitivities(), 6).tolist()
    if args.trace:
        # the serve-side metric snapshot joins any fleet-side ones already
        # in the dir: per-batch latency/throughput histograms (telemetry's
        # own registry) plus the process registry the watcher and class
        # scheduler record into
        merged = MetricRegistry.from_snapshots(
            [get_registry().snapshot(), telemetry.registry.snapshot()])
        dump_metrics(args.trace, merged)
        print(f"trace -> {args.trace}")
    if args.bench_json:
        write_bench_json(args.bench_json, s)
        print(f"bench summary -> {args.bench_json}")


if __name__ == "__main__":
    main()
