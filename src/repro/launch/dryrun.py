"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
partitions, and compiles on the production mesh — no hardware needed.

MUST set XLA_FLAGS before any jax import (device count locks on first
init); these two lines are deliberately the first statements:
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402

import argparse  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from .. import parallel  # noqa: E402
from ..configs import ARCH_IDS, get_config  # noqa: E402
from ..models import init_caches, init_model  # noqa: E402
from ..models.config import SHAPES, ModelConfig, ShapeConfig  # noqa: E402
from ..parallel.specs import (  # noqa: E402
    batch_specs, cache_specs, named, opt_specs, param_specs,
)
from ..train import OptimizerConfig, init_opt_state  # noqa: E402
from ..train.step import make_decode_step, make_train_step, make_prefill_step  # noqa: E402
from . import analysis  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402


def replace_layers(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    """Same config with a different depth (encoder scales along for
    enc-dec).  Used by the scan-aware cost extrapolation."""
    import dataclasses

    kw: dict = {"n_layers": n_layers}
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=n_layers)
    return dataclasses.replace(cfg, **kw)


def cell_is_skipped(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    """Returns a skip reason or None.  long_500k needs sub-quadratic
    attention (bounded KV state): run for SSM / hybrid / windowed archs,
    skip for pure full-attention archs (DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "long_500k skipped: pure full-attention arch (dense 500k KV)"
    return None


def batch_structs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.vision.n_patches, cfg.vision.d_vision), jnp.float32
        )
    return out


def input_specs(arch: str, shape_name: str, cfg: ModelConfig | None = None):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    params = jax.eval_shape(
        functools.partial(init_model, cfg), jax.random.PRNGKey(0)
    )
    if shape.kind == "train":
        opt = jax.eval_shape(init_opt_state, params)
        return {"params": params, "opt": opt, "batch": batch_structs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_structs(cfg, shape)}
    caches = jax.eval_shape(
        functools.partial(init_caches, cfg, shape.global_batch, shape.seq_len)
    )
    return {
        "params": params,
        "caches": caches,
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               remat: str = "full", microbatches: int = 1,
               cfg: ModelConfig | None = None, scan_unroll: bool = False,
               attn_bf16: bool = False,
               rules_override: dict | None = None):
    """Build shardings, lower, compile.  Returns (compiled, meta dict)."""
    import dataclasses

    cfg = cfg if cfg is not None else get_config(arch)
    if attn_bf16:
        cfg = dataclasses.replace(cfg, attn_f32=False)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    specs = input_specs(arch, shape_name, cfg)

    # §Perf iteration: decode wants weights replicated across `data` and
    # sharded over `model` only (TP) — FSDP all-gathers per token are pure
    # overhead.  Keep FSDP only when a TP-only shard won't fit HBM (104B).
    rules: dict[str, tuple[str, ...]] = {}
    pbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(specs["params"]))
    if shape.kind == "decode" and pbytes / 16 <= 10e9:
        rules["fsdp"] = ()
        rules["expert_fsdp"] = ()
    if rules_override:
        rules.update(rules_override)

    with parallel.activate(mesh, rules) as ctx, mesh:
        p_specs = param_specs(ctx, specs["params"])
        t0 = time.time()
        if shape.kind == "train":
            o_specs = opt_specs(ctx, specs["params"], p_specs)
            b_specs = batch_specs(cfg, shape, ctx)
            step = make_train_step(
                cfg, OptimizerConfig(), remat=remat, microbatches=microbatches,
                backend="ref", scan_unroll=scan_unroll,
            )
            jitted = jax.jit(
                step,
                in_shardings=(named(mesh, p_specs), named(mesh, o_specs),
                              named(mesh, b_specs)),
                out_shardings=(named(mesh, p_specs), named(mesh, o_specs),
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(specs["params"], specs["opt"], specs["batch"])
            tokens = shape.global_batch * shape.seq_len
            model_flops = analysis.model_flops_train(cfg.n_active_params(), tokens)
        elif shape.kind == "prefill":
            b_specs = batch_specs(cfg, shape, ctx)
            step = make_prefill_step(cfg, backend="ref", scan_unroll=scan_unroll)
            logit_spec = ctx.resolve(
                (shape.global_batch, cfg.vocab_size), ("batch", "model")
            )
            jitted = jax.jit(
                step,
                in_shardings=(named(mesh, p_specs), named(mesh, b_specs)),
                out_shardings=NamedSharding(mesh, logit_spec),
            )
            lowered = jitted.lower(specs["params"], specs["batch"])
            tokens = shape.global_batch * shape.seq_len
            model_flops = analysis.model_flops_decode(cfg.n_active_params(), tokens)
        else:  # decode
            cache_bytes = sum(
                x.size * x.dtype.itemsize for x in jax.tree.leaves(specs["caches"])
            )
            c_specs = cache_specs(cfg, specs["caches"], ctx)
            tok_spec = ctx.resolve((shape.global_batch, 1), ("batch", None))
            logit_spec = ctx.resolve(
                (shape.global_batch, cfg.vocab_size), ("batch", "model")
            )
            step = make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(
                    named(mesh, p_specs), named(mesh, c_specs),
                    NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()),
                ),
                out_shardings=(
                    NamedSharding(mesh, logit_spec), named(mesh, c_specs)
                ),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                specs["params"], specs["caches"], specs["tokens"], specs["pos"]
            )
            model_flops = analysis.model_flops_decode(
                cfg.n_active_params(), shape.global_batch
            )
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    param_bytes = sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(specs["params"])
    )
    if shape.kind == "decode":
        # minimum HBM traffic per decode step: weights once + cache once
        model_bytes = 2.0 * cfg.n_active_params() + cache_bytes
    elif shape.kind == "train":
        # params fwd+bwd (bf16), f32 grads r/w, two f32 moments r/w, param upd
        model_bytes = 30.0 * cfg.n_params()
    else:  # prefill
        model_bytes = 2.0 * cfg.n_active_params()
    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "model_flops": model_flops, "model_bytes": model_bytes,
        "param_bytes": param_bytes,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }
    return compiled, meta


def _raw_stats(compiled) -> tuple[float, float, float, dict]:
    cost = compiled.cost_analysis() or {}
    stats = analysis.collective_stats(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        stats.wire_bytes_total,
        stats.counts,
    )


def scan_corrected_stats(arch: str, shape_name: str, *, multi_pod: bool,
                         remat: str, microbatches: int, full_stats: tuple,
                         attn_bf16: bool = False,
                         rules_override: dict | None = None,
                         ) -> tuple[float, float, float, dict]:
    """XLA's cost_analysis counts a rolled ``scan`` body ONCE, so
    train/prefill cells (scan-over-layers) under-report FLOPs/bytes/wire by
    ~L x.  Fix: lower the same cell with the layer scan *unrolled* at
    depths 1 and 2 (real ops — counted correctly), take the per-layer
    delta, and extrapolate to the full depth.  The einsum attention path
    makes every layer shape-identical (local/global differ only in mask
    *values*), so a single delta is exact for all archs, including
    gemma3/hymba heterogeneous schedules.  Decode cells are Python-unrolled
    already and need no correction.

    NOT corrected (documented in EXPERIMENTS.md §Roofline): the RWKV/SSM
    inner time-scan recurrence, whose FLOPs are <1% of the projection FLOPs
    and whose state stays VMEM-resident in a production kernel.
    """
    import numpy as np

    cfg = get_config(arch)
    L = cfg.n_layers

    def stats_at(depth: int):
        c, _ = lower_cell(arch, shape_name, multi_pod=multi_pod, remat=remat,
                          microbatches=microbatches,
                          cfg=replace_layers(cfg, depth), scan_unroll=True,
                          attn_bf16=attn_bf16, rules_override=rules_override)
        return np.array(_raw_stats(c)[:3])

    f1, f2 = stats_at(1), stats_at(2)
    per_layer = np.maximum(f2 - f1, 0.0)
    total = np.maximum(f2 + (L - 2) * per_layer, 0.0)
    return float(total[0]), float(total[1]), float(total[2]), full_stats[3]


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             save_hlo: bool = False, remat: str = "full",
             microbatches: int = 1, roofline: bool = True,
             attn_bf16: bool = False, rules_override: dict | None = None,
             tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")

    reason = cell_is_skipped(cfg, shape)
    if reason:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    try:
        compiled, meta = lower_cell(
            arch, shape_name, multi_pod=multi_pod, remat=remat,
            microbatches=microbatches, attn_bf16=attn_bf16,
            rules_override=rules_override,
        )
        full_stats = _raw_stats(compiled)
        if shape.kind in ("train", "prefill") and roofline:
            flops, byts, wire, counts = scan_corrected_stats(
                arch, shape_name, multi_pod=multi_pod, remat=remat,
                microbatches=microbatches, full_stats=full_stats,
                attn_bf16=attn_bf16, rules_override=rules_override,
            )
        else:
            flops, byts, wire, counts = full_stats
        roof = analysis.Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=meta["chips"],
            hlo_flops=flops, hlo_bytes=byts, wire_bytes=wire,
            model_flops=meta["model_flops"], bytes_per_device=None,
            collectives=counts, model_bytes=meta["model_bytes"],
        )
        mem = None
        try:
            mem = compiled.memory_analysis()
        except Exception:
            pass
        rec = {"status": "ok", **meta, **roof.as_dict()}
        if mem is not None:
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            }
        if save_hlo:
            with open(out_path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(compiled.as_text())
    except Exception as e:  # a failing cell is a bug — record and surface it
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-roofline", action="store_true",
                    help="compile-pass only (multi-pod sweep; the roofline "
                         "table is single-pod per the assignment)")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape, args.multi_pod))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        t0 = time.time()
        rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                       save_hlo=args.save_hlo, remat=args.remat,
                       microbatches=args.microbatches,
                       roofline=not args.no_roofline)
        status = rec["status"]
        msg = ""
        if status == "ok":
            msg = (f"flops={rec['hlo_flops']:.3e} wire={rec['wire_bytes']:.3e} "
                   f"bottleneck={rec['bottleneck']} "
                   f"roofline={rec['roofline_fraction']:.3f}")
        elif status == "error":
            failures += 1
            msg = rec["error"][:160]
        else:
            msg = rec["reason"]
        print(f"[{status:7s}] {arch:24s} {shape:12s} "
              f"{'2x16x16' if mp else '16x16':8s} ({time.time()-t0:5.1f}s) {msg}",
              flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
