"""Pallas kernel: int4 x int4 LUT matmul — bit-exact emulation of an
approximate multiplier netlist, MXU-native.

The obvious emulation of ``out[m,n] = Σ_k LUT[a[m,k], b[k,n]]`` is a gather
per (m, k, n) — fast on a GPU's shared memory, slow on TPU.  The TPU-native
rewrite (DESIGN.md §3) turns the LUT application into two dense
contractions that run on the MXU:

1. ``R[m, k, y] = Σ_x onehot(a)[m, k, x] · LUT[x, y]``
   — one (bm·bk, 16) x (16, 16) matmul: R row = the LUT row of ``a[m,k]``.
2. ``out[m, n] = Σ_{k, y} R[m, k·16+y] · O[k·16+y, n]`` with
   ``O[k·16+y, n] = [b[k,n] == y]``
   — one (bm, bk·16) x (bk·16, bn) matmul.

Accumulation is exact in f32 (products <= 255, K <= 2^15 ⇒ sums < 2^23).
The K dimension is tiled by the grid's sequential last axis; the f32
accumulator lives in the output block (revisited across k steps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, lut_ref, out_ref, *, bk: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]          # (bm, bk) int32
    b = b_ref[...]          # (bk, bn) int32
    lut = lut_ref[...]      # (16, 16) int32
    bm = a.shape[0]
    bn = b.shape[1]

    # R[m, k, y] = LUT[a[m, k], y] via one-hot @ LUT (MXU contraction)
    a_codes = jax.lax.broadcasted_iota(jnp.int32, (bm, bk, 16), 2)
    a_oh = (a[:, :, None] == a_codes).astype(jnp.float32)
    r = jax.lax.dot_general(
        a_oh.reshape(bm * bk, 16),
        lut.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bm, bk * 16)
    # O[(k, y), n] = [b[k, n] == y]
    b_codes = jax.lax.broadcasted_iota(jnp.int32, (bk, 16, bn), 1)
    b_oh = (b[:, None, :] == b_codes).astype(jnp.float32)
    o = b_oh.reshape(bk * 16, bn)
    acc = jax.lax.dot_general(
        r, o, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] += acc.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def approx_matmul_pallas(
    a: jax.Array,    # (M, K) int32 in [0, 16)
    b: jax.Array,    # (K, N) int32 in [0, 16)
    lut: jax.Array,  # (16, 16) int32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    _, N = b.shape
    pm, pn, pk = (-M) % block_m, (-N) % block_n, (-K) % block_k
    # K padding uses code 0; LUT[0, 0] may be nonzero for an approximate
    # netlist, so mask the padded-K contribution by padding `a` with a code
    # whose LUT row is forced to zero via a 17th virtual code — instead we
    # simply subtract the padded contribution analytically below.
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    grid = ((M + pm) // block_m, (N + pn) // block_n, (K + pk) // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, bk=block_k, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((16, 16), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M + pm, N + pn), jnp.int32),
        interpret=interpret,
    )(a, b, lut)
    out = out[:M, :N]
    if pk:  # remove the LUT[0,0] contribution of the K padding
        out = out - jnp.int32(pk) * lut[0, 0]
    return out
