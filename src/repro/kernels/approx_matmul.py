"""Pallas kernels: LUT matmuls — bit-exact emulation of approximate
multiplier netlists, MXU-native, at 4-bit and 8-bit operand widths.

The obvious emulation of ``out[m,n] = Σ_k LUT[a[m,k], b[k,n]]`` is a gather
per (m, k, n) — fast on a GPU's shared memory, slow on TPU.  The TPU-native
rewrite (DESIGN.md §3) turns the LUT application into two dense
contractions that run on the MXU:

1. ``R[m, k, y] = Σ_x onehot(a)[m, k, x] · LUT[x, y]``
   — one (bm·bk, 16) x (16, 16) matmul: R row = the LUT row of ``a[m,k]``.
2. ``out[m, n] = Σ_{k, y} R[m, k·16+y] · O[k·16+y, n]`` with
   ``O[k·16+y, n] = [b[k,n] == y]``
   — one (bm, bk·16) x (bk·16, bn) matmul.

**8-bit (W8A8) path.**  The same rewrite does not scale to 256 codes in
one contraction: the one-hot operands and the ``R`` intermediate grow 16x
(bm·bk·256 f32 alone overflows VMEM at useful block sizes).  But W8A8
tables in this stack are *composed* — :mod:`repro.precision.compose`
builds every 256x256 table as the exact shift-add of one 16x16 tile over
operand nibbles::

    LUT8[a, b] = T[al, bl] + (T[al, bh] + T[ah, bl]) << 4 + T[ah, bh] << 8

so ``Σ_k LUT8[a, b]`` factors into **four 16x16-tile LUT matmuls combined
by shift-add inside the kernel** — each over nibble planes of the codes,
all sharing the one tile already resident in VMEM.  The wrapper recovers
the tile from the (256, 256) table by exact integer inversion
(:func:`repro.precision.compose.extract_tile`'s jnp twin below), keeping
the public interface "codes + behaviour table" at every width — the
per-layer serving stack stays a plain jitted argument and hot-swaps
without retracing.  Tables that are *not* composed are out of contract
for the Pallas path (the ``ref`` backend eats them).

Accumulation: per k-block the contractions are exact in f32 (tile entries
<= 255, block_k <= 128 ⇒ partial sums < 2^24 even through the x289 shift
weights); blocks accumulate in int32, exact while
``K * max_entry * 289 < 2^31`` (see ``WidthSpec.max_k``).  The K
dimension is tiled by the grid's sequential last axis; the accumulator
lives in the output block (revisited across k steps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lut16_contract(x: jax.Array, y: jax.Array, lut_f32: jax.Array
                    ) -> jax.Array:
    """``Σ_k LUT[x[m,k], y[k,n]]`` for 4-bit codes via the one-hot-twice
    MXU form; shared by the 4-bit kernel (once) and the 8-bit kernel
    (once per nibble-plane pair)."""
    bm, bk = x.shape
    bn = y.shape[1]
    x_codes = jax.lax.broadcasted_iota(jnp.int32, (bm, bk, 16), 2)
    x_oh = (x[:, :, None] == x_codes).astype(jnp.float32)
    r = jax.lax.dot_general(
        x_oh.reshape(bm * bk, 16),
        lut_f32,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bm, bk * 16)
    y_codes = jax.lax.broadcasted_iota(jnp.int32, (bk, 16, bn), 1)
    y_oh = (y[:, None, :] == y_codes).astype(jnp.float32)
    return jax.lax.dot_general(
        r, y_oh.reshape(bk * 16, bn), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _kernel(a_ref, b_ref, lut_ref, out_ref, *, bk: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]          # (bm, bk) int32
    b = b_ref[...]          # (bk, bn) int32
    lut = lut_ref[...]      # (16, 16) int32
    acc = _lut16_contract(a, b, lut.astype(jnp.float32))
    out_ref[...] += acc.astype(jnp.int32)


def _kernel8(a_ref, b_ref, tile_ref, out_ref, *, bk: int, nk: int):
    """Two-level 8-bit form: four nibble-plane tile matmuls + shift-add."""
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]          # (bm, bk) int32 in [0, 256)
    b = b_ref[...]          # (bk, bn) int32 in [0, 256)
    tile = tile_ref[...].astype(jnp.float32)    # (16, 16) generator tile
    al, ah = a & 15, a >> 4
    bl, bh = b & 15, b >> 4
    s_ll = _lut16_contract(al, bl, tile)
    s_lh = _lut16_contract(al, bh, tile)
    s_hl = _lut16_contract(ah, bl, tile)
    s_hh = _lut16_contract(ah, bh, tile)
    # shift-add with f32-exact weights (partials < 2^24 per k-block)
    acc = s_ll + (s_lh + s_hl) * 16.0 + s_hh * 256.0
    out_ref[...] += acc.astype(jnp.int32)


def _extract_tile_jnp(lut: jax.Array) -> jax.Array:
    """jnp twin of :func:`repro.precision.compose.extract_tile` — exact
    integer inversion of the nibble shift-add for composed tables; runs
    inside the jitted wrapper so the (256, 256) stack entry stays the
    swap unit."""
    t00 = lut[0, 0] // 289
    tx0 = (lut[:16, 0] - 272 * t00) // 17
    t0y = (lut[0, :16] - 272 * t00) // 17
    return lut[:16, :16] - 16 * (tx0[:, None] + t0y[None, :]) - 256 * t00


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def approx_matmul_pallas(
    a: jax.Array,    # (M, K) int32 in [0, side)
    b: jax.Array,    # (K, N) int32 in [0, side)
    lut: jax.Array,  # (side, side) int32; side = 16 (4-bit) or 256 (8-bit)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    side = lut.shape[-1]
    if side == 16:
        kernel, table = _kernel, lut
    elif side == 256:
        # the 8-bit kernel consumes the 16x16 generator tile; recover it
        # from the composed table (exact for anything compose.py emits)
        kernel, table = _kernel8, _extract_tile_jnp(lut)
        # per-block f32 exactness bound: acc <= 255 * block_k * 289 must
        # stay under 2^24 or the shift-add rounds before the int32 cast,
        # silently breaking the bit-match-the-oracle contract
        max_bk = (1 << 24) // (255 * 289)
        if block_k > max_bk:
            raise ValueError(
                f"block_k {block_k} exceeds the 8-bit path's f32-exact "
                f"accumulation bound ({max_bk}); pick a smaller K block"
            )
    else:
        raise ValueError(f"unsupported LUT side {side}; expected 16 or 256")

    M, K = a.shape
    _, N = b.shape
    pm, pn, pk = (-M) % block_m, (-N) % block_n, (-K) % block_k
    # K padding uses code 0; LUT[0, 0] may be nonzero for an approximate
    # netlist (and a composed 8-bit table contributes exactly
    # LUT[0, 0] = 289 * T[0, 0] per padded k), so the padded-K
    # contribution is subtracted analytically below.
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    grid = ((M + pm) // block_m, (N + pn) // block_n, (K + pk) // block_k)

    out = pl.pallas_call(
        functools.partial(kernel, bk=block_k, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((16, 16), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M + pm, N + pn), jnp.int32),
        interpret=interpret,
    )(a, b, table)
    out = out[:M, :N]
    if pk:  # remove the LUT[0,0] contribution of the K padding
        out = out - jnp.int32(pk) * lut[0, 0]
    return out
