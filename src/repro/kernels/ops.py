"""Public jit'd wrappers for the Pallas kernels, with backend dispatch.

``backend='auto'`` picks the Pallas kernel on TPU and the pure-jnp oracle
(:mod:`repro.kernels.ref`) elsewhere — interpret-mode Pallas is for
*validation*, not production CPU execution.  Tests exercise both paths and
assert they agree.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from . import ref
from .approx_matmul import approx_matmul_pallas
from .flash_attention import flash_attention_pallas
from .template_eval import template_eval_pallas

Backend = Literal["auto", "pallas", "pallas_interpret", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: Backend) -> str:
    if backend != "auto":
        return backend
    return "pallas" if _on_tpu() else "ref"


def template_eval(lits, sel, in_tt, exact_vals, *, backend: Backend = "auto"):
    """Population worst-case-error; see :func:`repro.kernels.ref.template_eval`."""
    b = _resolve(backend)
    if b == "ref":
        return ref.template_eval(lits, sel, in_tt, exact_vals)
    return template_eval_pallas(
        lits, sel, in_tt, exact_vals, interpret=(b == "pallas_interpret")
    )


def approx_matmul(a, b, lut, *, backend: Backend = "auto"):
    """LUT matmul; see :func:`repro.kernels.ref.approx_matmul`."""
    bk = _resolve(backend)
    if bk == "ref":
        return ref.approx_matmul(a, b, lut)
    return approx_matmul_pallas(a, b, lut, interpret=(bk == "pallas_interpret"))


def flash_attention(
    q, k, v, *, causal=True, window=None, scale=None, backend: Backend = "auto"
):
    """Blockwise attention; see :func:`repro.kernels.ref.flash_attention`."""
    b = _resolve(backend)
    if b == "ref":
        return ref.flash_attention(q, k, v, causal=causal, window=window, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        interpret=(b == "pallas_interpret"),
    )
