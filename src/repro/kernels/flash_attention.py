"""Pallas kernel: blockwise streaming-softmax (flash) attention.

Used by train/prefill steps where attention dominates FLOPs (32k prefill).
Grid = (batch*heads, q-blocks, k-blocks) with the k axis sequential;
running max / denominator / accumulator live in VMEM scratch and are
renormalized per k block (the standard online-softmax recurrence).

TPU-specific choices:
* GQA is handled by the *index map* — the kv block for query-head ``h``
  is fetched from kv-head ``h // (H / Hkv)``; grouped heads share the same
  HBM→VMEM stream instead of materializing repeated KV.
* Causal and sliding-window masks skip fully-masked k blocks via
  ``pl.when`` predication (the grid still steps, but no MXU work issues).
* Stats are kept as (bq, 128) lane-replicated tiles, the layout the VPU
  reduces along without cross-lane shuffles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,    # (1, bq, D)
    k_ref,    # (1, bk, D)
    v_ref,    # (1, bk, D)
    o_ref,    # (1, bq, D)
    acc_ref,  # (bq, D) f32 scratch
    m_ref,    # (bq, 128) f32 scratch
    l_ref,    # (bq, 128) f32 scratch
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    nk: int,
    lq: int,
    lk: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # query positions are aligned to the *end* of the kv sequence (kv prefix)
    offs = lk - lq
    q_lo = iq * bq + offs
    k_lo = ik * bk
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_lo <= q_lo + bq - 1
    if window is not None:
        relevant &= k_lo + bk - 1 > q_lo - window

    @pl.when(relevant)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (bq, D)
        k = k_ref[0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0].astype(jnp.float32)              # (bk, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                      # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[:, 0:1]                         # (bq, 1)
        m_cur = logits.max(axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                    # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = corr * l_ref[:, 0:1] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[:, 0:1]
        denom = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, Lq, D)
    k: jax.Array,  # (B, Hkv, Lk, D)
    v: jax.Array,  # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Lq, D = q.shape
    _, Hkv, Lk, _ = k.shape
    rep = H // Hkv
    if scale is None:
        scale = float(1.0 / np.sqrt(D))
    bq = min(block_q, Lq)
    bk = min(block_k, Lk)
    assert Lq % bq == 0 and Lk % bk == 0, "pad sequence to block multiples"
    nq, nk = Lq // bq, Lk // bk

    qf = q.reshape(B * H, Lq, D)
    kf = k.reshape(B * Hkv, Lk, D)
    vf = v.reshape(B * Hkv, Lk, D)

    def kv_index(b, i, kblk):
        return ((b // H) * Hkv + (b % H) // rep, kblk, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, nk=nk, lq=Lq, lk=Lk,
        ),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, kblk: (b, i, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, kblk: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Lq, D)
