"""Pallas kernel: bit-packed shared-template population evaluation.

This is the compute hot-spot of the beyond-paper *tensorized ALS search*
(DESIGN.md §4): thousands of candidate parameter assignments are scored
against the full input space per generation.  The ∀-inputs sweep is
bit-packed — one ``uint32`` lane carries 32 input assignments — so a
candidate's products/sums are evaluated with word-wide VPU boolean ops, and
the per-assignment integer re-interpretation (the miter's ``map``) is an
unrolled shift/mask loop over the (static, <= 8) packed words.

Tiling: the grid runs over population blocks; each block holds the full
(T, n, m, W) problem — for paper-scale operators (n <= 8, T <= 16, m <= 8,
W <= 8) the per-block working set is a few hundred KB, far below VMEM.
All loops over n / T / W are static (unrolled at trace time).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

ALL_ONES = jnp.uint32(0xFFFFFFFF)
USE, NEG = 0, 1


def _kernel(
    lits_ref,   # (Pb, T, n) int32
    sel_ref,    # (Pb, m, T) int32
    tt_ref,     # (n, W) uint32
    ev_ref,     # (W * 32,) int32 (padded with zeros past S)
    out_ref,    # (Pb,) int32 — worst-case error
    sum_ref,    # (Pb,) int32 — total error over all assignments
    *,
    n: int,
    T: int,
    m: int,
    W: int,
    S: int,
):
    lits = lits_ref[...]
    sel = sel_ref[...]
    tt = tt_ref[...]
    ev = ev_ref[...]
    Pb = lits.shape[0]
    ones = np.uint32(0xFFFFFFFF)  # inline literal; Pallas forbids captured arrays

    # ---- products: AND over selected literals (bit-packed) -----------------
    prods = jnp.zeros((Pb, T, W), dtype=jnp.uint32) | ones
    for j in range(n):
        ttj = tt[j]                                   # (W,)
        litj = lits[:, :, j]                          # (Pb, T)
        use = (litj == USE)[..., None]
        neg = (litj == NEG)[..., None]
        term = jnp.where(use, ttj[None, None, :], ones) & jnp.where(
            neg, ~ttj[None, None, :], ones
        )
        prods = prods & term

    # ---- sums: OR over selected products ------------------------------------
    outs = jnp.zeros((Pb, m, W), dtype=jnp.uint32)
    for t in range(T):
        s = (sel[:, :, t] > 0)[..., None]             # (Pb, m, 1)
        outs = outs | jnp.where(s, prods[:, t][:, None, :], np.uint32(0))

    # ---- map + dist: per-assignment value, worst-case |err| ----------------
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (Pb, m, 32), 2)
    weights = jnp.int32(1) << jax.lax.broadcasted_iota(jnp.int32, (Pb, m, 32), 1)
    wce = jnp.zeros((Pb,), dtype=jnp.int32)
    esum = jnp.zeros((Pb,), dtype=jnp.int32)
    for w in range(W):
        word = outs[:, :, w]                          # (Pb, m) uint32
        bits = ((word[..., None] >> shifts) & np.uint32(1)).astype(jnp.int32)
        vals = (bits * weights).sum(axis=1)           # (Pb, 32)
        err = jnp.abs(vals - ev[None, 32 * w : 32 * (w + 1)])
        # mask lanes past the real input-space size S
        lane = 32 * w + jax.lax.broadcasted_iota(jnp.int32, (Pb, 32), 1)
        valid = (lane < S).astype(jnp.int32)
        err = err * valid
        wce = jnp.maximum(wce, err.max(axis=1))
        esum = esum + err.sum(axis=1)
    out_ref[...] = wce
    sum_ref[...] = esum


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def template_eval_pallas(
    lits: jax.Array,        # (P, T, n) int32
    sel: jax.Array,         # (P, m, T) int32
    in_tt: jax.Array,       # (n, W) uint32
    exact_vals: jax.Array,  # (S,) int32
    *,
    block_p: int = 256,
    interpret: bool = False,
) -> jax.Array:
    P, T, n = lits.shape
    m = sel.shape[1]
    W = in_tt.shape[1]
    S = exact_vals.shape[0]

    pad = (-P) % block_p
    if pad:
        lits = jnp.pad(lits, ((0, pad), (0, 0), (0, 0)))
        sel = jnp.pad(sel, ((0, pad), (0, 0), (0, 0)))
    ev = jnp.pad(exact_vals.astype(jnp.int32), (0, W * 32 - S))

    wce, esum = pl.pallas_call(
        functools.partial(_kernel, n=n, T=T, m=m, W=W, S=S),
        grid=((P + pad) // block_p,),
        in_specs=[
            pl.BlockSpec((block_p, T, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_p, m, T), lambda i: (i, 0, 0)),
            pl.BlockSpec((n, W), lambda i: (0, 0)),
            pl.BlockSpec((W * 32,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(((P + pad),), jnp.int32),
            jax.ShapeDtypeStruct(((P + pad),), jnp.int32),
        ],
        interpret=interpret,
    )(lits, sel, in_tt, ev)
    return wce[:P], esum[:P]
