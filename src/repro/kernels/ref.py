"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the *semantic definition*; the Pallas kernels are
checked against these in ``tests/test_kernels_*.py`` (shape/dtype sweeps,
``interpret=True`` on CPU).  They are also the CPU fallback used by
:mod:`repro.kernels.ops` when not running on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

ALL_ONES = jnp.uint32(0xFFFFFFFF)
USE, NEG, IGNORE = 0, 1, 2


# ---------------------------------------------------------------------------
# template_eval — population worst-case-error of shared-template candidates
# ---------------------------------------------------------------------------
def template_eval(
    lits: jax.Array,        # (P, T, n) int32 in {USE, NEG, IGNORE}
    sel: jax.Array,         # (P, m, T) int32 in {0, 1}
    in_tt: jax.Array,       # (n, W) uint32 — packed input truth tables
    exact_vals: jax.Array,  # (S,) int32 — exact value per assignment
) -> tuple[jax.Array, jax.Array]:  # (P,) worst-case error, (P,) total error
    P, T, n = lits.shape
    m = sel.shape[1]
    W = in_tt.shape[1]
    S = exact_vals.shape[0]

    tt = in_tt[None, None, :, :]  # (1, 1, n, W)
    use_term = jnp.where((lits == USE)[..., None], tt, ALL_ONES)
    neg_term = jnp.where((lits == NEG)[..., None], ~tt, ALL_ONES)
    comb = use_term & neg_term                       # (P, T, n, W)
    prods = comb[:, :, 0, :]
    for j in range(1, n):
        prods = prods & comb[:, :, j, :]             # (P, T, W)

    masked = jnp.where(sel[..., None].astype(bool), prods[:, None, :, :], jnp.uint32(0))
    outs = masked[:, :, 0, :]
    for t in range(1, T):
        outs = outs | masked[:, :, t, :]             # (P, m, W)

    # unpack to per-assignment values and take the worst-case error
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (outs[..., None] >> shifts[None, None, None, :]) & jnp.uint32(1)
    bits = bits.reshape(P, m, W * 32)[:, :, :S].astype(jnp.int32)   # (P, m, S)
    weights = (jnp.int32(1) << jnp.arange(m, dtype=jnp.int32))[None, :, None]
    vals = (bits * weights).sum(axis=1)              # (P, S)
    err = jnp.abs(vals - exact_vals[None, :])
    return err.max(axis=1), err.sum(axis=1)


# ---------------------------------------------------------------------------
# approx_matmul — LUT matmul at any operand width (bit-exact emulation of
# an approximate multiplier netlist; LUT[a, b] = netlist(a, b)).  The
# gather is the *semantic definition* for every width: codes index a
# square behaviour table — (16, 16) for the native 4-bit regime,
# (256, 256) for composed W8A8 tables — so this oracle accepts arbitrary
# tables, including non-composed ones the Pallas two-level path refuses.
# ---------------------------------------------------------------------------
def approx_matmul(
    a: jax.Array,     # (M, K) int32, values in [0, side)
    b: jax.Array,     # (K, N) int32, values in [0, side)
    lut: jax.Array,   # (side, side) int32 — approximate product table
) -> jax.Array:       # (M, N) int32 — sum_k LUT[a[m,k], b[k,n]]
    prods = lut[a[:, :, None], b[None, :, :]]        # (M, K, N)
    return prods.sum(axis=1, dtype=jnp.int32)


def approx_matmul_two_level(
    a: jax.Array,     # (M, K) int32, values in [0, 256)
    b: jax.Array,     # (K, N) int32, values in [0, 256)
    tile: jax.Array,  # (16, 16) int32 — the composed table's generator
) -> jax.Array:
    """Tile-form oracle of the 8-bit kernel: four nibble-plane 16x16 LUT
    matmuls combined by shift-add.  For any composed table
    ``lut8 = tile_to_width(tile)`` this equals
    ``approx_matmul(a, b, lut8)`` — the identity the kernel tests pin."""
    def s(x, y):
        return approx_matmul(x, y, tile)

    al, ah = a & 15, a >> 4
    bl, bh = b & 15, b >> 4
    return s(al, bl) + ((s(al, bh) + s(ah, bl)) << 4) + (s(ah, bh) << 8)


# ---------------------------------------------------------------------------
# flash_attention — causal streaming-softmax attention oracle
# ---------------------------------------------------------------------------
def flash_attention(
    q: jax.Array,  # (B, H, Lq, D)
    k: jax.Array,  # (B, Hkv, Lk, D)
    v: jax.Array,  # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    B, H, Lq, D = q.shape
    Hkv = k.shape[1]
    if scale is None:
        scale = 1.0 / np.sqrt(D)
    if Hkv != H:  # GQA: expand kv heads
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qi = jnp.arange(Lq)[:, None] + (k.shape[2] - Lq)  # align ends (kv prefix)
    ki = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Lq, k.shape[2]), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v).astype(q.dtype)
