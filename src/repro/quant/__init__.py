from .lut import build_lut, exact_mul_lut
from .int4 import approx_linear, dequantize, quantize_int4, quantize_intb

__all__ = [
    "build_lut",
    "exact_mul_lut",
    "quantize_int4",
    "quantize_intb",
    "approx_linear",
    "dequantize",
]
