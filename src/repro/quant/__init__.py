from .lut import build_lut, exact_mul_lut
from .int4 import quantize_int4, approx_linear, dequantize

__all__ = ["build_lut", "exact_mul_lut", "quantize_int4", "approx_linear", "dequantize"]
