"""Symmetric integer quantization + approximate-multiplier linear layers.

Signed b-bit activations/weights run on an *unsigned* bxb approximate
multiplier via the exact shift decomposition (``c = 2**(b-1)``)::

    (a' - c)(b' - c) = a'b' - c a' - c b' + c²,   a', b' in [0, 2**b)

Only the ``a'b'`` term goes through the (approximate) multiplier; the
correction terms are exact adder work — on real silicon these are the
cheap operators, and in emulation they are exact integer sums.  This is
how edge NN inference actually deploys the paper's unsigned multipliers
for signed tensors (DESIGN.md §3), and it is width-generic: the W4A4
regime uses ``c = 8`` with a 16x16 table, W8A8 uses ``c = 128`` with a
composed 256x256 table.  :func:`approx_linear` infers the width from the
table it is handed (shapes are static under jit, so width dispatch never
retraces on a hot-swap at a fixed width).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..precision.widths import NATIVE_BLOCK_BITS, get_width, width_from_lut


def quantize_intb(x: jax.Array, bits: int, axis: int = -1
                  ) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-slice b-bit quantization shared by every width:
    returns (codes in ``[0, 2**bits)``, scale).

    ``x ≈ (codes - 2**(bits-1)) * scale``; codes are biased-unsigned for
    the LUT (the symmetric range leaves code 0 unused).
    """
    w = get_width(bits)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / w.qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -w.qmax, w.qmax).astype(jnp.int32)
    return q + w.bias, scale


def quantize_int4(x: jax.Array, axis: int = -1) -> tuple[jax.Array, jax.Array]:
    """The historical 4-bit entry point (kept for callers and tests)."""
    return quantize_intb(x, NATIVE_BLOCK_BITS, axis=axis)


def dequantize(codes: jax.Array, scale: jax.Array,
               bits: int = NATIVE_BLOCK_BITS) -> jax.Array:
    bias = get_width(bits).bias
    return (codes.astype(jnp.float32) - float(bias)) * scale


def approx_linear(
    x: jax.Array,     # (..., K) float
    w: jax.Array,     # (K, N) float
    lut: jax.Array,   # (side, side) int32 approximate product table
    *,
    backend: str = "auto",
) -> jax.Array:
    """``x @ w`` through the approximate b-bit multiplier, bit-exact
    emulation at the width the table implies (16x16 -> W4A4,
    256x256 -> W8A8).

    Per-row activation scales, per-column weight scales (standard WbAb).
    """
    spec = width_from_lut(lut)
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    xq, sx = quantize_intb(x2, spec.bits, axis=-1)    # (M, K), (M, 1)
    wq, sw = quantize_intb(w, spec.bits, axis=0)      # (K, N), (1, N)

    raw = ops.approx_matmul(xq, wq, lut, backend=backend).astype(jnp.float32)
    # exact correction of the biased-unsigned decomposition
    c = float(spec.bias)
    sum_a = xq.sum(axis=1, keepdims=True).astype(jnp.float32)   # (M, 1)
    sum_b = wq.sum(axis=0, keepdims=True).astype(jnp.float32)   # (1, N)
    corrected = raw - c * sum_a - c * sum_b + c * c * K
    out = corrected * sx * sw
    return out.reshape(*lead, w.shape[1]).astype(x.dtype)
