"""Symmetric int4 quantization + approximate-multiplier linear layers.

Signed int4 activations/weights run on an *unsigned* 4x4 approximate
multiplier via the exact shift decomposition::

    (a' - 8)(b' - 8) = a'b' - 8 a' - 8 b' + 64,   a', b' in [0, 16)

Only the ``a'b'`` term goes through the (approximate) multiplier; the
correction terms are exact adder work — on real silicon these are the
cheap operators, and in emulation they are exact integer sums.  This is
how edge NN inference actually deploys the paper's unsigned multipliers
for signed tensors (DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops


def quantize_int4(x: jax.Array, axis: int = -1) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-slice int4: returns (codes in [0,16), scale).

    ``x ≈ (codes - 8) * scale``; codes are biased-unsigned for the LUT.
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / 7.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -7, 7).astype(jnp.int32) + 8
    return q, scale


def dequantize(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return (codes.astype(jnp.float32) - 8.0) * scale


def approx_linear(
    x: jax.Array,     # (..., K) float
    w: jax.Array,     # (K, N) float
    lut: jax.Array,   # (16, 16) int32 approximate product table
    *,
    backend: str = "auto",
) -> jax.Array:
    """``x @ w`` through the approximate 4-bit multiplier, bit-exact emulation.

    Per-row activation scales, per-column weight scales (standard W4A4).
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    xq, sx = quantize_int4(x2, axis=-1)          # (M, K), (M, 1)
    wq, sw = quantize_int4(w, axis=0)            # (K, N), (1, N)

    raw = ops.approx_matmul(xq, wq, lut, backend=backend).astype(jnp.float32)
    # exact correction of the biased-unsigned decomposition
    sum_a = xq.sum(axis=1, keepdims=True).astype(jnp.float32)   # (M, 1)
    sum_b = wq.sum(axis=0, keepdims=True).astype(jnp.float32)   # (1, N)
    corrected = raw - 8.0 * sum_a - 8.0 * sum_b + 64.0 * K
    out = corrected * sx * sw
    return out.reshape(*lead, w.shape[1]).astype(x.dtype)
