"""LUT construction: approximate multiplier netlist -> (16, 16) table.

This is the bridge from Layer A (ALS) to Layer B (at-scale emulation):
whatever circuit the search produced, its full behaviour over 4-bit
operands is a 256-entry table, which the Pallas ``approx_matmul`` kernel
then applies bit-exactly inside model matmuls.
"""

from __future__ import annotations

import numpy as np

from ..core.circuits import Circuit


def build_lut(mult_circuit: Circuit) -> np.ndarray:
    """Evaluate a 4x4-bit multiplier circuit into a (16, 16) int32 LUT.

    Input convention follows :mod:`repro.core.arith`: inputs are
    ``[a0..a3, b0..b3]`` LSB-first, so assignment index = a + 16*b.
    """
    assert mult_circuit.n_inputs == 8, "expects a 4-bit multiplier (8 inputs)"
    vals = mult_circuit.eval_words().astype(np.int32)  # (256,)
    lut = np.zeros((16, 16), dtype=np.int32)
    for b in range(16):
        for a in range(16):
            lut[a, b] = vals[a + 16 * b]
    return lut


def exact_mul_lut() -> np.ndarray:
    """The exact 4-bit product table (baseline for error measurements)."""
    a = np.arange(16, dtype=np.int32)
    return a[:, None] * a[None, :]
