"""LUT construction: approximate multiplier netlist -> (16, 16) table.

This is the bridge from Layer A (ALS) to Layer B (at-scale emulation):
whatever circuit the search produced, its full behaviour over 4-bit
operands is a 256-entry table, which the Pallas ``approx_matmul`` kernel
then applies bit-exactly inside model matmuls.
"""

from __future__ import annotations

import numpy as np

from ..core.circuits import Circuit


def build_lut(mult_circuit: Circuit) -> np.ndarray:
    """Evaluate a b-bit two-operand circuit into a (2**b, 2**b) int32 LUT.

    Input convention follows :mod:`repro.core.arith`: inputs are
    ``[a0.., b0..]`` LSB-first, so assignment index = a + 2**b * b'.
    The classic use is the 4-bit multiplier (a (16, 16) table the Pallas
    kernel consumes directly); smaller operators lower through
    :mod:`repro.library.compile`, which tiles/chains them up to 4 bits.
    """
    assert mult_circuit.n_inputs % 2 == 0, "expects a two-operand circuit"
    bits = mult_circuit.n_inputs // 2
    side = 1 << bits
    vals = mult_circuit.eval_words().astype(np.int32)  # (2**(2b),)
    a = np.arange(side)
    return vals[a[:, None] + side * a[None, :]]


def exact_mul_lut() -> np.ndarray:
    """The exact 4-bit product table (baseline for error measurements)."""
    a = np.arange(16, dtype=np.int32)
    return a[:, None] * a[None, :]
