"""Multi-replica serving front: a router over ≥2 continuous engines.

One process, several :class:`~repro.serving.engine.ContinuousServingEngine`
replicas sharing a single watched
:class:`~repro.library.store.OperatorStore` — but each with its *own*
plan state.  That is the piece a single engine cannot express: within one
decode step every slot shares one LUT stack, so the way to give ``gold``
exact tiles *while* ``batch`` traffic soaks on W8A8 is to home the
classes on different replicas.  The router:

* **routes** each arrival by class affinity first (a replica declaring
  ``classes=("gold",)`` gets every gold request it can hold), falling
  back to the least-loaded replica (active slots + queued work per slot,
  deterministic tie toward the earlier replica);
* **steps** all replicas in lockstep through their public
  ``submit``/``step_once`` API — each keeps its own slot pool, page
  allocator, telemetry, controller and scheduler;
* **polls the shared store once** per tick and fans a refresh out to
  every replica, each of which rebuilds its own ladder and revalidates
  its own stacks (a refused refresh on one replica leaves only that
  replica on its old plan).

Every replica's decode step still traces exactly once; the router adds
no device work of its own.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

from ..obs.trace import event as trace_event
from .engine import ContinuousServingEngine
from .loadgen import LoadProfile, Request, synth_requests
from .telemetry import Telemetry

__all__ = ["Replica", "ReplicaRouter"]


@dataclass
class Replica:
    """One engine plus its private control plane and class affinity."""

    name: str
    engine: ContinuousServingEngine
    controller: object | None = None
    scheduler: object | None = None
    online: object | None = None
    classes: tuple[str, ...] = ()    # QoS classes homed here ((): any)
    telemetry: Telemetry = field(default_factory=Telemetry)
    # per-replica obs.health.HealthPlane: its ok/warn/page state adds a
    # routing penalty so a degraded replica sheds load to healthy peers
    health: object | None = None

    @property
    def routing_score(self) -> float:
        score = self.engine.load_score
        if self.health is not None:
            score += self.health.penalty
        return score


class ReplicaRouter:
    def __init__(self, replicas: Sequence[Replica], *, watcher=None) -> None:
        if len(replicas) < 2:
            raise ValueError(
                f"a router fronts at least 2 replicas, got {len(replicas)}")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names {names}")
        self.replicas = list(replicas)
        self.watcher = watcher
        self.routed: dict[str, int] = {r.name: 0 for r in self.replicas}

    # ----------------------------------------------------------------- route
    def route(self, request: Request) -> Replica:
        """Class affinity first, then least-loaded *healthy*.  Affinity is
        a preference, not a wall: if no replica claims the class (or the
        claiming replicas are the only ones and all is equal) the load
        tie-break still yields a deterministic home.  A replica whose
        health plane reports warn/page carries a load-score penalty
        (:attr:`Replica.routing_score`) so it measurably sheds admissions
        while it burns — without being black-holed: it still wins when
        every healthy peer is proportionally busier."""
        homed = [r for r in self.replicas
                 if request.qos_class in r.classes]
        candidates = homed or self.replicas
        return min(candidates, key=lambda r: r.routing_score)

    def submit(self, request: Request, now: float | None = None) -> Replica:
        r = self.route(request)
        r.engine.submit(request, now)
        self.routed[r.name] += 1
        return r

    # ----------------------------------------------------------------- serve
    def start(self, *, log: Callable[[str], None] | None = None) -> None:
        for r in self.replicas:
            # stamp before start(): every req.* lifecycle event a replica
            # emits names the engine that served the request, so merged
            # timelines stay attributable in a multi-replica trace
            r.engine.replica_name = r.name
            r.engine.start(telemetry=r.telemetry, controller=r.controller,
                           scheduler=r.scheduler, online=r.online,
                           health=r.health, log=log)

    def step_all(self) -> bool:
        """One decode step on every replica with active work."""
        stepped = [r.engine.step_once() for r in self.replicas]
        return any(stepped)

    def _poll_shared_store(self, log=None) -> None:
        """One poll of the shared store, fanned out to every replica —
        per-replica ladders/levels survive, only the frontier refreshes."""
        if self.watcher is None or not self.watcher.poll():
            return
        try:
            fr = self.watcher.load_frontier()
        except LookupError as e:
            if log:
                log(f"router watcher: refresh skipped ({e})")
            return
        for r in self.replicas:
            if r.engine.plan is None:
                continue
            try:
                if r.engine._width_map is not None:
                    changed = r.engine.refresh_mixed(
                        fr, controller=r.controller, scheduler=r.scheduler,
                        telemetry=r.telemetry)
                else:
                    compiled, exact_area, _bits = fr
                    changed = r.engine.refresh_library(
                        compiled, exact_area, controller=r.controller,
                        scheduler=r.scheduler, telemetry=r.telemetry)
                trace_event("router.refresh", replica=r.name,
                            changed=changed)
            except (LookupError, ValueError) as e:
                if log:
                    log(f"router watcher ({r.name}): refresh skipped ({e})")

    def serve(self, profile: LoadProfile, *, seed: int = 0,
              steps_per_tick: int | None = None,
              log: Callable[[str], None] | None = None) -> dict:
        """Serve one load profile across the fleet and return the merged
        summary.  Arrivals route per request; all replicas then step in
        lockstep so a gold-homed replica never waits on a busy batch
        one."""
        import time

        self.start(log=log)
        per_tick = synth_requests(profile, self.replicas[0].engine.cfg
                                  .vocab_size, seed)
        steps = steps_per_tick or max(r.engine.steps_per_tick
                                      for r in self.replicas)
        for tick, reqs in enumerate(per_tick):
            now = time.perf_counter()
            for r in reqs:
                self.submit(r, now)
            for _ in range(steps):
                if not self.step_all():
                    break
            self._poll_shared_store(log)
        while self.step_all():
            pass
        return self.summary()

    # --------------------------------------------------------------- results
    def summary(self) -> dict:
        per = {}
        for r in self.replicas:
            s = r.telemetry.summary()
            s["routed"] = self.routed[r.name]
            s["trace_count"] = r.engine.trace_count
            if r.engine.plan is not None:
                s["plan"] = r.engine.plan.plan_id
                s["widths"] = list(r.engine.widths)
            if r.health is not None:
                s["health"] = r.health.report()
            per[r.name] = s
        total_req = sum(s["requests"] for s in per.values())
        out = {
            "replicas": per,
            "requests": total_req,
            "preemptions": sum(s.get("preemptions", 0)
                               for s in per.values()),
        }
        # fleet-level cost dividend: the per-replica attributions sum —
        # the invariant the multi-replica provenance test pins against
        # the merged ledger
        costs = [s["costs"] for s in per.values() if "costs" in s]
        if costs:
            out["costs"] = {
                "mlp_macs": sum(c["mlp_macs"] for c in costs),
                "approx_macs": sum(c["approx_macs"] for c in costs),
                "area_mac_saved": [
                    round(sum(c["area_mac_saved"][0] for c in costs), 4),
                    round(sum(c["area_mac_saved"][1] for c in costs), 4)],
            }
        return out
