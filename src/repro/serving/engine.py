"""Adaptive serving engine: request queue + batched greedy decode with
between-batch operator hot-swap.

The load-bearing design point: the per-layer ``(L, side, side)`` LUT
stack — ``(L, 16, 16)`` for W4A4, ``(L, 256, 256)`` for composed W8A8 —
is a *plain jitted argument* of the decode step, never a closed-over
constant.  Swapping QoS plans between batches therefore re-stacks a tiny
int32 array and changes nothing the compiler specialized on — the decode
step is traced exactly once for the whole serve, across every controller
move and library refresh (``trace_count`` pins this, and the end-to-end
test asserts it).

One ``run_batch`` call serves up to ``batch`` queued requests: prefill
walks the prompt through the *same* jitted decode step (one code path,
one trace), then greedy decode extends ``gen_len`` tokens.  Prefill and
decode are timed separately — a python-loop prefill is O(prompt) step
dispatches and would otherwise silently poison the decode throughput
number.  Between batches the engine consults the library watcher (store
changed? refresh the frontier) and the QoS controller (latency/drift
says move? swap the plan), both of which funnel through
:meth:`ServingEngine.swap_plan` and its shape/dtype validation.

Drift sampling: every ``shadow_every`` batches the final decode step is
also evaluated on copies of the caches with the *exact* LUT stack; the
mean |Δlogit| between the live and shadow step is the measured drift the
controller holds under its budget.  The shadow call reuses the one jitted
executable (same shapes, different table values).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..library.qos import LayerPlan, refresh_plan, stack_luts, validate_lut_stack
from ..models import decode_fn, init_caches
from .loadgen import LoadProfile, Request, synth_requests
from .telemetry import Telemetry

__all__ = ["BatchStats", "ServingEngine"]


@dataclass
class BatchStats:
    """Measurements of one served batch."""

    n_requests: int
    prefill_s: float
    decode_s: float
    prefill_tokens: int
    decode_tokens: int
    decode_steps: int
    drift: float | None = None

    @property
    def ms_per_step(self) -> float:
        return 1e3 * self.decode_s / max(1, self.decode_steps)

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / self.prefill_s if self.prefill_s else 0.0


class ServingEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        batch: int,
        prompt_len: int,
        gen_len: int,
        plan: LayerPlan | None = None,
        compiled=None,
        exact_area: float | None = None,
        sensitivities=None,
        warmup_caches: Callable | None = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.batch = int(batch)
        self.prompt_len = int(prompt_len)
        self.gen_len = int(gen_len)
        self.total = self.prompt_len + self.gen_len
        self._warmup = warmup_caches
        self._trace_count = 0
        self.last_tokens: np.ndarray | None = None   # (n_requests, gen_len)

        self._adaptive = plan is not None
        self._plan = plan
        self._compiled = list(compiled) if compiled is not None else []
        self._exact_area = exact_area
        self._sens = (np.ones(cfg.n_layers) if sensitivities is None
                      else np.asarray(sensitivities, dtype=np.float64))

        step = decode_fn(cfg)
        if self._adaptive:
            assert cfg.approx_mlp, (
                "adaptive serving routes MLP matmuls through LUTs; build the "
                "config with .with_approx_mlp()"
            )
            self._luts = jnp.asarray(stack_luts(plan, self._compiled))
            from ..precision.widths import exact_table, width_from_stack

            # the exact shadow stack shares the live stack's width — a
            # W8A8 serve shadows against the exact 256x256 product table
            self.width = width_from_stack(self._luts)
            side = self.width.side
            self._exact_luts = jnp.asarray(np.broadcast_to(
                exact_table("mul", self.width.bits).astype(np.int32),
                (cfg.n_layers, side, side)).copy())

            def step_fn(params, caches, tok, pos, luts):
                # python side effect runs once per *trace*, so this counts
                # compilations, not calls — the no-retrace-across-swaps
                # invariant is `trace_count == 1` after any number of swaps
                self._trace_count += 1
                return step(cfg, params, caches, tok, pos, luts=luts)
        else:
            self._luts = None
            self._exact_luts = None
            self.width = None

            def step_fn(params, caches, tok, pos):
                self._trace_count += 1
                return step(cfg, params, caches, tok, pos)

        self._jit_step = jax.jit(step_fn, donate_argnums=(1,))

    # ----------------------------------------------------------------- state
    @property
    def trace_count(self) -> int:
        """How many times the decode step has been traced (must stay 1)."""
        return self._trace_count

    @property
    def plan(self) -> LayerPlan | None:
        return self._plan

    def _step(self, caches, tok, pos, luts=None):
        if self._adaptive:
            return self._jit_step(self.params, caches, tok, pos,
                                  self._luts if luts is None else luts)
        return self._jit_step(self.params, caches, tok, pos)

    # ------------------------------------------------------------------ swap
    def swap_plan(self, plan: LayerPlan, stack, *, reason: str = "manual",
                  telemetry: Telemetry | None = None,
                  batch_idx: int = 0) -> bool:
        """Adopt a new plan between batches.  Validates the stack against
        the live one (shape/dtype — a mismatch would retrace), suppresses
        no-op swaps (same per-layer assignment), logs the swap.  Returns
        whether the plan actually changed."""
        assert self._adaptive, "engine was built without a QoS plan"
        if plan.plan_id == self._plan.plan_id:
            return False
        new = jnp.asarray(stack)
        validate_lut_stack(self._luts, new)
        old_id = self._plan.plan_id
        self._plan, self._luts = plan, new
        if telemetry is not None:
            telemetry.register_plan(plan)
            telemetry.record_swap(batch=batch_idx, reason=reason,
                                  old=old_id, new=plan.plan_id)
        return True

    def refresh_library(self, compiled, exact_area: float, *,
                        controller=None, reason: str = "library",
                        telemetry: Telemetry | None = None,
                        batch_idx: int = 0) -> bool:
        """Adopt a refreshed frontier (the watcher path).  With a
        controller, its ladder is rebuilt and its current level re-stacked;
        without one, the live plan's budget re-selects over the new
        frontier via :func:`repro.library.qos.refresh_plan`.

        Nothing — engine frontier, controller ladder — is mutated until the
        new stack passes :func:`~repro.library.qos.validate_lut_stack`
        inside :meth:`swap_plan`: a surprising store merge (e.g. a future
        8-bit frontier landing in a watched 4-bit store) raises and leaves
        the runtime serving consistently on the old plan."""
        if controller is not None:
            new_ladder = controller.ladder.refresh(compiled, exact_area)
            level = min(controller.level, len(new_ladder) - 1)
            plan, stack = new_ladder.plan(level), new_ladder.luts(level)
        else:
            new_ladder = level = None
            plan = refresh_plan(self._plan, compiled, self._sens,
                                exact_area=exact_area)
            stack = stack_luts(plan, compiled)
        changed = self.swap_plan(plan, stack, reason=reason,
                                 telemetry=telemetry, batch_idx=batch_idx)
        self._compiled = list(compiled)
        self._exact_area = exact_area
        if controller is not None:
            controller.adopt(new_ladder, level=level)
        return changed

    # ----------------------------------------------------------------- batch
    def run_batch(self, requests: list[Request], *,
                  shadow: bool = False) -> BatchStats:
        """Serve one batch: prefill the prompts, greedily decode
        ``gen_len`` tokens.  Short batches are zero-padded to the fixed
        batch size so every call reuses the single traced executable."""
        assert 0 < len(requests) <= self.batch
        prompts_np = np.zeros((self.batch, self.prompt_len), np.int32)
        for i, r in enumerate(requests):
            prompts_np[i] = r.tokens
        prompts = jnp.asarray(prompts_np)

        caches = init_caches(self.cfg, self.batch, self.total)
        if self._warmup is not None:
            caches = self._warmup(caches)

        t0 = time.perf_counter()
        logits = None
        for t in range(self.prompt_len):
            logits, caches = self._step(caches, prompts[:, t:t + 1],
                                        jnp.int32(t))
        logits.block_until_ready()
        t1 = time.perf_counter()

        shadow_logits = None
        shadow_s = 0.0
        generated = []
        for t in range(self.prompt_len, self.total):
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            generated.append(tok)
            if shadow and self._adaptive and t == self.total - 1:
                # exact shadow step on copies — the live call below donates
                # the real caches, the copies are consumed by the shadow.
                # Timed separately and excluded from decode_s: the shadow is
                # measurement overhead, and folding it into ms/step would
                # bias the very latency signal the controller acts on.
                ts = time.perf_counter()
                shadow_caches = jax.tree.map(jnp.copy, caches)
                shadow_logits, _ = self._jit_step(
                    self.params, shadow_caches, tok, jnp.int32(t),
                    self._exact_luts)
                shadow_logits.block_until_ready()
                shadow_s = time.perf_counter() - ts
            logits, caches = self._step(caches, tok, jnp.int32(t))
        logits.block_until_ready()
        t2 = time.perf_counter()

        n = len(requests)
        drift = None
        if shadow_logits is not None:
            # only the real rows: zero-padded requests decode garbage and
            # would contaminate the controller's drift signal on the
            # partial batches ramp/spike load produces routinely
            drift = float(jnp.abs(logits[:n] - shadow_logits[:n]).mean())
        # completions for the real (unpadded) requests — a degenerate
        # repeated-token sample is also the quickest eyeball check that an
        # aggressive plan's LUT routing is live in decode
        self.last_tokens = np.asarray(jnp.concatenate(generated, axis=1))[:n]
        return BatchStats(
            n_requests=n,
            prefill_s=t1 - t0,
            decode_s=t2 - t1 - shadow_s,
            prefill_tokens=n * self.prompt_len,
            decode_tokens=n * self.gen_len,
            decode_steps=self.gen_len,
            drift=drift,
        )

    # ----------------------------------------------------------------- serve
    def serve(
        self,
        profile: LoadProfile,
        *,
        controller=None,
        watcher=None,
        telemetry: Telemetry | None = None,
        seed: int = 0,
        on_batch_end: Callable[["ServingEngine", int], None] | None = None,
        log: Callable[[str], None] | None = None,
    ) -> Telemetry:
        """Run the full serving loop over a synthetic load profile.

        Each tick's arrivals join the queue; the queue drains in batches
        of up to ``batch`` requests.  After every batch the control plane
        runs: watcher poll (library refresh), controller observe (plan
        move), then the optional ``on_batch_end`` hook (tests use it to
        mutate the store mid-serve)."""
        assert profile.prompt_len == self.prompt_len
        assert profile.gen_len == self.gen_len
        telemetry = telemetry or Telemetry()
        if self._adaptive:
            telemetry.register_plan(self._plan)
        per_tick = synth_requests(profile, self.cfg.vocab_size, seed)
        queue: deque[Request] = deque()
        batch_idx = 0
        for tick in range(profile.n_ticks):
            queue.extend(per_tick[tick])
            while queue:
                reqs = [queue.popleft()
                        for _ in range(min(self.batch, len(queue)))]
                backlog = len(queue)   # requests still waiting behind this batch
                want_shadow = (controller is not None and self._adaptive
                               and controller.wants_shadow(batch_idx))
                stats = self.run_batch(reqs, shadow=want_shadow)
                telemetry.record_batch(
                    batch=batch_idx, tick=tick, n_requests=stats.n_requests,
                    prefill_s=stats.prefill_s, decode_s=stats.decode_s,
                    prefill_tokens=stats.prefill_tokens,
                    decode_tokens=stats.decode_tokens,
                    decode_steps=stats.decode_steps,
                    plan_id=self._plan.plan_id if self._adaptive else None,
                    drift=stats.drift, backlog=backlog,
                )

                # ---- between-batch control plane ------------------------
                if watcher is not None and self._adaptive and watcher.poll():
                    try:
                        compiled, exact_area, _bits = watcher.load_frontier()
                        # LookupError: store emptied; ValueError: refreshed
                        # stack would retrace (validate_lut_stack refused).
                        # Either way the server keeps running on the old,
                        # still-consistent plan.
                        if self.refresh_library(
                                compiled, exact_area, controller=controller,
                                telemetry=telemetry, batch_idx=batch_idx
                        ) and log:
                            log(f"batch {batch_idx}: library refresh -> "
                                f"plan {self._plan.plan_id}")
                    except (LookupError, ValueError) as e:
                        if log:
                            log(f"watcher: refresh skipped ({e})")
                if controller is not None and self._adaptive:
                    # the load signal is *effective* ms/step: service time
                    # scaled by outstanding work (Little's-law flavour) —
                    # raw step latency is nearly plan-independent, so a
                    # building queue, not the step clock, is what says
                    # "trade accuracy for throughput" under ramp/spike load
                    eff_ms = stats.ms_per_step * (1.0 + backlog / self.batch)
                    level = controller.observe(eff_ms, stats.drift)
                    if level is not None:
                        moved = self.swap_plan(
                            controller.plan, controller.luts(),
                            reason=f"qos-{controller.last_reason}",
                            telemetry=telemetry, batch_idx=batch_idx)
                        if moved and log:
                            log(f"batch {batch_idx}: controller -> level "
                                f"{level} ({controller.last_reason}), plan "
                                f"{self._plan.plan_id}")
                if on_batch_end is not None:
                    on_batch_end(self, batch_idx)
                batch_idx += 1
        return telemetry
