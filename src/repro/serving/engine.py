"""Adaptive serving engine: request queue + batched greedy decode with
between-batch operator hot-swap.

The load-bearing design point: the per-layer ``(L, side, side)`` LUT
stack — ``(L, 16, 16)`` for W4A4, ``(L, 256, 256)`` for composed W8A8 —
is a *plain jitted argument* of the decode step, never a closed-over
constant.  Swapping QoS plans between batches therefore re-stacks a tiny
int32 array and changes nothing the compiler specialized on — the decode
step is traced exactly once for the whole serve, across every controller
move and library refresh (``trace_count`` pins this, and the end-to-end
test asserts it).

One ``run_batch`` call serves up to ``batch`` queued requests: prefill
walks the prompt through the *same* jitted decode step (one code path,
one trace), then greedy decode extends ``gen_len`` tokens.  Prefill and
decode are timed separately — a python-loop prefill is O(prompt) step
dispatches and would otherwise silently poison the decode throughput
number.  Between batches the engine consults the library watcher (store
changed? refresh the frontier) and the QoS controller (latency/drift
says move? swap the plan), both of which funnel through
:meth:`ServingEngine.swap_plan` and its shape/dtype validation.

Drift sampling: every ``shadow_every`` batches the final decode step is
also evaluated on copies of the caches with the *exact* LUT stack; the
mean |Δlogit| between the live and shadow step is the measured drift the
controller holds under its budget.  The shadow call reuses the one jitted
executable (same shapes, different table values).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..library.qos import (LayerPlan, plan_layer_areas, refresh_plan,
                           stack_luts, validate_lut_stack)
from ..models import decode_fn, init_caches
from ..obs.trace import current_tracer
from ..obs.trace import event as trace_event
from ..obs.trace import span as trace_span
from .controller import effective_load_ms
from .loadgen import LoadProfile, Request, synth_requests
from .telemetry import Telemetry

__all__ = ["BatchStats", "ServingEngine", "ContinuousServingEngine"]


def _area_hi_map(compiled) -> dict[str, float]:
    """Operator key -> glue-inclusive area upper bound over a compiled
    frontier (``CompiledLut.area_hi``; records compiled without a
    bracket collapse to their own area).  Mixed-width frontiers can
    carry one key at two widths — keeping the max keeps the value a
    sound upper bound."""
    out: dict[str, float] = {}
    for rec, comp in compiled:
        hi = getattr(comp, "area_hi", None)
        hi = rec.area if hi is None else max(rec.area, hi)
        out[rec.key] = max(out.get(rec.key, 0.0), hi)
    return out


@dataclass
class BatchStats:
    """Measurements of one served batch."""

    n_requests: int
    prefill_s: float
    decode_s: float
    prefill_tokens: int
    decode_tokens: int
    decode_steps: int
    drift: float | None = None

    @property
    def ms_per_step(self) -> float:
        return 1e3 * self.decode_s / max(1, self.decode_steps)

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / self.prefill_s if self.prefill_s else 0.0


class ServingEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        batch: int,
        prompt_len: int,
        gen_len: int,
        plan: LayerPlan | None = None,
        compiled=None,
        exact_area: float | None = None,
        sensitivities=None,
        width_map=None,
        sens_profile=None,
        warmup_caches: Callable | None = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.batch = int(batch)
        self.prompt_len = int(prompt_len)
        self.gen_len = int(gen_len)
        self.total = self.prompt_len + self.gen_len
        self._warmup = warmup_caches
        self._trace_count = 0
        self.last_tokens: np.ndarray | None = None   # (n_requests, gen_len)
        # the SLO health plane (obs.health.HealthPlane), bound by serve()/
        # start(); every control-plane trace event is mirrored into it so
        # fired anomalies attribute to the exact swap/refresh/control span
        self._health = None
        # test/chaos hook: extra seconds slept inside the *timed* step
        # section — the induced-latency-spike drill flips this mid-serve
        self.inject_step_delay = 0.0

        self._adaptive = plan is not None
        self._plan = plan
        self._compiled = list(compiled) if compiled is not None else []
        self._exact_area = exact_area
        # per-layer sensitivities: a vector for uniform-width serves, a
        # {bits: vector-or-matrix} dict for mixed-width (kept for the
        # watcher's ladder rebuild)
        if isinstance(sensitivities, dict):
            self._sens = sensitivities
        else:
            self._sens = (np.ones(cfg.n_layers) if sensitivities is None
                          else np.asarray(sensitivities, dtype=np.float64))
        self._width_map = (tuple(int(b) for b in width_map)
                           if width_map is not None else None)
        # measured SensitivityProfile (optional): refresh paths re-price
        # measured cost matrices against the *refreshed* frontier through
        # it — a stale (L, O) matrix cannot follow a frontier whose
        # operator set a background fleet sweep just changed
        self._profile = sens_profile
        self._mae_by_key = {rec.key: comp.mae
                            for rec, comp in self._compiled}
        self._area_hi_by_key = _area_hi_map(self._compiled)
        # per-plan cost rows (repro.obs.costs.plan_cost_row), cached by
        # plan_id so the per-step cost attribution is a dict lookup
        self._cost_rows: dict[str, dict] = {}
        self._macs_per_layer = None

        if self._adaptive:
            assert cfg.approx_mlp, (
                "adaptive serving routes MLP matmuls through LUTs; build the "
                "config with .with_approx_mlp()"
            )
            if self._width_map is not None:
                # mixed-width: one stack per width group, the per-layer
                # width routing is a static part of the single trace
                assert len(self._width_map) == cfg.n_layers
                from ..precision.plans import (exact_mixed_stacks,
                                               stack_mixed_luts)

                self._luts = {
                    b: jnp.asarray(a) for b, a in stack_mixed_luts(
                        plan, self._compiled, self._width_map).items()}
                self._exact_luts = {
                    b: jnp.asarray(a)
                    for b, a in exact_mixed_stacks(self._width_map).items()}
                self.width = None
                self.widths = tuple(sorted(set(self._width_map)))
            else:
                self._luts = jnp.asarray(stack_luts(plan, self._compiled))
                from ..precision.widths import exact_table, width_from_stack

                # the exact shadow stack shares the live stack's width — a
                # W8A8 serve shadows against the exact 256x256 product table
                self.width = width_from_stack(self._luts)
                self.widths = (self.width.bits,)
                side = self.width.side
                self._exact_luts = jnp.asarray(np.broadcast_to(
                    exact_table("mul", self.width.bits).astype(np.int32),
                    (cfg.n_layers, side, side)).copy())
        else:
            self._luts = None
            self._exact_luts = None
            self.width = None
            self.widths = ()

        self._jit_step = jax.jit(self._make_step_fn(), donate_argnums=(1,))

    def _make_step_fn(self):
        """Build the closure the engine jits exactly once.  Subclasses
        (the continuous-batching engine) override this to route through a
        different decode step; everything else — LUT stacking, swap
        validation, watcher refresh — is shared."""
        step = decode_fn(self.cfg)
        cfg, wm = self.cfg, self._width_map
        if self._adaptive:
            def step_fn(params, caches, tok, pos, luts):
                # python side effect runs once per *trace*, so this counts
                # compilations, not calls — the no-retrace-across-swaps
                # invariant is `trace_count == 1` after any number of swaps
                self._trace_count += 1
                if wm is not None:
                    return step(cfg, params, caches, tok, pos, luts=luts,
                                width_map=wm)
                return step(cfg, params, caches, tok, pos, luts=luts)
        else:
            def step_fn(params, caches, tok, pos):
                self._trace_count += 1
                return step(cfg, params, caches, tok, pos)
        return step_fn

    # ----------------------------------------------------------------- state
    @property
    def trace_count(self) -> int:
        """How many times the decode step has been traced (must stay 1)."""
        return self._trace_count

    @property
    def plan(self) -> LayerPlan | None:
        return self._plan

    def _step(self, caches, tok, pos, luts=None):
        if self._adaptive:
            return self._jit_step(self.params, caches, tok, pos,
                                  self._luts if luts is None else luts)
        return self._jit_step(self.params, caches, tok, pos)

    # ------------------------------------------------------------------ swap
    def swap_plan(self, plan: LayerPlan, stack, *, reason: str = "manual",
                  telemetry: Telemetry | None = None,
                  batch_idx: int = 0) -> bool:
        """Adopt a new plan between batches.  Validates the stack against
        the live one (shape/dtype — a mismatch would retrace), suppresses
        no-op swaps (same per-layer assignment), logs the swap.  Returns
        whether the plan actually changed."""
        assert self._adaptive, "engine was built without a QoS plan"
        if plan.plan_id == self._plan.plan_id:
            return False
        new = (dict((b, jnp.asarray(a)) for b, a in stack.items())
               if isinstance(stack, dict) else jnp.asarray(stack))
        validate_lut_stack(self._luts, new)
        old_id = self._plan.plan_id
        self._plan, self._luts = plan, new
        if telemetry is not None:
            telemetry.register_plan(plan)
            telemetry.record_swap(batch=batch_idx, reason=reason,
                                  old=old_id, new=plan.plan_id)
        eid = trace_event("serve.swap", reason=reason, batch=batch_idx,
                          old=old_id, new=plan.plan_id)
        if self._health is not None:
            self._health.note_event("serve.swap", step=batch_idx,
                                    event_id=eid, reason=reason,
                                    old=old_id, new=plan.plan_id)
        return True

    def refresh_library(self, compiled, exact_area: float, *,
                        controller=None, scheduler=None,
                        reason: str = "library",
                        telemetry: Telemetry | None = None,
                        batch_idx: int = 0) -> bool:
        """Adopt a refreshed frontier (the watcher path).  With a
        controller (or class scheduler), its ladder is rebuilt and the
        current level re-stacked; without either, the live plan's budget
        re-selects over the new frontier via
        :func:`repro.library.qos.refresh_plan`.

        Nothing — engine frontier, controller ladder — is mutated until the
        new stack passes :func:`~repro.library.qos.validate_lut_stack`
        inside :meth:`swap_plan`: a surprising store merge (e.g. a future
        8-bit frontier landing in a watched 4-bit store) raises and leaves
        the runtime serving consistently on the old plan."""
        # with a measured profile, re-price the refreshed frontier (a
        # stale (L, O) matrix cannot index new operator columns); without
        # one, the ladder keeps its own sensitivity model as before
        new_sens = self._uniform_sens(compiled)
        if controller is not None or scheduler is not None:
            owner = (controller.ladder if controller is not None
                     else scheduler.ladder)
            new_ladder = owner.refresh(compiled, exact_area,
                                       sensitivities=new_sens)
            level = (min(controller.level, len(new_ladder) - 1)
                     if controller is not None else 0)
            plan, stack = new_ladder.plan(level), new_ladder.luts(level)
        else:
            new_ladder = level = None
            plan = refresh_plan(
                self._plan, compiled,
                self._sens if new_sens is None else new_sens,
                exact_area=exact_area)
            stack = stack_luts(plan, compiled)
        changed = self.swap_plan(plan, stack, reason=reason,
                                 telemetry=telemetry, batch_idx=batch_idx)
        self._compiled = list(compiled)
        self._mae_by_key = {rec.key: comp.mae for rec, comp in self._compiled}
        self._area_hi_by_key = _area_hi_map(self._compiled)
        self._cost_rows = {}
        self._exact_area = exact_area
        if controller is not None:
            controller.adopt(new_ladder, level=level)
        if scheduler is not None:
            scheduler.adopt(new_ladder)
        return changed

    def refresh_mixed(self, mixed, *, controller=None, scheduler=None,
                      reason: str = "library",
                      telemetry: Telemetry | None = None,
                      batch_idx: int = 0) -> bool:
        """The mixed-width watcher path: rebuild the plan ladder over a
        refreshed :class:`~repro.precision.plans.MixedFrontier` *inside*
        the frozen width map, then re-point the controller and the class
        scheduler at it.  Group shapes are fixed by the width map, so the
        new level stacks validate against the live ones by construction —
        and are checked anyway before anything is adopted."""
        from ..precision.plans import (build_mixed_ladder,
                                       mixed_cost_matrix, stack_mixed_luts)

        assert self._width_map is not None, "engine serves a uniform width"
        sens = self._mixed_sens(mixed)
        old = (controller.ladder if controller is not None
               else scheduler.ladder if scheduler is not None else None)
        if old is None:
            # plain mixed serve (no controller / classes): the analog of
            # the refresh_plan path — re-select the live plan's budget
            # inside the frozen width map and keep serving
            wm = np.asarray(self._width_map)
            plan = refresh_plan(
                self._plan, mixed.compiled,
                mixed_cost_matrix(mixed, sens, len(wm)),
                exact_area=mixed.exact_areas(self._width_map),
                allowed=mixed.op_bits[None, :] == wm[:, None])
            stack = stack_mixed_luts(plan, mixed.compiled, self._width_map)
        else:
            new_ladder = build_mixed_ladder(
                mixed, self._width_map, sens,
                levels=old.requested_levels)
            level = (min(controller.level, len(new_ladder) - 1)
                     if controller is not None else 0)
            plan, stack = new_ladder.plan(level), new_ladder.luts(level)
        changed = self.swap_plan(plan, stack, reason=reason,
                                 telemetry=telemetry, batch_idx=batch_idx)
        self._compiled = list(mixed.compiled)
        self._mae_by_key = {rec.key: comp.mae for rec, comp in self._compiled}
        self._area_hi_by_key = _area_hi_map(self._compiled)
        self._cost_rows = {}
        if old is not None and controller is not None:
            controller.adopt(new_ladder, level=level)
        if old is not None and scheduler is not None:
            scheduler.adopt(new_ladder)
        return changed

    def _uniform_sens(self, compiled):
        """Measured pricing for a refreshed uniform-width frontier, or
        ``None`` when there is no profile (the caller keeps its own
        sensitivity model)."""
        if self._profile is None:
            return None
        from ..sensitivity.profile import costs_for

        return costs_for(self._profile, self.width.bits, compiled,
                         self.cfg.n_layers)

    def _mixed_sens(self, mixed):
        """Per-width pricing for a refreshed mixed frontier: measured via
        the profile when present, else the constructor's sensitivity
        model (vectors follow any frontier; a caller-supplied measured
        matrix cannot, and the resulting ValueError makes the watcher
        skip the refresh)."""
        if self._profile is None:
            return self._sens
        from ..sensitivity.profile import costs_for

        return {bits: costs_for(self._profile, bits, fr.compiled,
                                self.cfg.n_layers)
                for bits, fr in mixed.by_width.items()}

    def _plan_maes(self, plan: LayerPlan) -> np.ndarray:
        """Per-layer operator mae of a plan (0 for exact layers) — the
        attribution vector the online sensitivity estimator consumes."""
        return np.array([0.0 if c.key is None
                         else self._mae_by_key.get(c.key, 0.0)
                         for c in plan.choices])

    # ----------------------------------------------------------------- batch
    def run_batch(self, requests: list[Request], *,
                  shadow: bool = False, luts=None) -> BatchStats:
        """Serve one batch: prefill the prompts, greedily decode
        ``gen_len`` tokens.  Short batches are zero-padded to the fixed
        batch size so every call reuses the single traced executable.

        ``luts`` overrides the engine's live stack for this batch only —
        the class-aware serve passes each batch its QoS class's plan
        stack (same shapes, so still the one trace)."""
        assert 0 < len(requests) <= self.batch
        if luts is not None:
            luts = (dict((b, jnp.asarray(a)) for b, a in luts.items())
                    if isinstance(luts, dict) else jnp.asarray(luts))
        prompts_np = np.zeros((self.batch, self.prompt_len), np.int32)
        for i, r in enumerate(requests):
            # heterogeneous prompt lengths zero-pad to the fixed geometry:
            # the fixed-batch engine pays max-length for every request,
            # which is exactly the cost paged continuous batching removes
            assert len(r.tokens) <= self.prompt_len, (
                f"request {r.rid} prompt ({len(r.tokens)}) exceeds engine "
                f"prompt_len ({self.prompt_len})")
            prompts_np[i, :len(r.tokens)] = r.tokens
        prompts = jnp.asarray(prompts_np)

        caches = init_caches(self.cfg, self.batch, self.total)
        if self._warmup is not None:
            caches = self._warmup(caches)

        with trace_span("serve.batch", n_requests=len(requests)) as batch_sp:
            with trace_span("serve.prefill",
                            tokens=len(requests) * self.prompt_len):
                t0 = time.perf_counter()
                logits = None
                for t in range(self.prompt_len):
                    logits, caches = self._step(caches, prompts[:, t:t + 1],
                                                jnp.int32(t), luts=luts)
                logits.block_until_ready()
                t1 = time.perf_counter()

            shadow_logits = None
            shadow_s = 0.0
            generated = []
            with trace_span("serve.decode", steps=self.gen_len) as decode_sp:
                for t in range(self.prompt_len, self.total):
                    tok = jnp.argmax(logits, axis=-1)[:, None]
                    tok = tok.astype(jnp.int32)
                    generated.append(tok)
                    if shadow and self._adaptive and t == self.total - 1:
                        # exact shadow step on copies — the live call below
                        # donates the real caches, the copies are consumed by
                        # the shadow.  Timed separately and excluded from
                        # decode_s: the shadow is measurement overhead, and
                        # folding it into ms/step would bias the very latency
                        # signal the controller acts on.
                        with trace_span("serve.shadow"):
                            ts = time.perf_counter()
                            shadow_caches = jax.tree.map(jnp.copy, caches)
                            shadow_logits, _ = self._jit_step(
                                self.params, shadow_caches, tok, jnp.int32(t),
                                self._exact_luts)
                            shadow_logits.block_until_ready()
                            shadow_s = time.perf_counter() - ts
                    logits, caches = self._step(caches, tok, jnp.int32(t),
                                                luts=luts)
                logits.block_until_ready()
                t2 = time.perf_counter()
                decode_sp.set(shadow_s=round(shadow_s, 6))

            n = len(requests)
            drift = None
            if shadow_logits is not None:
                # only the real rows: zero-padded requests decode garbage and
                # would contaminate the controller's drift signal on the
                # partial batches ramp/spike load produces routinely
                drift = float(jnp.abs(logits[:n] - shadow_logits[:n]).mean())
            stats = BatchStats(
                n_requests=n,
                prefill_s=t1 - t0,
                decode_s=t2 - t1 - shadow_s,
                prefill_tokens=n * self.prompt_len,
                decode_tokens=n * self.gen_len,
                decode_steps=self.gen_len,
                drift=drift,
            )
            batch_sp.set(ms_per_step=round(stats.ms_per_step, 3),
                         decode_tok_s=round(stats.decode_tok_s, 2))
            if drift is not None:
                batch_sp.set(drift=round(drift, 6))
        # completions for the real (unpadded) requests — a degenerate
        # repeated-token sample is also the quickest eyeball check that an
        # aggressive plan's LUT routing is live in decode
        self.last_tokens = np.asarray(jnp.concatenate(generated, axis=1))[:n]
        return stats

    # ----------------------------------------------------------------- serve
    def serve(
        self,
        profile: LoadProfile,
        *,
        controller=None,
        watcher=None,
        scheduler=None,
        online=None,
        telemetry: Telemetry | None = None,
        seed: int = 0,
        on_batch_end: Callable[["ServingEngine", int], None] | None = None,
        log: Callable[[str], None] | None = None,
        health=None,
    ) -> Telemetry:
        """Run the full serving loop over a synthetic load profile.

        Each tick's arrivals join the queue; the queue drains in batches
        of up to ``batch`` requests.  With a class ``scheduler``
        (:class:`repro.sensitivity.classes.ClassScheduler`) there is one
        queue per declared QoS class, drained in priority order, and each
        batch decodes on *its class's* plan stack — same shapes, same
        single trace, but ``gold`` rides a more exact level than
        ``batch``.  After every batch the control plane runs: watcher
        poll (library refresh), per-class drift bookkeeping, online
        sensitivity update, controller observe (global level move), then
        the optional ``on_batch_end`` hook (tests use it to mutate the
        store mid-serve)."""
        assert profile.prompt_len == self.prompt_len
        assert profile.gen_len == self.gen_len
        if scheduler is not None:
            assert self._adaptive, "class-aware serving needs a QoS plan"
        telemetry = telemetry or Telemetry()
        self._health = health
        if self._adaptive:
            telemetry.register_plan(self._plan)
        per_tick = synth_requests(profile, self.cfg.vocab_size, seed)
        queue: deque[Request] = deque()
        queues: dict[str, deque[Request]] | None = None
        if scheduler is not None:
            queues = {name: deque() for name in scheduler.book.names}
        # wall-clock enqueue times (requests themselves carry only the
        # synthetic arrival tick) so drained batches can report real
        # time-in-queue to the per-class wait histograms
        enqueued_at: dict[int, float] = {}
        # device-resident class stacks, keyed by ladder level and
        # invalidated on ladder refresh — without this every class batch
        # would re-upload its (n_layers, side, side) stack host-to-device
        device_stacks: dict[int, object] = {}
        device_ladder = None
        batch_idx = 0
        for tick in range(profile.n_ticks):
            now = time.perf_counter()
            for r in per_tick[tick]:
                enqueued_at[r.rid] = now
                if queues is not None:
                    queues[scheduler.book.route(r.qos_class)].append(r)
                else:
                    queue.append(r)
            while True:
                # ---- next batch: priority class queue, or the one queue
                if queues is not None:
                    cls = next((n for n in scheduler.book.names
                                if queues[n]), None)
                    if cls is None:
                        break
                    q = queues[cls]
                else:
                    if not queue:
                        break
                    cls, q = None, queue
                reqs = [q.popleft() for _ in range(min(self.batch, len(q)))]
                backlog = (sum(len(x) for x in queues.values())
                           if queues is not None else len(queue))
                t_drain = time.perf_counter()
                telemetry.record_queue(
                    cls, backlog,
                    [t_drain - enqueued_at.pop(r.rid, t_drain)
                     for r in reqs])

                # ---- resolve this batch's plan --------------------------
                if scheduler is not None:
                    glevel = (controller.level if controller is not None
                              else scheduler.top_level)
                    level_c = scheduler.level_for(cls, glevel)
                    plan_b = scheduler.ladder.plan(level_c)
                    if scheduler.ladder is not device_ladder:
                        device_stacks.clear()
                        device_ladder = scheduler.ladder
                    luts_b = device_stacks.get(level_c)
                    if luts_b is None:
                        raw = scheduler.ladder.luts(level_c)
                        luts_b = (dict((b, jnp.asarray(a))
                                       for b, a in raw.items())
                                  if isinstance(raw, dict)
                                  else jnp.asarray(raw))
                        device_stacks[level_c] = luts_b
                    telemetry.register_plan(plan_b)
                else:
                    glevel = level_c = None
                    plan_b, luts_b = self._plan, None

                # per-class cadence first (it counts the batch), then the
                # controller's global cadence — no short-circuit, so a
                # class's sampling never aliases with the drain order
                sched_want = (scheduler is not None
                              and scheduler.wants_shadow(cls))
                ctrl_want = (controller is not None
                             and controller.wants_shadow(batch_idx))
                want_shadow = self._adaptive and (sched_want or ctrl_want)
                stats = self.run_batch(reqs, shadow=want_shadow, luts=luts_b)
                telemetry.record_batch(
                    batch=batch_idx, tick=tick, n_requests=stats.n_requests,
                    prefill_s=stats.prefill_s, decode_s=stats.decode_s,
                    prefill_tokens=stats.prefill_tokens,
                    decode_tokens=stats.decode_tokens,
                    decode_steps=stats.decode_steps,
                    plan_id=plan_b.plan_id if self._adaptive else None,
                    drift=stats.drift, backlog=backlog, qos_class=cls,
                )
                if stats.drift is not None and self._adaptive:
                    if scheduler is not None:
                        scheduler.observe(cls, stats.drift)
                    if online is not None:
                        online.update(self._plan_maes(plan_b), stats.drift)
                if health is not None:
                    health.observe_step(
                        step=batch_idx, step_ms=stats.ms_per_step,
                        classes={cls: {}} if cls is not None else {},
                        drift=stats.drift, backlog=backlog,
                        plan_id=plan_b.plan_id if self._adaptive else None,
                        level=glevel,
                        class_state=(scheduler.snapshot(glevel)
                                     if scheduler is not None else None))

                # ---- between-batch control plane ------------------------
                if watcher is not None and self._adaptive and watcher.poll():
                    try:
                        fr = watcher.load_frontier()
                        # LookupError: store emptied; ValueError: refreshed
                        # stack would retrace (validate_lut_stack refused).
                        # Either way the server keeps running on the old,
                        # still-consistent plan.
                        if self._width_map is not None:
                            changed = self.refresh_mixed(
                                fr, controller=controller,
                                scheduler=scheduler, telemetry=telemetry,
                                batch_idx=batch_idx)
                        else:
                            compiled, exact_area, _bits = fr
                            changed = self.refresh_library(
                                compiled, exact_area, controller=controller,
                                scheduler=scheduler, telemetry=telemetry,
                                batch_idx=batch_idx)
                        eid = trace_event("serve.refresh", cause="watcher",
                                          changed=changed, batch=batch_idx)
                        if health is not None:
                            health.note_event("serve.refresh",
                                              step=batch_idx, event_id=eid,
                                              changed=changed)
                        if changed and log:
                            log(f"batch {batch_idx}: library refresh -> "
                                f"plan {self._plan.plan_id}")
                    except (LookupError, ValueError) as e:
                        trace_event("serve.refresh", cause="watcher",
                                    changed=False, batch=batch_idx,
                                    skipped=str(e))
                        if log:
                            log(f"watcher: refresh skipped ({e})")
                if controller is not None and self._adaptive:
                    # the load signal is *effective* ms/step: service time
                    # scaled by outstanding work (Little's-law flavour) —
                    # raw step latency is nearly plan-independent, so a
                    # building queue, not the step clock, is what says
                    # "trade accuracy for throughput" under ramp/spike load
                    eff_ms = effective_load_ms(stats.ms_per_step,
                                               backlog=backlog,
                                               capacity=self.batch)
                    # with classes, the batch may have decoded below the
                    # global level (its class cap) — its drift then says
                    # nothing about the global operating point
                    drift_sig = (stats.drift
                                 if scheduler is None or level_c == glevel
                                 else None)
                    level = controller.observe(eff_ms, drift_sig)
                    if level is not None:
                        eid = trace_event("serve.control", level=level,
                                          cause=controller.last_reason,
                                          batch=batch_idx)
                        if health is not None:
                            health.note_event("serve.control",
                                              step=batch_idx, event_id=eid,
                                              level=level,
                                              cause=controller.last_reason)
                        if scheduler is None:
                            moved = self.swap_plan(
                                controller.plan, controller.luts(),
                                reason=f"qos-{controller.last_reason}",
                                telemetry=telemetry, batch_idx=batch_idx)
                            if moved and log:
                                log(f"batch {batch_idx}: controller -> "
                                    f"level {level} "
                                    f"({controller.last_reason}), plan "
                                    f"{self._plan.plan_id}")
                        else:
                            # the global operating point moved; per-class
                            # stacks resolve against it at their next
                            # batch.  glevel was read before a possible
                            # mid-iteration ladder refresh — clamp both
                            # levels to the ladder the swap log points at
                            lad = scheduler.ladder
                            telemetry.record_swap(
                                batch=batch_idx,
                                reason=f"qos-{controller.last_reason}",
                                old=lad.plan(min(glevel,
                                                 len(lad) - 1)).plan_id,
                                new=lad.plan(min(level,
                                                 len(lad) - 1)).plan_id)
                            if log:
                                log(f"batch {batch_idx}: controller -> "
                                    f"global level {level} "
                                    f"({controller.last_reason})")
                if on_batch_end is not None:
                    on_batch_end(self, batch_idx)
                batch_idx += 1
        return telemetry


class ContinuousServingEngine(ServingEngine):
    """Continuous batching over a fixed pool of decode slots.

    The fixed-batch loop above admits requests only at batch boundaries:
    an arrival one step after a batch starts waits out the whole batch,
    and every slot reserves a full-length KV cache.  This engine decodes
    token-at-a-time over ``max_slots`` slots — requests join and leave
    the running batch *per step* through an active-mask, KV lives in a
    paged pool (:mod:`repro.serving.kvcache`), and prefill is just the
    first ``len(prompt)-1`` steps of a slot's life through the *same*
    jitted step.  All step inputs (``tok``, ``pos``, ``active``,
    ``tables``, the LUT stack) are plain jitted arguments with fixed
    shapes, so the one-trace contract carries over verbatim: joins,
    leaves, preemptions and plan swaps re-stack host arrays and never
    retrace (``trace_count`` stays 1).

    Latency SLOs: a :class:`~repro.sensitivity.classes.QoSClass` that
    declares ``slo_ms`` (e.g. ``gold:0.02@8ms``) is entitled to a slot —
    when the pool is full, its arrivals preempt the worst lower-tier
    slot.  The victim keeps its pages (its paged KV survives untouched;
    sliding-window ring rows are snapshotted host-side) and resumes from
    the head of its class queue, so preemption costs a suspension, never
    a re-prefill.  Admission itself drains the class queues weighted-
    fair (:class:`~repro.serving.slots.WeightedFairQueues`) instead of
    strictly by priority.
    """

    # class-level defaults so the provenance/cost bookkeeping helpers stay
    # drivable on a bare instance (tests exercise them without __init__)
    replica_name = ""
    _area_hi_by_key: dict[str, float] = {}
    _macs_per_layer = None

    def __init__(self, cfg, params, *, max_slots: int, prompt_len: int,
                 gen_len: int, page_size: int = 8, n_pages: int | None = None,
                 steps_per_tick: int | None = None, **kw) -> None:
        from ..models import init_paged_caches  # validates the family

        assert kw.pop("warmup_caches", None) is None, (
            "continuous batching serves LM families only")
        self.max_slots = int(max_slots)
        self.page_size = int(page_size)
        total = int(prompt_len) + int(gen_len)
        pages_per_req = -(-total // self.page_size)
        # default pool: every slot can hold a worst-case request PLUS one
        # spare slot's worth — preempted victims keep their pages, so
        # without headroom an SLO arrival into a full pool could never
        # allocate and preemption would be permanently page-blocked.
        # Under-provisioned regimes (admission actually blocking) pass
        # n_pages explicitly.
        self.n_pages = ((self.max_slots + 1) * pages_per_req
                        if n_pages is None else int(n_pages))
        self.table_entries = pages_per_req
        self.steps_per_tick = (int(steps_per_tick) if steps_per_tick
                               else max(1, int(gen_len)))
        self._init_paged_caches = init_paged_caches
        # the router stamps its replica name here so every req.* lifecycle
        # event names the engine that actually served the request
        self.replica_name = ""
        super().__init__(cfg, params, batch=max_slots, prompt_len=prompt_len,
                         gen_len=gen_len, **kw)
        self._started = False

    def _make_step_fn(self):
        from ..models import decode_paged_fn

        pstep = decode_paged_fn(self.cfg)
        cfg, wm = self.cfg, self._width_map
        if self._adaptive:
            def step_fn(params, caches, tok, pos, active, tables, luts):
                self._trace_count += 1
                if wm is not None:
                    return pstep(cfg, params, caches, tok, pos, active,
                                 tables, luts=luts, width_map=wm)
                return pstep(cfg, params, caches, tok, pos, active, tables,
                             luts=luts)
        else:
            def step_fn(params, caches, tok, pos, active, tables):
                self._trace_count += 1
                return pstep(cfg, params, caches, tok, pos, active, tables)
        return step_fn

    # ----------------------------------------------------------------- state
    @property
    def occupancy(self) -> float:
        return self._pool.occupancy if self._started else 0.0

    @property
    def queue_depth(self) -> int:
        return self._queues.depth if self._started else 0

    @property
    def idle(self) -> bool:
        return (not self._started
                or (self._pool.n_active == 0 and self._queues.depth == 0))

    @property
    def load_score(self) -> float:
        """Router's routing signal: active + queued work per slot."""
        if not self._started:
            return 0.0
        return (self._pool.n_active + self._queues.depth) / self.max_slots

    @property
    def preemption_count(self) -> int:
        return self._n_preemptions

    # ----------------------------------------------------------------- setup
    def start(self, *, telemetry: Telemetry | None = None, controller=None,
              watcher=None, scheduler=None, online=None,
              shadow_every: int | None = None, health=None, provenance=None,
              log: Callable[[str], None] | None = None) -> Telemetry:
        """Bind the control plane and reset all serving state (slots,
        pages, queues, caches).  Callable directly (the router drives
        replicas through ``submit``/``step_once``) or via :meth:`serve`."""
        from .kvcache import PageAllocator
        from .slots import SlotPool, WeightedFairQueues

        if scheduler is not None:
            assert self._adaptive, "class-aware serving needs a QoS plan"
        self.telemetry = telemetry or Telemetry()
        self._controller, self._watcher = controller, watcher
        self._scheduler, self._online, self._log = scheduler, online, log
        self._health = health
        if shadow_every is not None:
            self._shadow_every = max(1, int(shadow_every))
        elif controller is not None:
            self._shadow_every = max(1, controller.config.shadow_every)
        elif scheduler is not None:
            self._shadow_every = scheduler.shadow_every
        else:
            self._shadow_every = 4
        self._alloc = PageAllocator(self.n_pages, self.page_size)
        self._caches = self._init_paged_caches(
            self.cfg, self.max_slots, self.n_pages, self.page_size,
            self.total)
        self._pool = SlotPool(self.max_slots)
        if scheduler is not None:
            self._queues = WeightedFairQueues(
                scheduler.book.names, scheduler.book.drain_weights())
        else:
            self._queues = WeightedFairQueues(("std",))
        self._device_stacks: dict[int, object] = {}
        self._device_ladder = None
        self._step_idx = 0
        self._tick = 0
        self._n_preemptions = 0
        self.completions: dict[int, np.ndarray] = {}
        # approximation-provenance ledger: when tracing is configured the
        # ledger rides in the trace dir (one shared writer per process, so
        # router replicas never collide); tests may inject their own
        self._provenance = provenance
        if self._provenance is None:
            tr = current_tracer()
            if tr is not None:
                from ..obs.provenance import ledger_for

                self._provenance = ledger_for(tr.root, tr.tag)
        self._prov_open: dict[int, dict] = {}
        # cost plane: the model's LUT-routable MAC vector prices every
        # provenance range; families that never route (RWKV) serve with
        # the cost plane off
        from ..obs.costs import mlp_macs_per_layer

        try:
            self._macs_per_layer = mlp_macs_per_layer(self.cfg)
        except ValueError:
            self._macs_per_layer = None
        self._cost_rows = {}
        if self._provenance is not None and self._macs_per_layer is not None:
            self._provenance.note_model(name=self.cfg.name,
                                        macs=self._macs_per_layer)
        if self._adaptive:
            self.telemetry.register_plan(self._plan)
        self._started = True
        return self.telemetry

    # ------------------------------------------------------------- admission
    def submit(self, request: Request, now: float | None = None) -> None:
        """Queue one request.  Join/leave happens per decode step, so this
        never blocks; admission itself waits for a slot *and* pages."""
        assert self._started, "call start() before submit()"
        assert len(request.tokens) <= self.prompt_len, (
            f"request {request.rid} prompt ({len(request.tokens)}) exceeds "
            f"engine prompt_len ({self.prompt_len})")
        from .slots import SeqState

        cls = (self._scheduler.book.route(request.qos_class)
               if self._scheduler is not None else "std")
        now = time.perf_counter() if now is None else now
        self._queues.push(cls, SeqState(
            rid=request.rid, cls=cls,
            prompt=np.asarray(request.tokens, np.int32),
            gen_len=self.gen_len, submitted_t=now))
        self._req_event("req.queued", rid=request.rid, cls=cls,
                        prompt_len=len(request.tokens))

    def _req_event(self, name: str, **attrs) -> str:
        """One request-lifecycle trace event; no-op when tracing is off.
        Every serving-layer event with a request in scope carries its
        ``rid`` (and the replica name under a router) so the obs side can
        reconstruct the causal chain per request."""
        if self.replica_name:
            attrs["replica"] = self.replica_name
        return trace_event(name, **attrs)

    def _admissible(self, seq) -> bool:
        # a preempted request still holds its pages; a fresh one needs the
        # pool to cover its whole prompt+gen lifetime (out-of-pages blocks
        # admission up front, it never corrupts a running neighbour)
        return self._alloc.holds(seq.rid) or self._alloc.can_alloc(
            seq.n_tokens)

    def _place(self, idx: int, seq, now: float) -> None:
        if not self._alloc.holds(seq.rid):
            self._alloc.alloc(seq.rid, seq.n_tokens)
        if seq.ring_rows is not None:
            # restore the suspended request's sliding-window ring rows
            # into its new slot (paged layers need nothing: the page
            # tables re-point at the same physical pages)
            for li, rows in seq.ring_rows.items():
                layer = self._caches[li]
                self._caches[li] = {
                    k: layer[k].at[idx].set(jnp.asarray(v))
                    for k, v in rows.items()}
            seq.ring_rows = None
        cls = seq.cls if self._scheduler is not None else None
        if seq.suspended_at is not None:
            # resume path: close out the suspension and say so — both as
            # a req.* chain link and as a serve.resume *control* event,
            # so an anomaly right after a resume attributes to the
            # resume, not to some stale earlier swap
            susp = now - seq.suspended_at
            seq.suspended_at = None
            seq.suspended_s += susp
            if seq.first_token_t is None:
                seq.suspended_before_first_s += susp
            self.telemetry.record_suspension(cls, susp)
            self._req_event("req.resume", rid=seq.rid, cls=seq.cls,
                            slot=idx, suspended_ms=round(1e3 * susp, 3))
            eid = trace_event("serve.resume", step=self._step_idx,
                              rid=seq.rid, cls=seq.cls)
            if self._health is not None:
                self._health.note_event("serve.resume", step=self._step_idx,
                                        event_id=eid, rid=seq.rid,
                                        cls=seq.cls)
        elif seq.admitted_t is None:
            seq.admitted_t = now
            seq.queue_wait_s = now - seq.submitted_t
            self.telemetry.record_queue(cls, self._queues.depth,
                                        [seq.queue_wait_s])
            self._req_event("req.admitted", rid=seq.rid, cls=seq.cls,
                            slot=idx,
                            queue_ms=round(1e3 * seq.queue_wait_s, 3))
            self._req_event("req.prefill", rid=seq.rid, cls=seq.cls,
                            slot=idx, prompt_len=len(seq.prompt))
        self._pool.place(idx, seq)

    def _preempt_slot(self, idx: int, by_cls: str, now: float) -> None:
        seq = self._pool.evict(idx)
        rows: dict[int, dict] = {}
        for li, layer in enumerate(self._caches):
            if "k" in layer:    # per-slot ring (sliding-window attention)
                rows[li] = {"k": np.asarray(layer["k"][idx]),
                            "v": np.asarray(layer["v"][idx])}
        seq.ring_rows = rows
        seq.preempted += 1
        seq.suspended_at = now
        self._n_preemptions += 1
        self._queues.push_front(seq.cls, seq)
        self._prov_close(seq.rid)
        self.telemetry.record_preemption(
            step=self._step_idx, victim_rid=seq.rid, victim_class=seq.cls,
            by_class=by_cls)
        self._req_event("req.preempt", rid=seq.rid, cls=seq.cls,
                        step=self._step_idx, by=by_cls)
        eid = trace_event("serve.preempt", step=self._step_idx, rid=seq.rid,
                          victim=seq.cls, by=by_cls)
        if self._health is not None:
            self._health.note_event("serve.preempt", step=self._step_idx,
                                    event_id=eid, rid=seq.rid,
                                    victim=seq.cls, by=by_cls)
        if self._log:
            self._log(f"step {self._step_idx}: preempt rid={seq.rid} "
                      f"({seq.cls}) for {by_cls}")

    def _admit(self, now: float) -> None:
        # 1) weighted-fair fill of free slots
        while (idx := self._pool.free_slot()) is not None:
            picked = self._queues.pick(self._admissible)
            if picked is None:
                break
            _, seq = picked
            self._place(idx, seq, now)
        # 2) SLO preemption: a queued request whose class declares a
        # latency SLO claims a slot from the worst strictly-lower tier
        if self._scheduler is None:
            return
        book = self._scheduler.book
        for _ in range(self.max_slots):
            if self._pool.free_slot() is not None:
                break
            did = False
            for c in book:
                if c.slo_ms is None:
                    continue
                head = self._queues.peek(c.name)
                if head is None or not self._admissible(head):
                    continue
                victim = self._pool.pick_victim(
                    lambda n: book.get(n).priority, c.priority)
                if victim is None:
                    continue
                self._preempt_slot(victim, by_cls=c.name, now=now)
                self._place(victim, self._queues.pop(c.name), now)
                did = True
                break
            if not did:
                break

    # ------------------------------------------------------------- provenance
    def _prov_extend(self, seq, token_idx: int, plan_b, level) -> None:
        """Charge one generated token to the active plan: extend the
        request's open decode-step range when the plan is unchanged and
        contiguous, else seal it and open a new one.  Ranges also seal on
        preemption and completion, so a finished request's ranges tile
        ``[0, gen_len)`` exactly — the gap-free audit the provenance CLI
        gates on."""
        pid = plan_b.plan_id if plan_b is not None else "exact"
        r = self._prov_open.get(seq.rid)
        if r is not None and r["plan"] == pid and r["t1"] == token_idx:
            r["t1"] = token_idx + 1
            return
        if r is not None:
            self._provenance.record_range(**r)
        if plan_b is not None:
            # plans missing an exact_area (stub plans in direct-drive
            # tests) stay unpriced; the cost audit flags them
            exact_area = getattr(plan_b, "exact_area", None)
            areas = (plan_layer_areas(plan_b, self._area_hi_by_key)
                     if exact_area is not None else None)
            self._provenance.note_plan(
                plan_b.plan_id, [c.key or "exact" for c in plan_b.choices],
                width_map=self._width_map,
                areas=[lo for lo, _ in areas] if areas else None,
                areas_hi=[hi for _, hi in areas] if areas else None,
                exact_area=exact_area)
        self._prov_open[seq.rid] = {
            "rid": seq.rid, "cls": seq.cls, "t0": token_idx,
            "t1": token_idx + 1, "plan": pid, "level": level, "drift": [],
            "replica": self.replica_name or None}

    def _prov_close(self, rid: int) -> None:
        if self._provenance is None:
            return
        r = self._prov_open.pop(rid, None)
        if r is not None:
            self._provenance.record_range(**r)

    def _cost_row(self, plan_b) -> dict:
        """The per-token cost increments of the step's live plan, cached
        by plan id (refresh paths invalidate — areas can move when a
        background sweep lands a new frontier)."""
        pid = plan_b.plan_id if plan_b is not None else "exact"
        row = self._cost_rows.get(pid)
        if row is None:
            from ..obs.costs import plan_cost_row

            areas = (plan_layer_areas(plan_b, self._area_hi_by_key)
                     if plan_b is not None else None)
            row = plan_cost_row(plan_b, self._macs_per_layer,
                                layer_areas=areas)
            self._cost_rows[pid] = row
        return row

    # ------------------------------------------------------------------ step
    def _resolve_stack(self, active_classes):
        """The step's LUT stack: with a scheduler, the batch decodes at
        the level of its *strictest* active class (slots share one step,
        so the most exacting tenant sets the table for everyone in it —
        per-class plans separate again at the router's replica level).
        Returns ``(luts, plan, global_level, step_level)`` — the last is
        the level this step actually decodes at, which the provenance
        ledger records per token range."""
        if not self._adaptive:
            return None, None, None, None
        if self._scheduler is None:
            lvl = self._controller.level if self._controller else None
            return None, self._plan, lvl, lvl
        sch = self._scheduler
        glevel = (self._controller.level if self._controller is not None
                  else sch.top_level)
        level = min((sch.level_for(c, glevel) for c in active_classes),
                    default=min(glevel, sch.top_level))
        if sch.ladder is not self._device_ladder:
            self._device_stacks.clear()
            self._device_ladder = sch.ladder
        luts = self._device_stacks.get(level)
        if luts is None:
            raw = sch.ladder.luts(level)
            luts = (dict((b, jnp.asarray(a)) for b, a in raw.items())
                    if isinstance(raw, dict) else jnp.asarray(raw))
            self._device_stacks[level] = luts
        plan = sch.ladder.plan(level)
        self.telemetry.register_plan(plan)
        return luts, plan, glevel, level

    def step_once(self, now: float | None = None) -> bool:
        """Admit what fits, then run one decode step over the pool.
        Returns ``False`` (and runs nothing) when no slot is active."""
        assert self._started, "call start() before step_once()"
        now = time.perf_counter() if now is None else now
        preempts_before = self._n_preemptions
        self._admit(now)
        occupied = list(self._pool)
        if not occupied:
            return False

        toks = np.zeros((self.max_slots, 1), np.int32)
        pos = np.zeros(self.max_slots, np.int32)
        active = np.zeros(self.max_slots, bool)
        tables = np.empty((self.max_slots, self.table_entries), np.int32)
        for i in range(self.max_slots):
            tables[i] = self._alloc.padded_table(None, self.table_entries)
        for idx, seq in occupied:
            toks[idx, 0] = seq.next_token()
            pos[idx] = seq.pos
            active[idx] = True
            tables[idx] = self._alloc.padded_table(seq.rid,
                                                   self.table_entries)

        classes = sorted({seq.cls for _, seq in occupied})
        luts, plan_b, glevel, step_level = self._resolve_stack(classes)
        if self._adaptive and luts is None:
            luts, plan_b = self._luts, self._plan

        jt = (jnp.asarray(toks), jnp.asarray(pos), jnp.asarray(active),
              jnp.asarray(tables))
        want_shadow = (self._adaptive
                       and (self._controller is not None
                            or self._scheduler is not None)
                       and self._step_idx % self._shadow_every == 0)
        shadow_logits = None
        shadow_s = 0.0
        if want_shadow:
            with trace_span("serve.shadow"):
                ts = time.perf_counter()
                shadow_caches = jax.tree.map(jnp.copy, self._caches)
                shadow_logits, _ = self._jit_step(
                    self.params, shadow_caches, *jt, self._exact_luts)
                shadow_logits.block_until_ready()
                shadow_s = time.perf_counter() - ts
        t0 = time.perf_counter()
        if self.inject_step_delay:
            # chaos hook: the sleep sits inside the timed section, so an
            # injected latency spike is indistinguishable from a real one
            # to the telemetry, the SLO monitors and the detectors
            time.sleep(self.inject_step_delay)
        if self._adaptive:
            logits, self._caches = self._jit_step(
                self.params, self._caches, *jt, luts)
        else:
            logits, self._caches = self._jit_step(
                self.params, self._caches, *jt)
        logits.block_until_ready()
        step_s = time.perf_counter() - t0

        drift = None
        if shadow_logits is not None:
            rows = np.flatnonzero(active)
            drift = float(jnp.abs(logits[rows]
                                  - shadow_logits[rows]).mean())

        sampled = np.asarray(jnp.argmax(logits, axis=-1), np.int64)
        t_done = time.perf_counter()
        by_class: dict[str, dict] = {}
        for idx, seq in occupied:
            row = by_class.setdefault(
                seq.cls, {"rows": 0, "decode_tokens": 0,
                          "prefill_tokens": 0})
            row["rows"] += 1
            generated, first = seq.advance(int(sampled[idx]))
            if generated:
                row["decode_tokens"] += 1
                if self._provenance is not None:
                    self._prov_extend(seq, len(seq.generated) - 1,
                                      plan_b if self._adaptive else None,
                                      step_level)
                    if drift is not None:
                        self._prov_open[seq.rid]["drift"].append(
                            round(drift, 6))
            else:
                row["prefill_tokens"] += 1
            if first:
                seq.first_token_t = t_done
                self.telemetry.record_ttft(
                    seq.cls if self._scheduler is not None else None,
                    t_done - seq.submitted_t)
                self._req_event(
                    "req.decode", rid=seq.rid, cls=seq.cls,
                    ttft_ms=round(1e3 * (t_done - seq.submitted_t), 3),
                    prefill_ms=round(
                        1e3 * max(0.0, (t_done - seq.admitted_t)
                                  - seq.suspended_before_first_s), 3)
                    if seq.admitted_t is not None else None)
            if seq.done:
                self._pool.evict(idx)
                self._alloc.free(seq.rid)
                gen = np.asarray(seq.generated, np.int32)
                self.completions[seq.rid] = gen
                self.last_tokens = gen[None, :]
                self.telemetry.record_request_done(
                    seq.cls if self._scheduler is not None else None)
                b = seq.breakdown(t_done)
                self._req_event("req.done", rid=seq.rid, cls=seq.cls,
                                steps=seq.pos, preempts=seq.preempted,
                                resumes=seq.preempted, **b)
                if self._provenance is not None:
                    self._prov_close(seq.rid)
                    self._provenance.record_done(
                        rid=seq.rid, cls=seq.cls, gen_len=len(gen),
                        steps=seq.pos, preempts=seq.preempted,
                        replica=self.replica_name or None)

        if self._macs_per_layer is not None:
            cost_row = self._cost_row(plan_b if self._adaptive else None)
            for cls, r in by_class.items():
                if r["decode_tokens"]:
                    self.telemetry.record_costs(
                        cls if self._scheduler is not None else None,
                        r["decode_tokens"], cost_row)

        backlog = self._queues.depth
        occ = self._pool.occupancy
        self.telemetry.record_step(
            step=self._step_idx, tick=self._tick, step_s=step_s,
            by_class=by_class,
            decode_tokens=sum(r["decode_tokens"] for r in by_class.values()),
            prefill_tokens=sum(r["prefill_tokens"]
                               for r in by_class.values()),
            plan_id=plan_b.plan_id if self._adaptive else None,
            drift=drift, backlog=backlog, occupancy=occ)
        self.telemetry.record_pages(used=self._alloc.used_pages,
                                    total=self._alloc.n_pages)
        if self._health is not None:
            self._health.observe_step(
                step=self._step_idx, step_ms=1e3 * step_s,
                classes=by_class, drift=drift, backlog=backlog,
                occupancy=occ,
                preemptions=self._n_preemptions - preempts_before,
                plan_id=plan_b.plan_id if self._adaptive else None,
                level=glevel,
                pages={"used": self._alloc.used_pages,
                       "free": self._alloc.free_pages,
                       "total": self._alloc.n_pages},
                class_state=(self._scheduler.snapshot(glevel)
                             if self._scheduler is not None else None))

        self._control_plane(step_s, drift, plan_b, glevel, backlog, occ)
        self._step_idx += 1
        return True

    def _control_plane(self, step_s, drift, plan_b, glevel, backlog, occ):
        controller, scheduler = self._controller, self._scheduler
        if drift is not None and self._adaptive:
            if scheduler is not None:
                for cls in {seq.cls for _, seq in self._pool}:
                    scheduler.observe(cls, drift)
            if self._online is not None and plan_b is not None:
                self._online.update(self._plan_maes(plan_b), drift)
        if self._watcher is not None and self._adaptive \
                and self._watcher.poll():
            try:
                fr = self._watcher.load_frontier()
                if self._width_map is not None:
                    changed = self.refresh_mixed(
                        fr, controller=controller, scheduler=scheduler,
                        telemetry=self.telemetry, batch_idx=self._step_idx)
                else:
                    compiled, exact_area, _bits = fr
                    changed = self.refresh_library(
                        compiled, exact_area, controller=controller,
                        scheduler=scheduler, telemetry=self.telemetry,
                        batch_idx=self._step_idx)
                eid = trace_event("serve.refresh", cause="watcher",
                                  changed=changed, batch=self._step_idx)
                if self._health is not None:
                    self._health.note_event("serve.refresh",
                                            step=self._step_idx,
                                            event_id=eid, changed=changed)
                if changed and self._log:
                    self._log(f"step {self._step_idx}: library refresh -> "
                              f"plan {self._plan.plan_id}")
            except (LookupError, ValueError) as e:
                trace_event("serve.refresh", cause="watcher", changed=False,
                            batch=self._step_idx, skipped=str(e))
                if self._log:
                    self._log(f"watcher: refresh skipped ({e})")
        if controller is not None and self._adaptive:
            # occupancy replaces the fixed loop's whole-queue heuristic:
            # requests already in slots are being served, only true
            # admission-queue depth counts as waiting work
            eff_ms = effective_load_ms(1e3 * step_s, backlog=backlog,
                                       capacity=self.max_slots,
                                       occupancy=occ)
            drift_sig = (drift if scheduler is None
                         or (glevel is not None
                             and plan_b is scheduler.ladder.plan(glevel))
                         else None)
            level = controller.observe(eff_ms, drift_sig)
            if level is not None:
                eid = trace_event("serve.control", level=level,
                                  cause=controller.last_reason,
                                  batch=self._step_idx)
                if self._health is not None:
                    self._health.note_event("serve.control",
                                            step=self._step_idx,
                                            event_id=eid, level=level,
                                            cause=controller.last_reason)
                if scheduler is None:
                    moved = self.swap_plan(
                        controller.plan, controller.luts(),
                        reason=f"qos-{controller.last_reason}",
                        telemetry=self.telemetry, batch_idx=self._step_idx)
                    if moved and self._log:
                        self._log(f"step {self._step_idx}: controller -> "
                                  f"level {level} "
                                  f"({controller.last_reason})")
                else:
                    lad = scheduler.ladder
                    self.telemetry.record_swap(
                        batch=self._step_idx,
                        reason=f"qos-{controller.last_reason}",
                        old=lad.plan(min(glevel, len(lad) - 1)).plan_id,
                        new=lad.plan(min(level, len(lad) - 1)).plan_id)
                    if self._log:
                        self._log(f"step {self._step_idx}: controller -> "
                                  f"global level {level} "
                                  f"({controller.last_reason})")

    # ----------------------------------------------------------------- serve
    def serve(self, profile: LoadProfile, *, controller=None, watcher=None,
              scheduler=None, online=None,
              telemetry: Telemetry | None = None, seed: int = 0,
              steps_per_tick: int | None = None,
              on_step_end: Callable[["ContinuousServingEngine", int],
                                    None] | None = None,
              log: Callable[[str], None] | None = None,
              health=None) -> Telemetry:
        """Serve a synthetic load profile continuously: each tick's
        arrivals join the admission queues, then up to ``steps_per_tick``
        decode steps run before the next tick's arrivals — requests keep
        joining/leaving the pool mid-generation.  After the last tick the
        pool drains to empty."""
        assert profile.prompt_len <= self.prompt_len, (
            f"profile prompts up to {profile.prompt_len} exceed engine "
            f"prompt_len {self.prompt_len}")
        assert profile.gen_len == self.gen_len
        telemetry = self.start(telemetry=telemetry, controller=controller,
                               watcher=watcher, scheduler=scheduler,
                               online=online, health=health, log=log)
        steps = steps_per_tick or self.steps_per_tick
        per_tick = synth_requests(profile, self.cfg.vocab_size, seed)
        try:
            with trace_span("serve.continuous", slots=self.max_slots,
                            pages=self.n_pages):
                for tick in range(profile.n_ticks):
                    self._tick = tick
                    now = time.perf_counter()
                    for r in per_tick[tick]:
                        self.submit(r, now)
                    for _ in range(steps):
                        if not self.step_once():
                            break
                        if on_step_end is not None:
                            on_step_end(self, self._step_idx - 1)
                while self.step_once():
                    if on_step_end is not None:
                        on_step_end(self, self._step_idx - 1)
        except BaseException as e:
            # the flight recorder's crash path: freeze the ring before the
            # exception unwinds past the serve loop, then re-raise
            if self._health is not None:
                self._health.record_crash(e)
            raise
        return telemetry
