"""Adaptive serving engine: request queue + batched greedy decode with
between-batch operator hot-swap.

The load-bearing design point: the per-layer ``(L, side, side)`` LUT
stack — ``(L, 16, 16)`` for W4A4, ``(L, 256, 256)`` for composed W8A8 —
is a *plain jitted argument* of the decode step, never a closed-over
constant.  Swapping QoS plans between batches therefore re-stacks a tiny
int32 array and changes nothing the compiler specialized on — the decode
step is traced exactly once for the whole serve, across every controller
move and library refresh (``trace_count`` pins this, and the end-to-end
test asserts it).

One ``run_batch`` call serves up to ``batch`` queued requests: prefill
walks the prompt through the *same* jitted decode step (one code path,
one trace), then greedy decode extends ``gen_len`` tokens.  Prefill and
decode are timed separately — a python-loop prefill is O(prompt) step
dispatches and would otherwise silently poison the decode throughput
number.  Between batches the engine consults the library watcher (store
changed? refresh the frontier) and the QoS controller (latency/drift
says move? swap the plan), both of which funnel through
:meth:`ServingEngine.swap_plan` and its shape/dtype validation.

Drift sampling: every ``shadow_every`` batches the final decode step is
also evaluated on copies of the caches with the *exact* LUT stack; the
mean |Δlogit| between the live and shadow step is the measured drift the
controller holds under its budget.  The shadow call reuses the one jitted
executable (same shapes, different table values).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..library.qos import LayerPlan, refresh_plan, stack_luts, validate_lut_stack
from ..models import decode_fn, init_caches
from ..obs.trace import event as trace_event
from ..obs.trace import span as trace_span
from .loadgen import LoadProfile, Request, synth_requests
from .telemetry import Telemetry

__all__ = ["BatchStats", "ServingEngine"]


@dataclass
class BatchStats:
    """Measurements of one served batch."""

    n_requests: int
    prefill_s: float
    decode_s: float
    prefill_tokens: int
    decode_tokens: int
    decode_steps: int
    drift: float | None = None

    @property
    def ms_per_step(self) -> float:
        return 1e3 * self.decode_s / max(1, self.decode_steps)

    @property
    def decode_tok_s(self) -> float:
        return self.decode_tokens / self.decode_s if self.decode_s else 0.0

    @property
    def prefill_tok_s(self) -> float:
        return self.prefill_tokens / self.prefill_s if self.prefill_s else 0.0


class ServingEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        batch: int,
        prompt_len: int,
        gen_len: int,
        plan: LayerPlan | None = None,
        compiled=None,
        exact_area: float | None = None,
        sensitivities=None,
        width_map=None,
        sens_profile=None,
        warmup_caches: Callable | None = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.batch = int(batch)
        self.prompt_len = int(prompt_len)
        self.gen_len = int(gen_len)
        self.total = self.prompt_len + self.gen_len
        self._warmup = warmup_caches
        self._trace_count = 0
        self.last_tokens: np.ndarray | None = None   # (n_requests, gen_len)

        self._adaptive = plan is not None
        self._plan = plan
        self._compiled = list(compiled) if compiled is not None else []
        self._exact_area = exact_area
        # per-layer sensitivities: a vector for uniform-width serves, a
        # {bits: vector-or-matrix} dict for mixed-width (kept for the
        # watcher's ladder rebuild)
        if isinstance(sensitivities, dict):
            self._sens = sensitivities
        else:
            self._sens = (np.ones(cfg.n_layers) if sensitivities is None
                          else np.asarray(sensitivities, dtype=np.float64))
        self._width_map = (tuple(int(b) for b in width_map)
                           if width_map is not None else None)
        # measured SensitivityProfile (optional): refresh paths re-price
        # measured cost matrices against the *refreshed* frontier through
        # it — a stale (L, O) matrix cannot follow a frontier whose
        # operator set a background fleet sweep just changed
        self._profile = sens_profile
        self._mae_by_key = {rec.key: comp.mae
                            for rec, comp in self._compiled}

        step = decode_fn(cfg)
        if self._adaptive:
            assert cfg.approx_mlp, (
                "adaptive serving routes MLP matmuls through LUTs; build the "
                "config with .with_approx_mlp()"
            )
            if self._width_map is not None:
                # mixed-width: one stack per width group, the per-layer
                # width routing is a static part of the single trace
                assert len(self._width_map) == cfg.n_layers
                from ..precision.plans import (exact_mixed_stacks,
                                               stack_mixed_luts)

                self._luts = {
                    b: jnp.asarray(a) for b, a in stack_mixed_luts(
                        plan, self._compiled, self._width_map).items()}
                self._exact_luts = {
                    b: jnp.asarray(a)
                    for b, a in exact_mixed_stacks(self._width_map).items()}
                self.width = None
                self.widths = tuple(sorted(set(self._width_map)))
            else:
                self._luts = jnp.asarray(stack_luts(plan, self._compiled))
                from ..precision.widths import exact_table, width_from_stack

                # the exact shadow stack shares the live stack's width — a
                # W8A8 serve shadows against the exact 256x256 product table
                self.width = width_from_stack(self._luts)
                self.widths = (self.width.bits,)
                side = self.width.side
                self._exact_luts = jnp.asarray(np.broadcast_to(
                    exact_table("mul", self.width.bits).astype(np.int32),
                    (cfg.n_layers, side, side)).copy())
            wm = self._width_map

            def step_fn(params, caches, tok, pos, luts):
                # python side effect runs once per *trace*, so this counts
                # compilations, not calls — the no-retrace-across-swaps
                # invariant is `trace_count == 1` after any number of swaps
                self._trace_count += 1
                if wm is not None:
                    return step(cfg, params, caches, tok, pos, luts=luts,
                                width_map=wm)
                return step(cfg, params, caches, tok, pos, luts=luts)
        else:
            self._luts = None
            self._exact_luts = None
            self.width = None
            self.widths = ()

            def step_fn(params, caches, tok, pos):
                self._trace_count += 1
                return step(cfg, params, caches, tok, pos)

        self._jit_step = jax.jit(step_fn, donate_argnums=(1,))

    # ----------------------------------------------------------------- state
    @property
    def trace_count(self) -> int:
        """How many times the decode step has been traced (must stay 1)."""
        return self._trace_count

    @property
    def plan(self) -> LayerPlan | None:
        return self._plan

    def _step(self, caches, tok, pos, luts=None):
        if self._adaptive:
            return self._jit_step(self.params, caches, tok, pos,
                                  self._luts if luts is None else luts)
        return self._jit_step(self.params, caches, tok, pos)

    # ------------------------------------------------------------------ swap
    def swap_plan(self, plan: LayerPlan, stack, *, reason: str = "manual",
                  telemetry: Telemetry | None = None,
                  batch_idx: int = 0) -> bool:
        """Adopt a new plan between batches.  Validates the stack against
        the live one (shape/dtype — a mismatch would retrace), suppresses
        no-op swaps (same per-layer assignment), logs the swap.  Returns
        whether the plan actually changed."""
        assert self._adaptive, "engine was built without a QoS plan"
        if plan.plan_id == self._plan.plan_id:
            return False
        new = (dict((b, jnp.asarray(a)) for b, a in stack.items())
               if isinstance(stack, dict) else jnp.asarray(stack))
        validate_lut_stack(self._luts, new)
        old_id = self._plan.plan_id
        self._plan, self._luts = plan, new
        if telemetry is not None:
            telemetry.register_plan(plan)
            telemetry.record_swap(batch=batch_idx, reason=reason,
                                  old=old_id, new=plan.plan_id)
        trace_event("serve.swap", reason=reason, batch=batch_idx,
                    old=old_id, new=plan.plan_id)
        return True

    def refresh_library(self, compiled, exact_area: float, *,
                        controller=None, scheduler=None,
                        reason: str = "library",
                        telemetry: Telemetry | None = None,
                        batch_idx: int = 0) -> bool:
        """Adopt a refreshed frontier (the watcher path).  With a
        controller (or class scheduler), its ladder is rebuilt and the
        current level re-stacked; without either, the live plan's budget
        re-selects over the new frontier via
        :func:`repro.library.qos.refresh_plan`.

        Nothing — engine frontier, controller ladder — is mutated until the
        new stack passes :func:`~repro.library.qos.validate_lut_stack`
        inside :meth:`swap_plan`: a surprising store merge (e.g. a future
        8-bit frontier landing in a watched 4-bit store) raises and leaves
        the runtime serving consistently on the old plan."""
        # with a measured profile, re-price the refreshed frontier (a
        # stale (L, O) matrix cannot index new operator columns); without
        # one, the ladder keeps its own sensitivity model as before
        new_sens = self._uniform_sens(compiled)
        if controller is not None or scheduler is not None:
            owner = (controller.ladder if controller is not None
                     else scheduler.ladder)
            new_ladder = owner.refresh(compiled, exact_area,
                                       sensitivities=new_sens)
            level = (min(controller.level, len(new_ladder) - 1)
                     if controller is not None else 0)
            plan, stack = new_ladder.plan(level), new_ladder.luts(level)
        else:
            new_ladder = level = None
            plan = refresh_plan(
                self._plan, compiled,
                self._sens if new_sens is None else new_sens,
                exact_area=exact_area)
            stack = stack_luts(plan, compiled)
        changed = self.swap_plan(plan, stack, reason=reason,
                                 telemetry=telemetry, batch_idx=batch_idx)
        self._compiled = list(compiled)
        self._mae_by_key = {rec.key: comp.mae for rec, comp in self._compiled}
        self._exact_area = exact_area
        if controller is not None:
            controller.adopt(new_ladder, level=level)
        if scheduler is not None:
            scheduler.adopt(new_ladder)
        return changed

    def refresh_mixed(self, mixed, *, controller=None, scheduler=None,
                      reason: str = "library",
                      telemetry: Telemetry | None = None,
                      batch_idx: int = 0) -> bool:
        """The mixed-width watcher path: rebuild the plan ladder over a
        refreshed :class:`~repro.precision.plans.MixedFrontier` *inside*
        the frozen width map, then re-point the controller and the class
        scheduler at it.  Group shapes are fixed by the width map, so the
        new level stacks validate against the live ones by construction —
        and are checked anyway before anything is adopted."""
        from ..precision.plans import (build_mixed_ladder,
                                       mixed_cost_matrix, stack_mixed_luts)

        assert self._width_map is not None, "engine serves a uniform width"
        sens = self._mixed_sens(mixed)
        old = (controller.ladder if controller is not None
               else scheduler.ladder if scheduler is not None else None)
        if old is None:
            # plain mixed serve (no controller / classes): the analog of
            # the refresh_plan path — re-select the live plan's budget
            # inside the frozen width map and keep serving
            wm = np.asarray(self._width_map)
            plan = refresh_plan(
                self._plan, mixed.compiled,
                mixed_cost_matrix(mixed, sens, len(wm)),
                exact_area=mixed.exact_areas(self._width_map),
                allowed=mixed.op_bits[None, :] == wm[:, None])
            stack = stack_mixed_luts(plan, mixed.compiled, self._width_map)
        else:
            new_ladder = build_mixed_ladder(
                mixed, self._width_map, sens,
                levels=old.requested_levels)
            level = (min(controller.level, len(new_ladder) - 1)
                     if controller is not None else 0)
            plan, stack = new_ladder.plan(level), new_ladder.luts(level)
        changed = self.swap_plan(plan, stack, reason=reason,
                                 telemetry=telemetry, batch_idx=batch_idx)
        self._compiled = list(mixed.compiled)
        self._mae_by_key = {rec.key: comp.mae for rec, comp in self._compiled}
        if old is not None and controller is not None:
            controller.adopt(new_ladder, level=level)
        if old is not None and scheduler is not None:
            scheduler.adopt(new_ladder)
        return changed

    def _uniform_sens(self, compiled):
        """Measured pricing for a refreshed uniform-width frontier, or
        ``None`` when there is no profile (the caller keeps its own
        sensitivity model)."""
        if self._profile is None:
            return None
        from ..sensitivity.profile import costs_for

        return costs_for(self._profile, self.width.bits, compiled,
                         self.cfg.n_layers)

    def _mixed_sens(self, mixed):
        """Per-width pricing for a refreshed mixed frontier: measured via
        the profile when present, else the constructor's sensitivity
        model (vectors follow any frontier; a caller-supplied measured
        matrix cannot, and the resulting ValueError makes the watcher
        skip the refresh)."""
        if self._profile is None:
            return self._sens
        from ..sensitivity.profile import costs_for

        return {bits: costs_for(self._profile, bits, fr.compiled,
                                self.cfg.n_layers)
                for bits, fr in mixed.by_width.items()}

    def _plan_maes(self, plan: LayerPlan) -> np.ndarray:
        """Per-layer operator mae of a plan (0 for exact layers) — the
        attribution vector the online sensitivity estimator consumes."""
        return np.array([0.0 if c.key is None
                         else self._mae_by_key.get(c.key, 0.0)
                         for c in plan.choices])

    # ----------------------------------------------------------------- batch
    def run_batch(self, requests: list[Request], *,
                  shadow: bool = False, luts=None) -> BatchStats:
        """Serve one batch: prefill the prompts, greedily decode
        ``gen_len`` tokens.  Short batches are zero-padded to the fixed
        batch size so every call reuses the single traced executable.

        ``luts`` overrides the engine's live stack for this batch only —
        the class-aware serve passes each batch its QoS class's plan
        stack (same shapes, so still the one trace)."""
        assert 0 < len(requests) <= self.batch
        if luts is not None:
            luts = (dict((b, jnp.asarray(a)) for b, a in luts.items())
                    if isinstance(luts, dict) else jnp.asarray(luts))
        prompts_np = np.zeros((self.batch, self.prompt_len), np.int32)
        for i, r in enumerate(requests):
            prompts_np[i] = r.tokens
        prompts = jnp.asarray(prompts_np)

        caches = init_caches(self.cfg, self.batch, self.total)
        if self._warmup is not None:
            caches = self._warmup(caches)

        with trace_span("serve.batch", n_requests=len(requests)) as batch_sp:
            with trace_span("serve.prefill",
                            tokens=len(requests) * self.prompt_len):
                t0 = time.perf_counter()
                logits = None
                for t in range(self.prompt_len):
                    logits, caches = self._step(caches, prompts[:, t:t + 1],
                                                jnp.int32(t), luts=luts)
                logits.block_until_ready()
                t1 = time.perf_counter()

            shadow_logits = None
            shadow_s = 0.0
            generated = []
            with trace_span("serve.decode", steps=self.gen_len) as decode_sp:
                for t in range(self.prompt_len, self.total):
                    tok = jnp.argmax(logits, axis=-1)[:, None]
                    tok = tok.astype(jnp.int32)
                    generated.append(tok)
                    if shadow and self._adaptive and t == self.total - 1:
                        # exact shadow step on copies — the live call below
                        # donates the real caches, the copies are consumed by
                        # the shadow.  Timed separately and excluded from
                        # decode_s: the shadow is measurement overhead, and
                        # folding it into ms/step would bias the very latency
                        # signal the controller acts on.
                        with trace_span("serve.shadow"):
                            ts = time.perf_counter()
                            shadow_caches = jax.tree.map(jnp.copy, caches)
                            shadow_logits, _ = self._jit_step(
                                self.params, shadow_caches, tok, jnp.int32(t),
                                self._exact_luts)
                            shadow_logits.block_until_ready()
                            shadow_s = time.perf_counter() - ts
                    logits, caches = self._step(caches, tok, jnp.int32(t),
                                                luts=luts)
                logits.block_until_ready()
                t2 = time.perf_counter()
                decode_sp.set(shadow_s=round(shadow_s, 6))

            n = len(requests)
            drift = None
            if shadow_logits is not None:
                # only the real rows: zero-padded requests decode garbage and
                # would contaminate the controller's drift signal on the
                # partial batches ramp/spike load produces routinely
                drift = float(jnp.abs(logits[:n] - shadow_logits[:n]).mean())
            stats = BatchStats(
                n_requests=n,
                prefill_s=t1 - t0,
                decode_s=t2 - t1 - shadow_s,
                prefill_tokens=n * self.prompt_len,
                decode_tokens=n * self.gen_len,
                decode_steps=self.gen_len,
                drift=drift,
            )
            batch_sp.set(ms_per_step=round(stats.ms_per_step, 3),
                         decode_tok_s=round(stats.decode_tok_s, 2))
            if drift is not None:
                batch_sp.set(drift=round(drift, 6))
        # completions for the real (unpadded) requests — a degenerate
        # repeated-token sample is also the quickest eyeball check that an
        # aggressive plan's LUT routing is live in decode
        self.last_tokens = np.asarray(jnp.concatenate(generated, axis=1))[:n]
        return stats

    # ----------------------------------------------------------------- serve
    def serve(
        self,
        profile: LoadProfile,
        *,
        controller=None,
        watcher=None,
        scheduler=None,
        online=None,
        telemetry: Telemetry | None = None,
        seed: int = 0,
        on_batch_end: Callable[["ServingEngine", int], None] | None = None,
        log: Callable[[str], None] | None = None,
    ) -> Telemetry:
        """Run the full serving loop over a synthetic load profile.

        Each tick's arrivals join the queue; the queue drains in batches
        of up to ``batch`` requests.  With a class ``scheduler``
        (:class:`repro.sensitivity.classes.ClassScheduler`) there is one
        queue per declared QoS class, drained in priority order, and each
        batch decodes on *its class's* plan stack — same shapes, same
        single trace, but ``gold`` rides a more exact level than
        ``batch``.  After every batch the control plane runs: watcher
        poll (library refresh), per-class drift bookkeeping, online
        sensitivity update, controller observe (global level move), then
        the optional ``on_batch_end`` hook (tests use it to mutate the
        store mid-serve)."""
        assert profile.prompt_len == self.prompt_len
        assert profile.gen_len == self.gen_len
        if scheduler is not None:
            assert self._adaptive, "class-aware serving needs a QoS plan"
        telemetry = telemetry or Telemetry()
        if self._adaptive:
            telemetry.register_plan(self._plan)
        per_tick = synth_requests(profile, self.cfg.vocab_size, seed)
        queue: deque[Request] = deque()
        queues: dict[str, deque[Request]] | None = None
        if scheduler is not None:
            queues = {name: deque() for name in scheduler.book.names}
        # wall-clock enqueue times (requests themselves carry only the
        # synthetic arrival tick) so drained batches can report real
        # time-in-queue to the per-class wait histograms
        enqueued_at: dict[int, float] = {}
        # device-resident class stacks, keyed by ladder level and
        # invalidated on ladder refresh — without this every class batch
        # would re-upload its (n_layers, side, side) stack host-to-device
        device_stacks: dict[int, object] = {}
        device_ladder = None
        batch_idx = 0
        for tick in range(profile.n_ticks):
            now = time.perf_counter()
            for r in per_tick[tick]:
                enqueued_at[r.rid] = now
                if queues is not None:
                    queues[scheduler.book.route(r.qos_class)].append(r)
                else:
                    queue.append(r)
            while True:
                # ---- next batch: priority class queue, or the one queue
                if queues is not None:
                    cls = next((n for n in scheduler.book.names
                                if queues[n]), None)
                    if cls is None:
                        break
                    q = queues[cls]
                else:
                    if not queue:
                        break
                    cls, q = None, queue
                reqs = [q.popleft() for _ in range(min(self.batch, len(q)))]
                backlog = (sum(len(x) for x in queues.values())
                           if queues is not None else len(queue))
                t_drain = time.perf_counter()
                telemetry.record_queue(
                    cls, backlog,
                    [t_drain - enqueued_at.pop(r.rid, t_drain)
                     for r in reqs])

                # ---- resolve this batch's plan --------------------------
                if scheduler is not None:
                    glevel = (controller.level if controller is not None
                              else scheduler.top_level)
                    level_c = scheduler.level_for(cls, glevel)
                    plan_b = scheduler.ladder.plan(level_c)
                    if scheduler.ladder is not device_ladder:
                        device_stacks.clear()
                        device_ladder = scheduler.ladder
                    luts_b = device_stacks.get(level_c)
                    if luts_b is None:
                        raw = scheduler.ladder.luts(level_c)
                        luts_b = (dict((b, jnp.asarray(a))
                                       for b, a in raw.items())
                                  if isinstance(raw, dict)
                                  else jnp.asarray(raw))
                        device_stacks[level_c] = luts_b
                    telemetry.register_plan(plan_b)
                else:
                    glevel = level_c = None
                    plan_b, luts_b = self._plan, None

                # per-class cadence first (it counts the batch), then the
                # controller's global cadence — no short-circuit, so a
                # class's sampling never aliases with the drain order
                sched_want = (scheduler is not None
                              and scheduler.wants_shadow(cls))
                ctrl_want = (controller is not None
                             and controller.wants_shadow(batch_idx))
                want_shadow = self._adaptive and (sched_want or ctrl_want)
                stats = self.run_batch(reqs, shadow=want_shadow, luts=luts_b)
                telemetry.record_batch(
                    batch=batch_idx, tick=tick, n_requests=stats.n_requests,
                    prefill_s=stats.prefill_s, decode_s=stats.decode_s,
                    prefill_tokens=stats.prefill_tokens,
                    decode_tokens=stats.decode_tokens,
                    decode_steps=stats.decode_steps,
                    plan_id=plan_b.plan_id if self._adaptive else None,
                    drift=stats.drift, backlog=backlog, qos_class=cls,
                )
                if stats.drift is not None and self._adaptive:
                    if scheduler is not None:
                        scheduler.observe(cls, stats.drift)
                    if online is not None:
                        online.update(self._plan_maes(plan_b), stats.drift)

                # ---- between-batch control plane ------------------------
                if watcher is not None and self._adaptive and watcher.poll():
                    try:
                        fr = watcher.load_frontier()
                        # LookupError: store emptied; ValueError: refreshed
                        # stack would retrace (validate_lut_stack refused).
                        # Either way the server keeps running on the old,
                        # still-consistent plan.
                        if self._width_map is not None:
                            changed = self.refresh_mixed(
                                fr, controller=controller,
                                scheduler=scheduler, telemetry=telemetry,
                                batch_idx=batch_idx)
                        else:
                            compiled, exact_area, _bits = fr
                            changed = self.refresh_library(
                                compiled, exact_area, controller=controller,
                                scheduler=scheduler, telemetry=telemetry,
                                batch_idx=batch_idx)
                        trace_event("serve.refresh", cause="watcher",
                                    changed=changed, batch=batch_idx)
                        if changed and log:
                            log(f"batch {batch_idx}: library refresh -> "
                                f"plan {self._plan.plan_id}")
                    except (LookupError, ValueError) as e:
                        trace_event("serve.refresh", cause="watcher",
                                    changed=False, batch=batch_idx,
                                    skipped=str(e))
                        if log:
                            log(f"watcher: refresh skipped ({e})")
                if controller is not None and self._adaptive:
                    # the load signal is *effective* ms/step: service time
                    # scaled by outstanding work (Little's-law flavour) —
                    # raw step latency is nearly plan-independent, so a
                    # building queue, not the step clock, is what says
                    # "trade accuracy for throughput" under ramp/spike load
                    eff_ms = stats.ms_per_step * (1.0 + backlog / self.batch)
                    # with classes, the batch may have decoded below the
                    # global level (its class cap) — its drift then says
                    # nothing about the global operating point
                    drift_sig = (stats.drift
                                 if scheduler is None or level_c == glevel
                                 else None)
                    level = controller.observe(eff_ms, drift_sig)
                    if level is not None:
                        trace_event("serve.control", level=level,
                                    cause=controller.last_reason,
                                    batch=batch_idx)
                        if scheduler is None:
                            moved = self.swap_plan(
                                controller.plan, controller.luts(),
                                reason=f"qos-{controller.last_reason}",
                                telemetry=telemetry, batch_idx=batch_idx)
                            if moved and log:
                                log(f"batch {batch_idx}: controller -> "
                                    f"level {level} "
                                    f"({controller.last_reason}), plan "
                                    f"{self._plan.plan_id}")
                        else:
                            # the global operating point moved; per-class
                            # stacks resolve against it at their next
                            # batch.  glevel was read before a possible
                            # mid-iteration ladder refresh — clamp both
                            # levels to the ladder the swap log points at
                            lad = scheduler.ladder
                            telemetry.record_swap(
                                batch=batch_idx,
                                reason=f"qos-{controller.last_reason}",
                                old=lad.plan(min(glevel,
                                                 len(lad) - 1)).plan_id,
                                new=lad.plan(min(level,
                                                 len(lad) - 1)).plan_id)
                            if log:
                                log(f"batch {batch_idx}: controller -> "
                                    f"global level {level} "
                                    f"({controller.last_reason})")
                if on_batch_end is not None:
                    on_batch_end(self, batch_idx)
                batch_idx += 1
        return telemetry
