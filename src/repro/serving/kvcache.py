"""Paged KV cache: a block allocator for the continuous-batching engine.

The fixed-batch engine reserves a dense ``(batch, prompt+gen, ...)`` KV
cache per slot — every slot pays for the *longest* request it might ever
see.  Paging splits the cache into fixed-size pages shared by all slots:
each admitted request owns just enough pages for its own
``prompt_len + gen_len`` tokens, returned to a free list the moment the
request completes.  Heterogeneous prompt lengths then cost what they use,
and total cache memory is ``n_pages * page_size`` tokens instead of
``max_slots * max_len``.

Split of responsibilities:

* :class:`PageAllocator` (this module) is **pure host-side bookkeeping**:
  a free list plus per-request page tables, with hard alloc/free
  invariants (no double alloc, no foreign free, conservation of pages).
  It never touches device memory.
* The device-side page *pools* — one ``(n_pages + 1, page_size, ...)``
  array per paged layer — are built by
  :func:`repro.models.lm.init_paged_caches`; the jitted decode step
  scatters each slot's new KV row into ``pool[table[pos // page_size],
  pos % page_size]`` and gathers ``pool[table]`` back for attention.
  Physical page 0 is a **scratch page** reserved by the allocator:
  inactive slots write there and unused table entries point there, so
  masking (not allocation state) is what keeps requests isolated.

Allocation is whole-lifetime: a request's pages for ``prompt + gen``
tokens are claimed at admission, so admission *blocks* when the pool is
exhausted (``can_alloc`` says no) instead of a request stalling — or
corrupting a neighbour — mid-decode.  Preempted requests keep their
pages (their KV survives; resuming is a slot re-stack, not a re-prefill),
which is exactly why ``free`` is keyed by request id, not slot.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["PageAllocator", "OutOfPages"]

# physical page id every unused/inactive page-table entry points at; the
# decode step routes masked writes there and never reads it unmasked
SCRATCH_PAGE = 0


class OutOfPages(Exception):
    """The pool cannot satisfy an allocation (admission should block)."""


class PageAllocator:
    """Free-list allocator over ``n_pages`` fixed-size pages.

    Page ids handed out are physical indices in ``[1, n_pages]`` —
    index 0 is the reserved scratch page (:data:`SCRATCH_PAGE`).  The
    free list is LIFO and seeded in descending order, so allocation
    order is deterministic: same admission sequence, same page tables,
    same preempted set (``tests/test_continuous.py`` pins this).
    """

    def __init__(self, n_pages: int, page_size: int) -> None:
        if n_pages < 1 or page_size < 1:
            raise ValueError(
                f"need at least one page of at least one token "
                f"(got n_pages={n_pages}, page_size={page_size})")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO free list, low ids on top: freshly freed pages are reused
        # first (cache-warm) and allocation stays deterministic
        self._free: list[int] = list(range(self.n_pages, 0, -1))
        self._tables: dict[int, list[int]] = {}

    # ----------------------------------------------------------------- sizing
    def pages_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(int(n_tokens) / self.page_size))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def holds(self, rid: int) -> bool:
        return rid in self._tables

    def table(self, rid: int) -> tuple[int, ...]:
        return tuple(self._tables[rid])

    def can_alloc(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    # ------------------------------------------------------------- alloc/free
    def alloc(self, rid: int, n_tokens: int) -> tuple[int, ...]:
        """Claim pages for ``n_tokens`` cache slots under request ``rid``.

        Raises :class:`OutOfPages` when the free list cannot cover the
        request (callers treat this as "admission blocks") and
        ``ValueError`` on a double allocation — a request that already
        holds pages (e.g. a preempted one) must resume, not re-alloc.
        """
        if rid in self._tables:
            raise ValueError(f"request {rid} already holds pages "
                             f"(preempted requests keep theirs; resume)")
        need = self.pages_for(n_tokens)
        if need > len(self._free):
            raise OutOfPages(
                f"request {rid} needs {need} page(s), {len(self._free)} free "
                f"(of {self.n_pages})")
        pages = [self._free.pop() for _ in range(need)]
        self._tables[rid] = pages
        return tuple(pages)

    def free(self, rid: int) -> int:
        """Return ``rid``'s pages to the free list; returns how many.
        Freeing a request that holds nothing is an error — it would mask
        double-free bugs that corrupt a neighbour's table."""
        pages = self._tables.pop(rid, None)
        if pages is None:
            raise ValueError(f"request {rid} holds no pages")
        self._free.extend(reversed(pages))
        assert len(self._free) <= self.n_pages, "free list overflow"
        return len(pages)

    # ---------------------------------------------------------------- tables
    def padded_table(self, rid: int | None, n_entries: int) -> np.ndarray:
        """``rid``'s page table as a fixed-width int32 row for the jitted
        step: unused tail entries (and the whole row for ``rid=None``,
        i.e. an empty slot) point at the scratch page."""
        row = np.full((n_entries,), SCRATCH_PAGE, dtype=np.int32)
        if rid is not None:
            pages = self._tables[rid]
            if len(pages) > n_entries:
                raise ValueError(
                    f"request {rid} holds {len(pages)} pages but the step "
                    f"table has {n_entries} entries")
            row[: len(pages)] = pages
        return row

    def check_invariants(self) -> None:
        """Every physical page is owned exactly once (free list or one
        table), and the scratch page is never handed out."""
        free = list(self._free)
        owned = [p for t in self._tables.values() for p in t]
        seen = free + owned
        assert len(seen) == self.n_pages, (
            f"page conservation violated: {len(seen)} owned vs "
            f"{self.n_pages} total")
        assert len(set(seen)) == len(seen), "a page has two owners"
        assert SCRATCH_PAGE not in seen, "scratch page was allocated"
        assert all(1 <= p <= self.n_pages for p in seen), seen
