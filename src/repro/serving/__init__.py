"""Adaptive serving runtime: the third pillar (search → library → serving).

``repro.launch.serve`` used to freeze one QoS plan at startup; this
package makes the plan a *runtime input*.  The paper's template search
yields a whole Pareto frontier of operators, and QoS-Nets-style adaptive
deployment is where that frontier pays off: a serving fleet trades
accuracy for throughput under load, between batches, without ever
recompiling the decode step.

* :mod:`repro.serving.engine` — request queue + batched greedy-decode
  loop.  The per-layer ``(L, 16, 16)`` LUT stack is a plain jitted
  argument of the decode step, so a plan swap re-stacks arrays and reuses
  the one traced executable (``ServingEngine.trace_count`` stays 1).
* :mod:`repro.serving.controller` — QoS controller: EWMA latency versus
  a target band plus measured logit drift versus an exact shadow step,
  walking a :class:`~repro.serving.controller.PlanLadder` up (cheaper)
  under load and down (more exact) when drift headroom shrinks, with
  patience/cooldown hysteresis so it never flaps.
* :mod:`repro.serving.watcher` — store watcher: detects
  ``OperatorStore.version_token`` changes (a background ``repro.fleet``
  sweep densifying the library mid-serve) and refreshes the frontier
  atomically via ``ParetoFrontier.from_store`` → ``qos.refresh_plan`` →
  ``stack_luts``.
* :mod:`repro.serving.telemetry` — ring-buffer metrics (tok/s split by
  prefill/decode, ms/step, active plan, swap events) dumped as one JSON
  document for the bench trajectory (``BENCH_serve.json``).
* :mod:`repro.serving.loadgen` — deterministic synthetic request
  schedules (steady / ramp / spike) so the whole loop is testable on CPU
  with ``--reduced``; requests carry a QoS-class tag (``class_mix``) and
  optionally heterogeneous prompt lengths (``prompt_dist``).

The production serving tier layers continuous batching on top:

* :mod:`repro.serving.kvcache` — paged KV block allocator (fixed-size
  pages, per-request page tables, free-list reuse, hard alloc/free
  invariants).
* :mod:`repro.serving.slots` — the fixed decode-slot pool, per-request
  decode state, and weighted-fair admission queues.
* :class:`~repro.serving.engine.ContinuousServingEngine` — token-level
  scheduling: requests join/leave the running batch per step via an
  active-mask, SLO-carrying classes (``gold:0.02@8ms``) preempt lower
  tiers (victims keep their pages and resume), all through the same
  single-traced decode step.
* :mod:`repro.serving.router` — a multi-replica front over engines
  sharing one watched store with per-replica plan state.

Class-aware and mixed-width serving plug in from
:mod:`repro.sensitivity`: a
:class:`~repro.sensitivity.classes.ClassScheduler` gives every declared
traffic tier its own queue and ladder level (per-batch LUT stacks, same
single trace), an
:class:`~repro.sensitivity.online.OnlineSensitivity` folds the shadow
drift samples back into per-layer sensitivities, and a frozen per-layer
``width_map`` serves one LUT stack per width group
(:func:`repro.precision.plans.build_mixed_ladder`).
"""

from .controller import (ControllerConfig, PlanLadder, QoSController,
                         effective_load_ms)
from .engine import BatchStats, ContinuousServingEngine, ServingEngine
from .kvcache import OutOfPages, PageAllocator
from .loadgen import (LoadProfile, Request, make_profile, parse_prompt_dist,
                      ramp, spike, steady)
from .router import Replica, ReplicaRouter
from .slots import SeqState, SlotPool, WeightedFairQueues
from .telemetry import Telemetry
from .watcher import LibraryWatcher

__all__ = [
    "BatchStats",
    "ContinuousServingEngine",
    "ControllerConfig",
    "LibraryWatcher",
    "LoadProfile",
    "OutOfPages",
    "PageAllocator",
    "PlanLadder",
    "QoSController",
    "Replica",
    "ReplicaRouter",
    "Request",
    "SeqState",
    "ServingEngine",
    "SlotPool",
    "Telemetry",
    "WeightedFairQueues",
    "effective_load_ms",
    "make_profile",
    "parse_prompt_dist",
    "ramp",
    "spike",
    "steady",
]
