"""Deterministic synthetic load profiles for the serving runtime.

A :class:`LoadProfile` is a per-tick arrival count plus fixed request
shapes (prompt/gen lengths stay constant so the jitted decode step is
traced exactly once).  Profiles are pure data — the same ``(profile,
seed)`` pair synthesizes bit-identical request streams on any machine,
which is what makes the controller's end-to-end behaviour testable on
CPU with ``--reduced``.

Three canonical shapes cover the QoS controller's operating regimes:

* ``steady`` — constant arrivals; the controller should settle, not flap.
* ``ramp``   — linearly growing arrivals; the controller walks the
  frontier *up* (cheaper operators) as the queue builds.
* ``spike``  — baseline with a burst window; tests recovery hysteresis.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Request", "LoadProfile", "steady", "ramp", "spike",
           "make_profile", "synth_requests", "parse_prompt_dist",
           "PROFILES", "PROMPT_DISTS"]


@dataclass(frozen=True)
class Request:
    """One synthetic serving request: a prompt to greedily extend."""

    rid: int
    tokens: np.ndarray      # (prompt_len,) int32 prompt
    arrived_tick: int = 0
    qos_class: str = "std"  # traffic tier (repro.sensitivity.classes)


@dataclass(frozen=True)
class LoadProfile:
    """Arrivals per tick plus the (fixed) request geometry.

    ``class_mix`` optionally tags each synthesized request with a QoS
    class, drawn from the given ``((name, fraction), ...)`` distribution —
    the fractions should sum to 1 (``repro.sensitivity.classes.parse_class_mix``
    normalizes a CLI spec).  ``None`` keeps the legacy single-tier stream
    bit-identical (no extra RNG draws happen).

    ``prompt_dist`` optionally varies per-request prompt lengths inside
    ``[1, prompt_len]`` — ``("uniform", lo, hi)`` or ``("bimodal", lo,
    hi)`` (half the requests near ``lo``, half near ``hi``) — which is
    what makes the paged KV cache earn its keep: with fixed lengths every
    request needs the same page count and paging is pure overhead.
    ``prompt_len`` stays the *maximum* (the fixed-batch engine pads to
    it; the continuous engine sizes page tables by it)."""

    name: str
    arrivals: tuple[int, ...]
    prompt_len: int = 16
    gen_len: int = 32
    class_mix: tuple[tuple[str, float], ...] | None = None
    prompt_dist: tuple | None = None

    @property
    def n_ticks(self) -> int:
        return len(self.arrivals)

    @property
    def total_requests(self) -> int:
        return int(sum(self.arrivals))


def steady(ticks: int, per_tick: int, *, prompt_len: int = 16,
           gen_len: int = 32, class_mix=None,
           prompt_dist=None) -> LoadProfile:
    return LoadProfile("steady", (per_tick,) * ticks, prompt_len, gen_len,
                       class_mix, prompt_dist)


def ramp(ticks: int, peak: int, *, prompt_len: int = 16,
         gen_len: int = 32, class_mix=None, prompt_dist=None) -> LoadProfile:
    """0 -> ``peak`` arrivals, linearly over ``ticks`` ticks."""
    arr = tuple(int(round(peak * (t + 1) / ticks)) for t in range(ticks))
    return LoadProfile("ramp", arr, prompt_len, gen_len, class_mix,
                       prompt_dist)


def spike(ticks: int, base: int, peak: int, *, at: int | None = None,
          width: int | None = None, prompt_len: int = 16,
          gen_len: int = 32, class_mix=None,
          prompt_dist=None) -> LoadProfile:
    """``base`` arrivals with a ``peak`` burst of ``width`` ticks at ``at``."""
    at = ticks // 3 if at is None else at
    width = max(1, ticks // 4) if width is None else width
    arr = tuple(peak if at <= t < at + width else base for t in range(ticks))
    return LoadProfile("spike", arr, prompt_len, gen_len, class_mix,
                       prompt_dist)


PROFILES = ("steady", "ramp", "spike")
PROMPT_DISTS = ("uniform", "bimodal")

# prompt-length RNG salt: lengths ride their own stream (like the QoS
# class salt 0xC1A5) so turning a distribution on never changes which
# *tokens* a request would have drawn
_LEN_SALT = 0x1E57


def parse_prompt_dist(spec: str, prompt_len: int) -> tuple:
    """CLI prompt-length spec -> a :class:`LoadProfile.prompt_dist` tuple.

    ``"uniform:4-16"`` draws each request's length uniformly in [4, 16];
    ``"bimodal:4-16"`` draws half near 4 and half near 16.  Bounds must
    fit ``[1, prompt_len]`` — the profile's ``prompt_len`` stays the hard
    maximum every engine sizes against."""
    try:
        kind, _, rng = spec.partition(":")
        lo_s, _, hi_s = rng.partition("-")
        lo, hi = int(lo_s), int(hi_s)
    except ValueError:
        raise ValueError(
            f"bad prompt-length spec {spec!r}; expected kind:lo-hi, e.g. "
            f"uniform:4-16 (kinds: {PROMPT_DISTS})") from None
    if kind not in PROMPT_DISTS:
        raise ValueError(
            f"unknown prompt-length distribution {kind!r}; "
            f"known: {PROMPT_DISTS}")
    if not 1 <= lo <= hi <= prompt_len:
        raise ValueError(
            f"prompt-length bounds {lo}-{hi} must satisfy "
            f"1 <= lo <= hi <= prompt_len ({prompt_len})")
    return (kind, lo, hi)


def _draw_lengths(dist: tuple, n: int, rng: np.random.Generator
                  ) -> np.ndarray:
    kind, lo, hi = dist
    if kind == "uniform":
        return rng.integers(lo, hi + 1, size=n)
    if kind == "bimodal":
        # two tight modes at the bounds: the short/long request mix that
        # makes fixed-size per-slot caches (and fixed batches) look worst
        mode = rng.integers(0, 2, size=n)
        jitter = rng.integers(0, max(1, (hi - lo) // 4) + 1, size=n)
        return np.where(mode == 0, np.minimum(lo + jitter, hi),
                        np.maximum(hi - jitter, lo))
    raise ValueError(f"unknown prompt-length distribution {kind!r}")


def make_profile(kind: str, *, ticks: int, per_tick: int,
                 prompt_len: int = 16, gen_len: int = 32,
                 class_mix=None, prompt_dist=None) -> LoadProfile:
    """CLI helper: one of :data:`PROFILES` at a given scale.  ``per_tick``
    is the steady rate / ramp peak / spike peak (spike base is 1)."""
    if kind == "steady":
        return steady(ticks, per_tick, prompt_len=prompt_len, gen_len=gen_len,
                      class_mix=class_mix, prompt_dist=prompt_dist)
    if kind == "ramp":
        return ramp(ticks, per_tick, prompt_len=prompt_len, gen_len=gen_len,
                    class_mix=class_mix, prompt_dist=prompt_dist)
    if kind == "spike":
        return spike(ticks, 1, per_tick, prompt_len=prompt_len,
                     gen_len=gen_len, class_mix=class_mix,
                     prompt_dist=prompt_dist)
    raise ValueError(f"unknown load profile {kind!r}; known: {PROFILES}")


def synth_requests(profile: LoadProfile, vocab_size: int,
                   seed: int = 0) -> list[list[Request]]:
    """Materialize the request stream: ``out[tick]`` is that tick's
    arrivals.  Prompts follow the same Zipf-ish token distribution as
    :func:`repro.train.data.synth_batch`; the RNG is seeded per
    ``(seed, tick)`` and drawn sequentially within the tick, so the same
    profile + seed reproduces the stream bit-identically (changing a
    tick's arrival count reshuffles only that tick's later prompts).
    With a ``class_mix``, QoS classes come from a *separate* RNG stream
    (seeded per ``(seed, tick)`` with a class salt), so tagging traffic
    never changes the token stream a profile would synthesize untagged.
    ``prompt_dist`` lengths likewise ride their own salted stream, and a
    request always draws its full ``prompt_len`` ranks before truncating
    to the drawn length — request *i*'s tokens are a prefix of what it
    would have drawn at any other length setting."""
    names = probs = None
    if profile.class_mix:
        names = [n for n, _ in profile.class_mix]
        probs = np.asarray([f for _, f in profile.class_mix],
                           dtype=np.float64)
        probs = probs / probs.sum()
    out: list[list[Request]] = []
    rid = 0
    for tick, n in enumerate(profile.arrivals):
        rng = np.random.default_rng((seed, tick))
        crng = np.random.default_rng((seed, tick, 0xC1A5))
        lens = None
        if profile.prompt_dist is not None:
            lrng = np.random.default_rng((seed, tick, _LEN_SALT))
            lens = _draw_lengths(profile.prompt_dist, n, lrng)
        reqs = []
        for i in range(n):
            ranks = rng.zipf(1.2, size=profile.prompt_len).astype(np.int64)
            tokens = np.minimum(ranks - 1, vocab_size - 1).astype(np.int32)
            if lens is not None:
                tokens = tokens[: int(lens[i])]
            cls = (names[crng.choice(len(names), p=probs)]
                   if names is not None else "std")
            reqs.append(Request(rid=rid, tokens=tokens, arrived_tick=tick,
                                qos_class=cls))
            rid += 1
        out.append(reqs)
    return out
