"""Deterministic synthetic load profiles for the serving runtime.

A :class:`LoadProfile` is a per-tick arrival count plus fixed request
shapes (prompt/gen lengths stay constant so the jitted decode step is
traced exactly once).  Profiles are pure data — the same ``(profile,
seed)`` pair synthesizes bit-identical request streams on any machine,
which is what makes the controller's end-to-end behaviour testable on
CPU with ``--reduced``.

Three canonical shapes cover the QoS controller's operating regimes:

* ``steady`` — constant arrivals; the controller should settle, not flap.
* ``ramp``   — linearly growing arrivals; the controller walks the
  frontier *up* (cheaper operators) as the queue builds.
* ``spike``  — baseline with a burst window; tests recovery hysteresis.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["Request", "LoadProfile", "steady", "ramp", "spike",
           "make_profile", "synth_requests", "PROFILES"]


@dataclass(frozen=True)
class Request:
    """One synthetic serving request: a prompt to greedily extend."""

    rid: int
    tokens: np.ndarray      # (prompt_len,) int32 prompt
    arrived_tick: int = 0
    qos_class: str = "std"  # traffic tier (repro.sensitivity.classes)


@dataclass(frozen=True)
class LoadProfile:
    """Arrivals per tick plus the (fixed) request geometry.

    ``class_mix`` optionally tags each synthesized request with a QoS
    class, drawn from the given ``((name, fraction), ...)`` distribution —
    the fractions should sum to 1 (``repro.sensitivity.classes.parse_class_mix``
    normalizes a CLI spec).  ``None`` keeps the legacy single-tier stream
    bit-identical (no extra RNG draws happen)."""

    name: str
    arrivals: tuple[int, ...]
    prompt_len: int = 16
    gen_len: int = 32
    class_mix: tuple[tuple[str, float], ...] | None = None

    @property
    def n_ticks(self) -> int:
        return len(self.arrivals)

    @property
    def total_requests(self) -> int:
        return int(sum(self.arrivals))


def steady(ticks: int, per_tick: int, *, prompt_len: int = 16,
           gen_len: int = 32, class_mix=None) -> LoadProfile:
    return LoadProfile("steady", (per_tick,) * ticks, prompt_len, gen_len,
                       class_mix)


def ramp(ticks: int, peak: int, *, prompt_len: int = 16,
         gen_len: int = 32, class_mix=None) -> LoadProfile:
    """0 -> ``peak`` arrivals, linearly over ``ticks`` ticks."""
    arr = tuple(int(round(peak * (t + 1) / ticks)) for t in range(ticks))
    return LoadProfile("ramp", arr, prompt_len, gen_len, class_mix)


def spike(ticks: int, base: int, peak: int, *, at: int | None = None,
          width: int | None = None, prompt_len: int = 16,
          gen_len: int = 32, class_mix=None) -> LoadProfile:
    """``base`` arrivals with a ``peak`` burst of ``width`` ticks at ``at``."""
    at = ticks // 3 if at is None else at
    width = max(1, ticks // 4) if width is None else width
    arr = tuple(peak if at <= t < at + width else base for t in range(ticks))
    return LoadProfile("spike", arr, prompt_len, gen_len, class_mix)


PROFILES = ("steady", "ramp", "spike")


def make_profile(kind: str, *, ticks: int, per_tick: int,
                 prompt_len: int = 16, gen_len: int = 32,
                 class_mix=None) -> LoadProfile:
    """CLI helper: one of :data:`PROFILES` at a given scale.  ``per_tick``
    is the steady rate / ramp peak / spike peak (spike base is 1)."""
    if kind == "steady":
        return steady(ticks, per_tick, prompt_len=prompt_len, gen_len=gen_len,
                      class_mix=class_mix)
    if kind == "ramp":
        return ramp(ticks, per_tick, prompt_len=prompt_len, gen_len=gen_len,
                    class_mix=class_mix)
    if kind == "spike":
        return spike(ticks, 1, per_tick, prompt_len=prompt_len,
                     gen_len=gen_len, class_mix=class_mix)
    raise ValueError(f"unknown load profile {kind!r}; known: {PROFILES}")


def synth_requests(profile: LoadProfile, vocab_size: int,
                   seed: int = 0) -> list[list[Request]]:
    """Materialize the request stream: ``out[tick]`` is that tick's
    arrivals.  Prompts follow the same Zipf-ish token distribution as
    :func:`repro.train.data.synth_batch`; the RNG is seeded per
    ``(seed, tick)`` and drawn sequentially within the tick, so the same
    profile + seed reproduces the stream bit-identically (changing a
    tick's arrival count reshuffles only that tick's later prompts).
    With a ``class_mix``, QoS classes come from a *separate* RNG stream
    (seeded per ``(seed, tick)`` with a class salt), so tagging traffic
    never changes the token stream a profile would synthesize untagged."""
    names = probs = None
    if profile.class_mix:
        names = [n for n, _ in profile.class_mix]
        probs = np.asarray([f for _, f in profile.class_mix],
                           dtype=np.float64)
        probs = probs / probs.sum()
    out: list[list[Request]] = []
    rid = 0
    for tick, n in enumerate(profile.arrivals):
        rng = np.random.default_rng((seed, tick))
        crng = np.random.default_rng((seed, tick, 0xC1A5))
        reqs = []
        for _ in range(n):
            ranks = rng.zipf(1.2, size=profile.prompt_len).astype(np.int64)
            tokens = np.minimum(ranks - 1, vocab_size - 1).astype(np.int32)
            cls = (names[crng.choice(len(names), p=probs)]
                   if names is not None else "std")
            reqs.append(Request(rid=rid, tokens=tokens, arrived_tick=tick,
                                qos_class=cls))
            rid += 1
        out.append(reqs)
    return out
